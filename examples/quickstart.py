"""Quickstart: the MXInt format and the paper's three datapaths in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MXFormat, NonlinearConfig, quantize, dequantize,
                        MXINT6_WEIGHT, MXINT8_ACT)
from repro.core import nonlinear as nl

rng = np.random.default_rng(0)

# --- 1. the format -----------------------------------------------------------
x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32)) * 5
t = quantize(x, MXINT8_ACT)           # int8 mantissas + shared exponents
x_hat = dequantize(t)
print("MXInt8 (A8.5):")
print(f"  bits/element   : {MXINT8_ACT.bits_per_element}")
print(f"  reconstruction : max|err| = {float(jnp.max(jnp.abs(x - x_hat))):.4f}")
print(f"  weight format W{MXINT6_WEIGHT.bits_per_element:.2f} -> "
      f"{MXINT6_WEIGHT.density_vs(32):.2f}x denser than f32")

# --- 2. outlier isolation (why microscaling wins) ---------------------------
y = np.full((1, 64), 0.01, np.float32)
y[0, 0] = 1000.0
yq = dequantize(quantize(jnp.asarray(y), MXINT8_ACT))
print(f"\noutlier test: small values survive next to a 1000x outlier: "
      f"{float(yq[0, 20]):.4f} (true 0.01)")

# --- 3. the three datapaths (paper §III-B) -----------------------------------
cfg = NonlinearConfig()               # LN 5 bits, GELU 5 bits/a=3, SM 2 bits
g, b = jnp.ones((64,)), jnp.zeros((64,))
ln = nl.layernorm_value(x, g, b, cfg, MXINT8_ACT)
ln_ref = (x - x.mean(-1, keepdims=True)) / jnp.sqrt(x.var(-1, keepdims=True)
                                                    + 1e-6)
sm = nl.softmax_value(x, cfg, MXINT8_ACT)
ge = nl.gelu_value(x, cfg, MXINT8_ACT)
print("\nMXInt datapaths vs float ops (mean |err|):")
print(f"  LayerNorm (LUT_1/sqrt, {cfg.ln_lut_entries} entries): "
      f"{float(jnp.mean(jnp.abs(ln - ln_ref))):.4f}")
print(f"  Softmax   (LUT_pow2,   {cfg.softmax_lut_entries} entries): "
      f"{float(jnp.mean(jnp.abs(sm - jax.nn.softmax(x, -1)))):.4f}")
print(f"  GELU      (LUT_GELU,   {cfg.gelu_lut_entries} entries): "
      f"{float(jnp.mean(jnp.abs(ge - jax.nn.gelu(x, approximate=False)))):.4f}")

# --- 4. a fully-quantized ViT forward pass ----------------------------------
import dataclasses
from repro.configs.deit import DEIT_MICRO
from repro.core.mx_types import QuantConfig
from repro.models import build_model

cfg_q = dataclasses.replace(DEIT_MICRO, quant=QuantConfig(
    mode="sim", quantize_nonlinear=True))
model_q = build_model(cfg_q)
model_f = build_model(DEIT_MICRO)
params = model_f.init(jax.random.key(0))
imgs = jnp.asarray(rng.normal(size=(2, 32, 32, 3)).astype(np.float32))
lq = model_q.logits(params, imgs)
lf = model_f.logits(params, imgs)
cos = float(jnp.vdot(lq.ravel(), lf.ravel()) /
            (jnp.linalg.norm(lq) * jnp.linalg.norm(lf)))
print(f"\nfully-MXInt DeiT forward (W6/A8.5 + LN/GELU/Softmax datapaths):")
print(f"  logit cosine vs float model: {cos:.4f}")
print("\nquickstart OK")
