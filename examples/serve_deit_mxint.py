"""End-to-end driver: serve a DeiT classifier fully quantized in MXInt.

This is the paper's deployment scenario — a ViT whose EVERY operator
(linears, LayerNorm, GELU, Softmax) runs the MXInt datapath — wrapped in a
batched inference service: requests arrive, are batched, classified, and
answered; throughput and accuracy-vs-float are reported.

The serving path is ``mode='kernel'``: weights are packed once into int8
mantissa/exponent planes and fed straight into the Pallas kernels through
``ViTServingEngine`` (on CPU the kernels run in interpret mode; on TPU
they compile).  The ``mode='sim'`` XLA oracle is also run and must agree
bit-for-bit — the serving datapath IS the validated datapath.

Run:  PYTHONPATH=src python examples/serve_deit_mxint.py [--requests 64]
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/
from benchmarks import common
from repro.core.mx_types import QuantConfig
from repro.data.pipeline import SyntheticImageData
from repro.models import build_model
from repro.serving.engine import ServeConfig, ViTServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    print("training/loading the float DeiT (synthetic 100-class task)...")
    model_f, params = common.trained_deit_micro()

    kcfg = QuantConfig(mode="kernel", quantize_nonlinear=True)
    model_k = build_model(dataclasses.replace(common.BENCH_DEIT, quant=kcfg))
    engine = ViTServingEngine(
        model_k, params,
        ServeConfig(batch=args.batch, pack_weights=True,
                    weight_fmt=kcfg.weight_fmt))

    scfg = QuantConfig(mode="sim", quantize_nonlinear=True)
    model_s = build_model(dataclasses.replace(common.BENCH_DEIT, quant=scfg))
    classify_s = jax.jit(model_s.logits)
    classify_f = jax.jit(model_f.logits)

    data = SyntheticImageData(batch=args.batch, seed=123, **common._TASK)
    served = agree = correct = sim_exact = 0
    t0 = time.time()
    lat = []
    while served < args.requests:
        batch = data.next_batch()
        t1 = time.time()
        pred, logits = engine.classify(batch["images"])
        jax.block_until_ready(logits)
        lat.append(time.time() - t1)
        ref = classify_f(params, batch["images"])
        sim = classify_s(params, batch["images"])
        sim_exact += int(np.array_equal(np.asarray(logits), np.asarray(sim)))
        agree += int(jnp.sum(pred == jnp.argmax(ref, -1)))
        correct += int(jnp.sum(pred == batch["labels"]))
        served += args.batch
    dt = time.time() - t0
    n_batches = served // args.batch

    print(f"\nserved {served} requests in {dt:.2f}s "
          f"({served/dt:.1f} img/s, Pallas kernel path, packed weights)")
    print(f"  p50 batch latency   : {1e3*np.percentile(lat, 50):.1f} ms")
    print(f"  accuracy (MXInt)    : {correct/served:.4f}")
    print(f"  agreement w/float   : {agree/served:.4f}  "
          f"(paper budget: within 1% -> {agree/served >= 0.99})")
    print(f"  kernel == sim (bit) : {sim_exact}/{n_batches} batches")


if __name__ == "__main__":
    main()
