"""End-to-end driver: serve a DeiT classifier fully quantized in MXInt.

This is the paper's deployment scenario — a ViT whose EVERY operator
(linears, LayerNorm, GELU, Softmax) runs the MXInt datapath — wrapped in a
batched inference service: requests arrive, are continuously batched into
one fixed-shape jit, classified, and answered; throughput and
accuracy-vs-float are reported.

The serving path is ``mode='kernel'``: weights are packed once into int8
mantissa/exponent planes and fed straight into the Pallas kernels through
``ViTServingEngine`` (on CPU the kernels run in interpret mode; on TPU
they compile).  The ``mode='sim'`` XLA oracle is also run and must agree
bit-for-bit — the serving datapath IS the validated datapath.

With ``--tp N`` the engine serves SHARDED: the packed planes are
partitioned over an N-way 'model' mesh and every linear runs per shard
under shard_map — still bit-identical to the single-device sim oracle
(DESIGN.md §10).  On CPU the fake devices are forced automatically.

Requests are streamed through ``ClassifyScheduler``: each request carries
a RANDOM number of images, and the scheduler packs them across request
boundaries into the fixed batch shape — zero recompiles after warmup.

``--metrics-json PATH`` dumps the full ``repro.telemetry`` snapshot of
the serving run — request-latency histograms, queue/slot gauges, the
``serving/recompiles`` counter (0 after warmup) — plus a
``predicted_vs_measured`` section joining live DeiT kernel probes
(``matmul-deit``, ``flash-deit``) against the static cost-model table
by row label (DESIGN.md §15).

Run:  PYTHONPATH=src python examples/serve_deit_mxint.py \
          [--requests 64] [--batch 16] [--tp 2] [--metrics-json out.json]
"""
import argparse
import dataclasses
import os
import sys
import time
from pathlib import Path


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64,
                    help="total images to serve")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1,
                    help="shard packed planes over an N-way 'model' mesh")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the telemetry snapshot + the "
                         "predicted-vs-measured kernel roofline here")
    return ap.parse_args()


def main():
    args = _parse_args()
    if args.tp > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        # must land before the first jax device query (backend init)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.tp}")

    import jax
    import jax.numpy as jnp
    import numpy as np

    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/
    from benchmarks import common
    from repro.core.mx_types import QuantConfig
    from repro.data.pipeline import SyntheticImageData
    from repro.models import build_model
    from repro.serving.engine import ServeConfig, ViTServingEngine
    from repro.serving.scheduler import ClassifyRequest, ClassifyScheduler

    print("training/loading the float DeiT (synthetic 100-class task)...")
    model_f, params = common.trained_deit_micro()

    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(args.tp)
        print(f"serving sharded: packed planes over a {args.tp}-way "
              "'model' mesh (column-parallel, bit-exact)")

    kcfg = QuantConfig(mode="kernel", quantize_nonlinear=True)
    model_k = build_model(dataclasses.replace(common.BENCH_DEIT, quant=kcfg))
    engine = ViTServingEngine(
        model_k, params,
        ServeConfig(batch=args.batch, pack_weights=True,
                    weight_fmt=kcfg.weight_fmt),
        mesh=mesh)

    scfg = QuantConfig(mode="sim", quantize_nonlinear=True)
    model_s = build_model(dataclasses.replace(common.BENCH_DEIT, quant=scfg))
    classify_s = jax.jit(model_s.logits)
    classify_f = jax.jit(model_f.logits)

    data = SyntheticImageData(batch=args.batch, seed=123, **common._TASK)
    # warm the one jit specialization, then stream mixed-size requests
    warm = data.next_batch()
    engine.classify(warm["images"])
    cache_warm = engine.jit_cache_size()

    rng = np.random.default_rng(7)
    sched = ClassifyScheduler(engine)
    pool_imgs, pool_labels = [], []
    served = 0
    uid = 0
    while served < args.requests:
        batch = data.next_batch()
        pool_imgs.append(np.asarray(batch["images"]))
        pool_labels.append(np.asarray(batch["labels"]))
        served += args.batch
    imgs = np.concatenate(pool_imgs)
    labels = np.concatenate(pool_labels)
    # slice the pool into randomly sized requests (1..batch images each)
    reqs, off = [], 0
    while off < imgs.shape[0]:
        n = int(rng.integers(1, args.batch + 1))
        reqs.append(ClassifyRequest(uid=uid, images=imgs[off:off + n]))
        uid += 1
        off += n

    t0 = time.time()
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    dt = time.time() - t0

    pred = np.concatenate([r.labels for r in done])
    logits = np.concatenate([r.logits for r in done])
    ref = np.asarray(classify_f(params, imgs))
    sim = np.asarray(classify_s(params, imgs))
    n = imgs.shape[0]

    print(f"\nserved {n} images across {len(done)} mixed-size requests "
          f"in {dt:.2f}s ({n/dt:.1f} img/s, Pallas kernel path, packed "
          f"weights{f', tp={args.tp}' if args.tp > 1 else ''})")
    print(f"  accuracy (MXInt)    : {np.mean(pred == labels):.4f}")
    agree = np.mean(pred == np.argmax(ref, -1))
    print(f"  agreement w/float   : {agree:.4f}  "
          f"(paper budget: within 1% -> {agree >= 0.99})")
    print(f"  kernel == sim (bit) : {np.array_equal(logits, sim)}")
    rc = engine.jit_cache_size() - cache_warm
    print(f"  recompiles after warmup: {rc if cache_warm >= 0 else 'n/a'}")

    if args.metrics_json:
        from repro.telemetry import export as tel_export
        from repro.telemetry import probes as tel_probes

        print("\nrunning kernel probes for the predicted-vs-measured "
              "join (DeiT matmul + flash attention)...")
        tel_probes.run_probes()
        pvm = tel_export.predicted_vs_measured()
        payload = tel_export.json_snapshot(
            path=args.metrics_json,
            extra={"predicted_vs_measured": pvm,
                   "run": {"images": int(n), "requests": len(done),
                           "img_per_s": round(n / dt, 2),
                           "tp": args.tp}})
        joined = {k["label"]: k["measured_ms"]
                  for k in pvm["kernels"]}
        print(f"  metrics -> {args.metrics_json}  "
              f"({len(payload['histograms'])} histograms, "
              f"joined kernels: {joined})")


if __name__ == "__main__":
    main()
