"""Train an LM with the full production loop: checkpoints, crash recovery,
heartbeats, metrics — then kill it mid-run and watch it resume.

Default config is CPU-sized; --arch picks any assigned architecture's smoke
config, --steps/--batch scale it up (the same loop + sharding machinery is
what the multi-pod dry-run compiles at the 512-chip mesh).

Run:  PYTHONPATH=src python examples/train_lm_fault_tolerant.py
"""
import argparse
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import cosine_schedule
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.state import make_train_state
from repro.train.step import make_train_step


def build(arch, tmpdir, total_steps, batch, seq):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    state = make_train_state(model, jax.random.key(0))
    data = SyntheticLMData(vocab=cfg.vocab, batch=batch, seq_len=seq, seed=7)
    lr_fn = lambda s: cosine_schedule(s, peak=3e-3, warmup_steps=10,
                                      total_steps=total_steps)
    step = jax.jit(make_train_step(model, lr_fn=lr_fn,
                                   opt_cfg=AdamWConfig(weight_decay=0.01)))
    lcfg = LoopConfig(total_steps=total_steps, checkpoint_every=10,
                      log_every=5, checkpoint_dir=str(tmpdir / "ckpt"),
                      metrics_path=str(tmpdir / "metrics.jsonl"),
                      heartbeat_path=str(tmpdir / "heartbeat.json"))
    return TrainLoop(train_step=step, state=state, data=data, cfg=lcfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args()

    tmpdir = Path("/tmp/repro_train_demo")
    shutil.rmtree(tmpdir, ignore_errors=True)
    tmpdir.mkdir(parents=True)

    print(f"=== phase 1: train to step {args.steps // 2}, then 'crash' ===")
    loop = build(args.arch, tmpdir, args.steps // 2, args.batch, args.seq)
    m1 = loop.run()
    print(f"   loss {m1[0]['loss']:.3f} -> {m1[-1]['loss']:.3f}; "
          f"checkpoint committed at step {loop.ckpt.latest_step()}")
    del loop  # the 'crash'

    print(f"=== phase 2: fresh process resumes from the checkpoint ===")
    loop2 = build(args.arch, tmpdir, args.steps, args.batch, args.seq)
    resumed = loop2.try_resume()
    print(f"   resumed from step {resumed} "
          f"(data stream index {loop2.data.state.next_index})")
    m2 = loop2.run(start_step=resumed)
    print(f"   final loss {m2[-1]['loss']:.3f} at step {m2[-1]['step']}")
    print(f"   metrics in {tmpdir}/metrics.jsonl, "
          f"heartbeat in {tmpdir}/heartbeat.json")
    assert m2[-1]["loss"] < m1[0]["loss"]
    print("fault-tolerant training demo OK")


if __name__ == "__main__":
    main()
