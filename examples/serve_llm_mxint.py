"""Serve an LM with packed MXInt weights + continuous batching.

Weights are stored as int8 mantissa planes + shared exponents (the paper's
format, W8 block-256), the KV cache and scheduler come from repro.serving.
Uses the llama3-family smoke config so it runs on CPU; pass --arch to pick
any assigned architecture.  ``--kernel`` switches the model to
QuantConfig(mode='kernel'): every linear eats the packed planes in a
Pallas kernel and each decode step scores the KV cache ring through the
fused `flash_attention_decode` datapath (DESIGN.md §11) — interpret mode
on CPU, so it is slower here but is the TPU deployment path.

Run:  PYTHONPATH=src python examples/serve_llm_mxint.py [--arch llama3_8b]
                                                        [--kernel]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.mx_types import MXINT8_WEIGHT, QuantConfig
from repro.models import build_model
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.scheduler import BatchScheduler, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--kernel", action="store_true",
                    help="mode='kernel': Pallas linears + fused decode "
                         "attention over the cache ring")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if args.kernel:
        cfg = dataclasses.replace(
            cfg, quant=QuantConfig(mode="kernel", quantize_nonlinear=True))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    print(f"arch={cfg.name}: packing weights to MXInt8 (block 256)...")
    eng = ServingEngine(model, params,
                        ServeConfig(max_len=128, batch=2, pack_weights=True,
                                    weight_fmt=MXINT8_WEIGHT))
    sched = BatchScheduler(eng, batch_size=2)

    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        sched.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
            max_new_tokens=args.new_tokens))

    t0 = time.time()
    done = []
    steps = 0
    while (any(not r.done for r in sched.active if r) or sched.queue) and \
            steps < 500:
        sched.step()
        steps += 1
        for i, r in enumerate(sched.active):
            if r is not None and r.done and r not in done:
                done.append(r)
                print(f"  req {r.uid}: {len(r.generated)} tokens -> "
                      f"{r.generated[:8]}...")
                sched.active[i] = None
    dt = time.time() - t0
    total_toks = sum(len(r.generated) for r in done)
    print(f"\n{len(done)} requests, {total_toks} tokens in {dt:.2f}s "
          f"({total_toks/max(dt,1e-9):.1f} tok/s, CPU, continuous batching)")


if __name__ == "__main__":
    main()
