#!/usr/bin/env python3
"""Docs link-check: every DESIGN.md section cited in the source exists.

Scans ``src/ benchmarks/ examples/ tests/`` for ``DESIGN.md §N``
citations (the docstring convention) and fails if docs/DESIGN.md is
missing, or any cited §N has no ``## §N`` heading, or the README lacks
the tier-1 verify command.

This check is folded into the unified static-analysis runner as the
``docs-links`` rule — CI and local runs go through that
(DESIGN.md §13)::

    PYTHONPATH=src python tools/repro_lint.py

Standalone invocation (``python tools/check_docs.py``) and the
importable ``check(root) -> list[str]`` remain for scripting.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

CITE = re.compile(r"DESIGN\.md\s+§(\d+)")
HEADING = re.compile(r"^##\s+§(\d+)\b", re.M)
SCAN_DIRS = ("src", "benchmarks", "examples", "tests", "docs")
TIER1 = "python -m pytest -x -q"


def check(root: Path) -> list:
    problems = []
    design = root / "docs" / "DESIGN.md"
    if not design.exists():
        return [f"missing {design}"]
    sections = set(HEADING.findall(design.read_text()))

    for d in SCAN_DIRS:
        for py in sorted((root / d).rglob("*.py")):
            if "__pycache__" in py.parts:
                continue
            for i, line in enumerate(py.read_text().splitlines(), 1):
                for sec in CITE.findall(line):
                    if sec not in sections:
                        problems.append(
                            f"{py.relative_to(root)}:{i} cites DESIGN.md "
                            f"§{sec} but docs/DESIGN.md has no '## §{sec}'")

    readme = root / "README.md"
    if not readme.exists():
        problems.append("missing README.md")
    elif TIER1 not in readme.read_text():
        problems.append(f"README.md lost the tier-1 command ({TIER1!r})")
    return problems


def main() -> int:
    root = Path(__file__).resolve().parents[1]
    problems = check(root)
    for p in problems:
        print(f"check_docs: {p}", file=sys.stderr)
    if not problems:
        print("check_docs: all DESIGN.md citations resolve")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
