#!/usr/bin/env python3
"""Unified static-analysis runner (DESIGN.md §13) — THE lint entrypoint.

Folds every registered ``repro.analysis`` pass (kernel contracts, trace
invariants, AST source rules) together with the two legacy tree checks
(``check_dispatch`` dispatch-seam scan, ``check_docs`` DESIGN-citation
scan) behind one command.  CI runs exactly this; tier-1 runs the same
registry in-process via ``tests/test_analysis.py``::

    PYTHONPATH=src python tools/repro_lint.py            # whole tree
    python tools/repro_lint.py --list                    # show rules
    python tools/repro_lint.py --only source-rules       # subset
    python tools/repro_lint.py --fixture vmem-over-budget  # must exit 1
    python tools/repro_lint.py --fixtures                # list fixtures
    python tools/repro_lint.py --only cost-model --json  # roofline table
    python tools/repro_lint.py --update-cost-baseline    # refresh bytes

Exit code 0 iff no error-severity violation (``warn`` findings print but
do not fail).  ``--fixture NAME`` runs one deliberately violating
fixture through its pass and exits non-zero when it fires — the
self-test that every rule can flag its own counterexample.
"""
from __future__ import annotations

import argparse
import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT / "src") not in sys.path:
    sys.path.insert(0, str(ROOT / "src"))


def _load_tool(name: str):
    spec = importlib.util.spec_from_file_location(
        name, ROOT / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _register_legacy_rules():
    """Adapt the standalone tree checks into the rule registry."""
    from repro.analysis import Violation, register_rule

    dispatch = _load_tool("check_dispatch")
    docs = _load_tool("check_docs")

    @register_rule("dispatch-seam",
                   "quant-mode branching only inside repro/datapath/ "
                   "(tools/check_dispatch.py)")
    def _dispatch(root):
        return [Violation("dispatch-seam", "tree", p)
                for p in dispatch.check(Path(root))]

    @register_rule("docs-links",
                   "DESIGN.md §N citations resolve; README keeps the "
                   "tier-1 command (tools/check_docs.py)")
    def _docs(root):
        return [Violation("docs-links", "tree", p)
                for p in docs.check(Path(root))]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--list", action="store_true",
                    help="list registered rules and exit")
    ap.add_argument("--only", help="comma-separated rule subset to run")
    ap.add_argument("--skip", default="",
                    help="comma-separated rules to skip")
    ap.add_argument("--fixture",
                    help="run one violating fixture; exits non-zero when "
                         "it fires (self-test)")
    ap.add_argument("--fixtures", action="store_true",
                    help="list fixture names and exit")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="emit a machine-readable report (violations + "
                         "cost-model roofline table) to PATH or stdout")
    ap.add_argument("--update-cost-baseline", action="store_true",
                    help="rewrite benchmarks/_cache/cost_model_baseline"
                         ".json from the current tree and exit")
    args = ap.parse_args(argv)

    import repro.analysis as AN
    _register_legacy_rules()

    if args.update_cost_baseline:
        from repro.analysis import cost_model
        path = cost_model.write_baseline(ROOT)
        print(f"repro_lint: wrote {path.relative_to(ROOT)}")
        return 0

    if args.list:
        for rule in AN.rules():
            print(f"{rule.name:24s} {rule.description}")
        return 0

    if args.fixtures:
        from repro.analysis.fixtures import FIXTURES
        for name in FIXTURES:
            print(name)
        return 0

    if args.fixture:
        from repro.analysis.fixtures import FIXTURES, run_fixture
        if args.fixture not in FIXTURES:
            print(f"repro_lint: unknown fixture {args.fixture!r} "
                  f"(try --fixtures)", file=sys.stderr)
            return 2
        violations = run_fixture(args.fixture)
        for v in violations:
            print(f"repro_lint: {v}", file=sys.stderr)
        if not violations:
            print(f"repro_lint: fixture {args.fixture!r} did NOT fire — "
                  f"its rule is dead", file=sys.stderr)
            return 0   # exit 0 == self-test FAILURE (tests assert != 0)
        return 1

    only = args.only.split(",") if args.only else None
    skip = tuple(s for s in args.skip.split(",") if s)
    violations = AN.run_rules(ROOT, only=only, skip=skip)
    errors = [v for v in violations if v.severity == AN.ERROR]
    warns = [v for v in violations if v.severity != AN.ERROR]

    if args.json is not None:
        import json as _json

        from repro.analysis import cost_model
        payload = {
            "rules": [r.name for r in AN.rules()],
            "violations": [
                {"rule": v.rule, "where": v.where, "severity": v.severity,
                 "message": v.message} for v in violations],
            "errors": len(errors),
            "cost_model": cost_model.report(ROOT),
        }
        text = _json.dumps(payload, indent=1, sort_keys=True)
        if args.json == "-":
            print(text)
        else:
            Path(args.json).write_text(text + "\n")
            print(f"repro_lint: wrote {args.json}", file=sys.stderr)
            # the CI log should still show findings inline, not only
            # inside the archived artifact
            for v in violations:
                print(f"repro_lint: {v}", file=sys.stderr)
        return 1 if errors else 0

    for v in warns:
        print(f"repro_lint: warning {v}", file=sys.stderr)
    for v in errors:
        print(f"repro_lint: {v}", file=sys.stderr)
    if errors:
        print(f"repro_lint: {len(errors)} violation(s)", file=sys.stderr)
        return 1
    print(f"repro_lint: clean ({len(AN.rules())} rules"
          + (f", {len(warns)} warning(s)" if warns else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
