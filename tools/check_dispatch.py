#!/usr/bin/env python3
"""Dispatch-seam check: mode branching only inside ``repro/datapath/``.

The execution-backend redesign (DESIGN.md §12) moved every
``QuantConfig.mode`` decision behind the ``q.datapath`` backend object;
``models/``, ``kernels/``, ``serving/`` must never again branch on the
mode string, or the pluggable seam silently regrows into per-op
if-chains.  This tool scans ``src/`` for

    ``.mode ==`` / ``.mode !=`` / ``.mode in`` / ``.mode not in``
    and bare ``mode in (...)`` membership tests

and fails unless the line lives in ``src/repro/datapath/`` (backends may
branch) or ``src/repro/core/mx_types.py`` (mode validation + backend
resolution).  The attribute rule is deliberately TOTAL: any ``.mode``
token outside the seam is flagged — reversed comparisons
(``"kernel" == q.mode``), ``q.mode.startswith(...)``, ``match q.mode:``
and dict-dispatch ``{...}[q.mode]`` all require writing ``.mode``, so
none can evade the guard (nothing outside the seam has a legitimate
read of the mode string; identifiers merely ENDING in "mode" —
``tp_mode``, ``exp_mode`` — are untouched).

The per-layer override plumbing (DESIGN.md §16) gets the same
treatment: reading ``.overrides`` outside the seam re-implements scope
resolution ad hoc — scoping decisions go through ``QuantConfig.scoped``
/ ``datapath.resolve(q, scope)`` only, so any ``.overrides`` attribute
read in ``src/`` outside the allowed files is flagged.  (The boolean
``.has_overrides`` gate models use to pick scan vs unroll is a distinct
token and stays free.)

This check is folded into the unified static-analysis runner as the
``dispatch-seam`` rule — CI and local runs go through that
(DESIGN.md §13)::

    PYTHONPATH=src python tools/repro_lint.py

Standalone invocation (``python tools/check_dispatch.py``) and the
importable ``check(root) -> list[str]`` / ``check_text(text, relpath)``
remain for scripting; tests/test_datapath.py runs ``check`` in tier-1
and the ``override-branch-outside-seam`` lint fixture goes through
``check_text``.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# ANY attribute named exactly `mode` (covers ==/!=/in, reversed forms,
# .startswith, match statements, dict dispatch — all must spell `.mode`)
ATTR_BRANCH = re.compile(r"\.mode\b")
# bare membership: `mode in (`, not `tp_mode in (` / `exp_mode in (`
BARE_BRANCH = re.compile(r"(?<![\w.])mode\s+(?:not\s+)?in\s*\(")
# per-layer override reads outside the seam (`.has_overrides` is a
# different attribute token and does not match)
OVERRIDE_READ = re.compile(r"\.overrides\b")

ALLOWED = ("src/repro/datapath/", "src/repro/core/mx_types.py")


def check_text(text: str, relpath: str) -> list:
    """Seam problems in one file's source (``relpath`` repo-relative)."""
    if any(relpath.startswith(a) for a in ALLOWED):
        return []
    problems = []
    for i, line in enumerate(text.splitlines(), 1):
        if ATTR_BRANCH.search(line) or BARE_BRANCH.search(line):
            problems.append(
                f"{relpath}:{i} touches a quant mode string outside "
                f"repro/datapath/: {line.strip()!r} — dispatch through "
                f"q.datapath instead (DESIGN.md §12)")
        elif OVERRIDE_READ.search(line):
            problems.append(
                f"{relpath}:{i} reads per-layer overrides outside the "
                f"seam: {line.strip()!r} — resolve through "
                f"q.scoped(scope) / datapath.resolve(q, scope) "
                f"(DESIGN.md §16)")
    return problems


def check(root: Path) -> list:
    problems = []
    for py in sorted((root / "src").rglob("*.py")):
        if "__pycache__" in py.parts:
            continue
        rel = py.relative_to(root).as_posix()
        problems.extend(check_text(py.read_text(), rel))
    return problems


def main() -> int:
    root = Path(__file__).resolve().parents[1]
    problems = check(root)
    for p in problems:
        print(f"check_dispatch: {p}", file=sys.stderr)
    if not problems:
        print("check_dispatch: no mode branching outside repro/datapath/")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
