"""Train state: params + AdamW moments + step, with logical-axes plumbing."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model_api import Param, axes_tree, is_param
from repro.optim.adamw import AdamWState, adamw_init


class TrainState(NamedTuple):
    params: Any                  # Param-wrapped pytree
    opt: AdamWState
    step: jnp.ndarray
    err_fb: Any = None           # gradient-compression error feedback


def make_train_state(model, rng, *, grad_compression: bool = False,
                     n_pods: int = 1) -> TrainState:
    params = model.init(rng)
    opt = adamw_init(params)
    err = None
    if grad_compression:
        # per-pod error-feedback residuals: leading n_pods axis, P('pod')
        err = jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32), err_fb=err)


def abstract_train_state(model, *, grad_compression: bool = False,
                         n_pods: int = 1) -> TrainState:
    """ShapeDtypeStruct version — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: make_train_state(model, jax.random.key(0),
                                 grad_compression=grad_compression,
                                 n_pods=n_pods))


def train_state_axes(state: TrainState) -> TrainState:
    """Logical-axes tree matching the state structure (prefix tree for
    in_shardings)."""
    p_axes = axes_tree(state.params)
    err_axes = None
    if state.err_fb is not None:
        err_axes = jax.tree_util.tree_map(
            lambda p: ("pods",) + tuple(p.axes), state.params,
            is_leaf=is_param)
    return TrainState(
        params=p_axes,
        opt=AdamWState(step=(), mu=p_axes, nu=p_axes),
        step=(),
        err_fb=err_axes,
    )
