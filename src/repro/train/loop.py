"""Training loop with checkpoint/restart, heartbeats and failure recovery.

The loop is host-side orchestration around the jitted train step:

  * periodic atomic checkpoints (params + optimizer + data state);
  * resume-from-latest on startup (crash/preemption recovery) — combined
    with the elastic restore in CheckpointManager this is the
    checkpoint/restart half of fault tolerance;
  * heartbeat file per step — an external watchdog (launcher/k8s) detects
    stragglers/hangs by heartbeat age and restarts the job, which re-enters
    through the resume path;
  * step-time EMA straggler detection — steps slower than
    ``straggler_factor`` x EMA are logged to the metrics stream so a fleet
    scheduler can act (on one host we can only observe, not migrate);
  * metrics JSONL for offline analysis — each logged record carries the
    live ``repro.telemetry`` snapshot (DESIGN.md §15), so the step-time
    histogram and any serving/kernel counters ride in the same stream.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro import telemetry as T


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: str = "checkpoints"
    metrics_path: Optional[str] = None
    heartbeat_path: Optional[str] = None
    straggler_factor: float = 3.0
    keep_last: int = 3


class TrainLoop:
    def __init__(self, *, train_step: Callable, state, data,
                 cfg: LoopConfig, state_shardings=None):
        from repro.train.checkpoint import CheckpointManager
        self.step_fn = train_step
        self.state = state
        self.data = data
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.checkpoint_dir,
                                      keep_last=cfg.keep_last)
        self.state_shardings = state_shardings
        self.metrics: list = []
        self._ema_step_time = None

    # -- fault tolerance ------------------------------------------------------
    def try_resume(self) -> int:
        """Restore the newest committed checkpoint if one exists."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0
        like = jax.eval_shape(lambda: self.state)
        self.state, extra = self.ckpt.restore(
            like, step=latest, shardings=self.state_shardings)
        if "data_state" in extra and hasattr(self.data, "state"):
            from repro.data.pipeline import DataState
            self.data.state = DataState.from_dict(extra["data_state"])
        return latest

    def _heartbeat(self, step: int):
        if self.cfg.heartbeat_path:
            Path(self.cfg.heartbeat_path).write_text(
                json.dumps({"step": step, "time": T.walltime()}))

    def _checkpoint(self, step: int):
        extra = {}
        if hasattr(self.data, "state"):
            extra["data_state"] = self.data.state.to_dict()
        self.ckpt.save(step, self.state, extra=extra)

    # -- main -------------------------------------------------------------------
    def run(self, start_step: Optional[int] = None) -> list:
        step = self.try_resume() if start_step is None else start_step
        cfg = self.cfg
        while step < cfg.total_steps:
            # the span IS the step timer: its histogram feeds the JSONL
            # snapshot and its .elapsed_s feeds the EMA — one clock read,
            # no parallel t0/dt bookkeeping
            with T.span("train/step") as sp:
                batch = self.data.next_batch()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = sp.elapsed_s
            step += 1

            ema = self._ema_step_time
            self._ema_step_time = dt if ema is None else 0.9 * ema + 0.1 * dt
            straggler = (ema is not None and
                         dt > cfg.straggler_factor * ema)

            self._heartbeat(step)
            if step % cfg.log_every == 0 or straggler or \
                    step == cfg.total_steps:
                rec = {"step": step,
                       "loss": float(np.asarray(metrics["loss"])),
                       "grad_norm": float(np.asarray(metrics["grad_norm"])),
                       "lr": float(np.asarray(metrics["lr"])),
                       "step_time_s": round(dt, 4),
                       "straggler": bool(straggler),
                       "telemetry": T.snapshot()}
                self.metrics.append(rec)
                if cfg.metrics_path:
                    with open(cfg.metrics_path, "a") as f:
                        f.write(json.dumps(rec) + "\n")
            if step % cfg.checkpoint_every == 0 or step == cfg.total_steps:
                self._checkpoint(step)
        return self.metrics
