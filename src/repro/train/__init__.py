from repro.train.state import TrainState, make_train_state, train_state_axes
from repro.train.step import make_train_step, make_eval_step
