"""Fault-tolerant checkpointing: atomic, sharded, resumable, elastic.

Layout (one directory per step):

    <root>/step_000420.tmp/      # written first
        manifest.json            # tree structure, dtypes, logical axes,
                                 # data-pipeline state, mesh shape
        shard_00000.npz          # leaf arrays (this host's slice)
    <root>/step_000420/          # atomic rename commits the checkpoint
    <root>/LATEST                # text file with the newest committed step

Fault-tolerance properties:
  * atomicity — a crash mid-write leaves only a .tmp dir, never a corrupt
    committed checkpoint; restore() ignores .tmp dirs;
  * resumable data — DataState rides in the manifest so the token stream
    resumes exactly;
  * elastic restore — arrays are saved UNSHARDED-logical (gathered values)
    with their logical axes; restore re-shards onto whatever mesh the new
    job brings up (different pod count / device count), which is the
    checkpoint half of elastic scaling;
  * retention — keep_last bounds disk usage; LATEST is written last.

On a real fleet each host writes only its addressable shards; on this
single-process container the gather is the identity.  The wire format is
plain npz + json — no pickle, robust across versions.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as T
from repro.models.model_api import Param, is_param
from repro.core.quantize import MXTensor

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


@dataclasses.dataclass
class CheckpointManager:
    root: str
    keep_last: int = 3

    def __post_init__(self):
        Path(self.root).mkdir(parents=True, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, *, extra: Optional[Dict] = None):
        root = Path(self.root)
        tmp = root / f"step_{step:06d}.tmp"
        final = root / f"step_{step:06d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        flat, _ = _flatten_with_paths(state)
        arrays = {}
        manifest_leaves = []
        for i, (path, leaf) in enumerate(flat):
            key = f"leaf_{i:05d}"
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
            manifest_leaves.append({
                "key": key, "path": _path_str(path),
                "dtype": str(arr.dtype), "shape": list(arr.shape),
            })
        np.savez(tmp / "shard_00000.npz", **arrays)
        manifest = {
            "step": step,
            "time": T.walltime(),
            "leaves": manifest_leaves,
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        os.replace(tmp, final)                     # atomic commit
        (root / "LATEST").write_text(str(step))
        self._gc()
        return str(final)

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        root = Path(self.root)
        steps = []
        for d in root.iterdir() if root.exists() else []:
            m = _STEP_RE.match(d.name)
            if m and d.is_dir():
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like`` (a state pytree or its
        eval_shape).  ``shardings``: optional matching tree of NamedShardings
        for elastic re-sharding onto the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = Path(self.root) / f"step_{step:06d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "shard_00000.npz")

        flat, treedef = _flatten_with_paths(like)
        by_path = {l["path"]: l for l in manifest["leaves"]}
        leaves = []
        if shardings is not None:
            sh_flat, _ = _flatten_with_paths(shardings)
            sh_by_path = {_path_str(p): s for p, s in sh_flat}
        else:
            sh_by_path = {}
        for path, leaf in flat:
            ps = _path_str(path)
            if ps not in by_path:
                raise KeyError(f"checkpoint missing leaf {ps}")
            arr = data[by_path[ps]["key"]]
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            sh = sh_by_path.get(ps)
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jnp.asarray(arr))
        state = jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
        return state, manifest.get("extra", {})

    # -- retention -----------------------------------------------------------
    def _gc(self):
        root = Path(self.root)
        steps = sorted(
            int(_STEP_RE.match(d.name).group(1))
            for d in root.iterdir()
            if d.is_dir() and _STEP_RE.match(d.name))
        for s in steps[:-self.keep_last] if self.keep_last > 0 else []:
            shutil.rmtree(root / f"step_{s:06d}", ignore_errors=True)
        # clean stale tmp dirs (crashed writers)
        for d in root.glob("step_*.tmp"):
            shutil.rmtree(d, ignore_errors=True)
