"""Train / eval step builders.

make_train_step returns a pure (state, batch) -> (state, metrics) function
suitable for jit with in/out shardings.  Options:

  * microbatches=N      — gradient accumulation via lax.scan over N slices
                          of the global batch (activation memory / N).
  * grad_compression    — MXInt-compress the *pod-axis* gradient reduction
                          (beyond-paper; DESIGN.md §3).  Implemented with
                          jax.shard_map manual over the 'pod' axis only;
                          'data'/'model' stay in GSPMD auto mode, so TP and
                          intra-pod DP sharding propagate as usual while the
                          inter-pod wire format is int8 mantissa + shared
                          exponents with error feedback.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import gradient_compression as gc
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.train.state import TrainState


def _microbatch_value_and_grad(loss_fn, params, batch, n_micro: int):
    """Accumulate grads over n_micro slices of the leading batch dim."""
    def slice_batch(b, i):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(
                x, i * (x.shape[0] // n_micro), x.shape[0] // n_micro, 0), b)

    def body(carry, i):
        loss_acc, grad_acc = carry
        mb = slice_batch(batch, i)
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        grad_acc = jax.tree_util.tree_map(jnp.add, grad_acc, grads)
        return (loss_acc + loss, grad_acc), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), jnp.arange(n_micro))
    scale = 1.0 / n_micro
    return loss_sum * scale, jax.tree_util.tree_map(
        lambda g: g * scale, grads)


def make_train_step(model, *, lr_fn: Callable, opt_cfg: AdamWConfig = None,
                    microbatches: int = 1,
                    grad_compression: bool = False,
                    mesh=None) -> Callable:
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, batch):
        return model.loss(params, batch).astype(jnp.float32)

    def _compute_grads(params, batch):
        if microbatches > 1:
            return _microbatch_value_and_grad(loss_fn, params, batch,
                                              microbatches)
        return jax.value_and_grad(loss_fn)(params, batch)

    use_compression = (grad_compression and mesh is not None
                       and "pod" in mesh.axis_names)

    def train_step(state: TrainState, batch) -> tuple:
        if use_compression:
            loss, grads, err_fb = _pod_compressed_grads(
                _compute_grads, state.params, batch, state.err_fb, mesh)
        else:
            loss, grads = _compute_grads(state.params, batch)
            err_fb = state.err_fb
        lr = lr_fn(state.step)
        new_params, new_opt, gnorm = adamw_update(
            grads, state.opt, state.params, lr, opt_cfg)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": state.step}
        return TrainState(new_params, new_opt, state.step + 1,
                          err_fb), metrics

    return train_step


def _pod_compressed_grads(compute_grads, params, batch, err_fb, mesh):
    """Per-pod gradients + MXInt-compressed mean over the 'pod' axis.

    Only 'pod' is manual; 'data'/'model' stay GSPMD-auto, so intra-pod DP
    and TP sharding propagate as usual.  Error-feedback residuals carry a
    leading n_pods axis (sharded P('pod')) — each pod keeps its own
    residual, the EF-SGD requirement.
    """
    from jax.sharding import PartitionSpec as P
    n_pods = mesh.shape["pod"]

    def per_pod(p, pod_batch, pod_err):
        err = jax.tree_util.tree_map(lambda e: e[0], pod_err)
        loss, grads = compute_grads(p, pod_batch)
        red, new_err = gc.compressed_psum(grads, "pod", err)
        grads = jax.tree_util.tree_map(lambda g: g / n_pods, red)
        loss = jax.lax.pmean(loss, "pod")
        return loss, grads, jax.tree_util.tree_map(
            lambda e: e[None], new_err)

    in_specs = (P(), P("pod"), P("pod"))
    out_specs = (P(), P(), P("pod"))
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        mapped = sm(
            per_pod, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names={"pod"},
            # scan carries inside the model init as pod-unvarying zeros
            # while their outputs vary with the pod-local batch; skip the
            # VMA check (the explicit psum makes the reduction correct by
            # construction)
            check_vma=False)
    else:
        # pre-0.5 jax: jax.experimental.shard_map with 'auto' for the
        # GSPMD axes (manual over 'pod' only) and check_rep as the VMA
        # check's predecessor
        # NOTE: partial-manual (auto={data, model}) trips an XLA CHECK in
        # the jaxlib this pin ships (hlo_sharding_util IsManualSubgroup),
        # so the legacy path runs fully manual: the pod axis is split, the
        # intra-pod axes see replicated pod-local arrays.  The wire format
        # and reduction math are identical; only intra-pod GSPMD layout
        # differs from the modern path.
        from jax.experimental.shard_map import shard_map as _legacy_sm
        mapped = _legacy_sm(
            per_pod, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False)
    return mapped(params, batch, err_fb)


def make_eval_step(model) -> Callable:
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step
