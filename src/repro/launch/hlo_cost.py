"""While-aware HLO cost model parsed from compiled.as_text().

XLA's ``compiled.cost_analysis()`` counts a `while` body ONCE, so scanned
models (scan-over-layers, chunked attention, microbatching) under-report
FLOPs and bytes by the trip count.  This module re-derives:

  * FLOPs — from `dot` ops (2 * prod(out_dims) * prod(contracting_dims)),
    which dominate transformer compute; found in top-level computations AND
    inside fusion sub-computations;
  * bytes — operand + output bytes at FUSION BOUNDARIES (top-level ops
    only: fusion/dot/copy/collective/custom-call/dynamic-slice...), the
    HBM-traffic proxy XLA's own memory model uses;
  * collective bytes — by kind;

all scaled by while-loop trip counts recovered from the canonical
counted-loop condition (`compare(iv, constant(N))`).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
# first lowercase-word token followed by '(' after the shape is the op kind;
# tuple shapes may contain '/*index=N*/' comments and layouts may contain
# 'T(8,128)' tiles (uppercase, excluded)
_KIND_RE = re.compile(r"\s([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_WHILE_ATTRS = re.compile(r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_B = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

# top-level ops whose boundary bytes count as traffic
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "conditional", "call", "after-all",
             "add-dependency", "partition-id", "replica-id", "iota",
             "opt-barrier"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    """Dims of the first (only) array shape in the string."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    kind: str
    line: str
    args: str = ""


def _parse_computations(hlo: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    current = None
    for line in hlo.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            # column-0 lines: module header, computation headers (possibly
            # wrapping over several lines), or the closing brace
            s = line.strip()
            if s == "}":
                current = None
            elif s.startswith(("%", "ENTRY")) and "(" in s:
                head = s.replace("ENTRY", "").strip()
                head = head.split("(", 1)[0].strip().lstrip("%")
                if head:
                    current = head
                    comps[current] = []
            continue
        if current is None:
            continue
        nm = _NAME_RE.match(line)
        if not nm:
            continue
        rest = line[nm.end():]
        km = _KIND_RE.search(" " + rest)
        if not km:
            continue
        shape = rest[:km.start() - 1].strip()
        args = rest[km.end() - 1:].split(")", 1)[0]
        comps[current].append(_Op(nm.group(1), shape, km.group(1),
                                  line.strip(), args))
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    return m.group(1) if m else None


def _trip_count(cond_ops: List[_Op]) -> Optional[int]:
    consts = []
    for op in cond_ops:
        if "compare" in op.line or "constant" in op.line:
            consts += [int(c) for c in _CONST_RE.findall(op.line)]
    return max(consts) if consts else None


def _dot_flops(op: _Op, symtab: Dict[str, str]) -> float:
    out_dims = _shape_dims(op.shape)
    out_n = 1
    for d in out_dims:
        out_n *= d
    # contracting dims from the lhs operand's shape
    cm = _LHS_C.search(op.line)
    if not cm:
        return 2.0 * out_n          # degenerate
    # first operand = lhs
    ops = _OPERAND_RE.findall(op.args)
    k = 1
    if ops:
        lhs_shape = symtab.get(ops[0], "")
        lhs_dims = _shape_dims(lhs_shape)
        for idx in (int(i) for i in cm.group(1).split(",") if i):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    return 2.0 * out_n * k


@dataclasses.dataclass
class ProgramCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    unknown_trip_counts: int = 0
    n_while_loops: int = 0


def _sliced_params(comps, callee) -> Dict[int, int]:
    """Parameter indices of ``callee`` that are only read through a
    (dynamic-)slice inside the fused computation, mapped to the slice's
    output bytes — those operands contribute slice-sized traffic, not their
    full (e.g. whole stacked-cache-carry) size."""
    ops = comps.get(callee)
    if not ops:
        return {}
    # param name -> index; include single-level bitcast/reshape aliases
    param_idx: Dict[str, int] = {}
    for op in ops:
        if op.kind == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.line)
            if m:
                param_idx[op.name] = int(m.group(1))
    alias = dict(param_idx)
    for op in ops:
        if op.kind in ("bitcast", "reshape", "copy"):
            srcs = _OPERAND_RE.findall(op.args)
            if srcs and srcs[0] in alias:
                alias[op.name] = alias[srcs[0]]
    sliced: Dict[int, int] = {}
    direct_use: Dict[int, bool] = {}
    for op in ops:
        refs = [alias[o] for o in _OPERAND_RE.findall(op.args)
                if o in alias]
        if op.kind in ("dynamic-slice", "slice"):
            for idx in refs:
                b = _shape_bytes(op.shape)
                sliced[idx] = max(sliced.get(idx, 0), b)
        elif op.kind not in ("bitcast", "reshape", "copy", "parameter"):
            for idx in refs:
                direct_use[idx] = True
    # a param consumed anywhere else at full size is NOT capped
    return {i: b for i, b in sliced.items() if not direct_use.get(i)}


def parse_program_costs(hlo: str) -> ProgramCost:
    comps = _parse_computations(hlo)
    entry = _entry_name(hlo)
    cost = ProgramCost()
    symtabs: Dict[str, Dict[str, str]] = {
        c: {op.name: op.shape for op in ops} for c, ops in comps.items()}

    def visit(comp: str, mult: float, count_bytes: bool,
              stack: Tuple[str, ...] = ()):
        if comp not in comps or comp in stack:
            return
        symtab = symtabs[comp]
        for op in comps[comp]:
            kind = op.kind
            # -- control flow ------------------------------------------------
            if kind == "while":
                wm = _WHILE_ATTRS.search(op.line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trips = _trip_count(comps.get(cond, []))
                    if trips is None:
                        trips = 1
                        cost.unknown_trip_counts += 1
                    cost.n_while_loops += 1
                    visit(body, mult * trips, count_bytes, stack + (comp,))
                continue
            if kind in ("call", "conditional"):
                for callee in _CALLS_RE.findall(op.line):
                    visit(callee, mult, count_bytes, stack + (comp,))
                continue
            if kind == "fusion":
                cm = _CALLS_RE.search(op.line)
                if cm:
                    # flops inside fusions count; bytes only at the boundary
                    visit(cm.group(1), mult, False, stack + (comp,))
            # -- flops ----------------------------------------------------------
            if kind == "dot":
                cost.flops += _dot_flops(op, symtab) * mult
            # -- collectives ------------------------------------------------------
            base_kind = kind[:-6] if kind.endswith("-start") else kind
            if base_kind in _COLLECTIVES:
                b = _shape_bytes(op.shape)
                cost.collective_by_kind[base_kind] += b * mult
                cost.collective_counts[base_kind] += 1
                cost.collective_bytes += b * mult
            # -- boundary bytes ---------------------------------------------------
            if count_bytes and kind not in _FREE_OPS and \
                    not kind.endswith("-done"):
                if kind == "dynamic-update-slice":
                    # XLA updates the buffer in place (aliased); traffic is
                    # the update region read + written, not the whole buffer
                    operands = _OPERAND_RE.findall(op.args)
                    upd = symtab.get(operands[1], "") if len(operands) > 1 \
                        else ""
                    cost.bytes += 2.0 * _shape_bytes(upd) * mult
                    continue
                out_b = _shape_bytes(op.shape)
                if kind in ("dynamic-slice", "slice", "gather"):
                    # reads only the selected region ~= output bytes
                    cost.bytes += 2.0 * out_b * mult
                    continue
                operands = _OPERAND_RE.findall(op.args)
                if kind == "fusion":
                    cm = _CALLS_RE.search(op.line)
                    callee = cm.group(1) if cm else None
                    if "update-slice" in op.name:
                        # fused in-place DUS: the aliased buffer (and any
                        # dtype-normalization echoes of it that the CPU
                        # backend materializes) is not traffic; the real
                        # cost is the update region read + written.  The
                        # update is the largest operand clearly smaller
                        # than the buffer.
                        sizes = [_shape_bytes(symtab.get(o, ""))
                                 for o in operands]
                        small = [s for s in sizes if s < out_b / 2]
                        if small:
                            cost.bytes += 2.0 * max(small) * mult
                        continue
                    operand_b = 0
                    sliced = _sliced_params(comps, callee) \
                        if callee else {}
                    for i, operand in enumerate(operands):
                        ob = _shape_bytes(symtab.get(operand, ""))
                        if i in sliced:
                            ob = min(ob, 2 * sliced[i])
                        operand_b += ob
                    cost.bytes += (out_b + operand_b) * mult
                    continue
                operand_b = sum(_shape_bytes(symtab.get(o, ""))
                                for o in operands)
                cost.bytes += (out_b + operand_b) * mult

    if entry:
        visit(entry, 1.0, True)
    return cost
