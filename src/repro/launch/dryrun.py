import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The first two lines force 512 host platform devices BEFORE any jax import so
``make_production_mesh`` can build the 16x16 single-pod and 2x16x16
multi-pod meshes.  Never import this module from tests — run it as a
subprocess (`python -m repro.launch.dryrun ...`).

Per cell the dry-run:
  1. builds ShapeDtypeStruct inputs (launch.specs) — zero allocation;
  2. jits the train/prefill/decode step with NamedShardings derived from
     the Param logical axes (parallel.sharding);
  3. .lower().compile() — success proves the sharding config is coherent;
  4. records memory_analysis / cost_analysis / parsed collective bytes and
     the three roofline terms to a JSON artifact in experiments/dryrun/.

Serve cells run twice: weights in bf16 (float baseline) and packed MXInt
(the paper's format) — the Fig-10 comparison at cluster scale.
"""
import argparse
import dataclasses
import json
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import telemetry as T
from repro.configs import ARCH_IDS, full_config, shape_supported, skip_reason
from repro.launch import hlo_analysis, specs as S
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.models import build_model, shape_by_name, ALL_SHAPES
from repro.models.model_api import axes_tree
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import (ShardingRules, logical_to_pspec,
                                     named_sharding_tree)
from repro.serving.engine import (make_decode_step, make_prefill_step,
                                  pack_params_mxint)
from repro.train.state import abstract_train_state, train_state_axes
from repro.train.step import make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _axes_leaf(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def _leaf_shape(val):
    """Shape of the value paired with an axes leaf: Param -> its value;
    MXTensor -> the mantissa plane (the exponent shares the spec)."""
    from repro.models.model_api import Param
    from repro.core.quantize import MXTensor
    if isinstance(val, Param):
        val = val.value
    if isinstance(val, MXTensor):
        val = val.mantissa
    return getattr(val, "shape", None)


def shardings_for(axes_pytree, rules: ShardingRules, mesh,
                  values_pytree=None):
    names = mesh.axis_names
    mesh_shape = dict(mesh.shape)

    def one(axes, val=None):
        shape = _leaf_shape(val) if val is not None else None
        return NamedSharding(mesh, logical_to_pspec(
            axes, rules, names, shape=shape, mesh_shape=mesh_shape))

    if values_pytree is None:
        return jax.tree_util.tree_map(one, axes_pytree, is_leaf=_axes_leaf)
    from repro.models.model_api import Param
    return jax.tree_util.tree_map(
        one, axes_pytree, values_pytree, is_leaf=_axes_leaf)


def _result(ok, mesh_name, arch, shape, kind, variant, extra=None,
            error=None, seconds=None):
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "kind": kind,
           "variant": variant, "ok": ok, "compile_seconds": seconds}
    if extra:
        rec.update(extra)
    if error:
        rec["error"] = error
    return rec


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             rules: ShardingRules, variant: str = "bf16",
             grad_compression: bool = False,
             microbatches: int = 1):
    cfg = full_config(arch)
    shape = shape_by_name(shape_name)
    model = build_model(cfg)
    n_dev = mesh.size
    if shape.kind == "decode" and shape.global_batch < (
            mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)):
        # long-context decode at batch 1: no batch DP possible — switch to
        # sequence-parallel KV (ring/local caches shard their seq dim over
        # 'data') and replicate the batch dim.
        rules = dataclasses.replace(rules, batch=None, kv_seq="data")
    # one span per cell compile: the wall-clock lands in the
    # span/dryrun/compile/ms histogram AND in this cell's record
    with T.span("dryrun/compile", devices=mesh.size) as sp:
        if shape.kind == "train":
            state = abstract_train_state(
                model, grad_compression=grad_compression,
                n_pods=mesh.shape.get("pod", 1))
            st_axes = train_state_axes(state)
            st_sh = shardings_for(st_axes, rules, mesh, state)
            batch, b_axes = S.batch_specs(cfg, shape, "train")
            b_sh = shardings_for(b_axes, rules, mesh, batch)
            step = make_train_step(
                model, lr_fn=lambda s: jnp.asarray(1e-4, jnp.float32),
                opt_cfg=AdamWConfig(), microbatches=microbatches,
                grad_compression=grad_compression, mesh=mesh)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None),
                             donate_argnums=(0,))
            with mesh_context(mesh):
                lowered = jitted.lower(state, batch)
                compiled = lowered.compile()
        else:
            params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
            if variant == "mxint":
                from repro.core.mx_types import MXINT6_WEIGHT
                params = pack_params_mxint(
                    params, MXINT6_WEIGHT, abstract=True,
                    tp_shards=mesh.shape.get("model", 1))
            p_sh = shardings_for(axes_tree(params), rules, mesh, params)
            cache = S.decode_cache_specs(model, shape)
            c_sh = shardings_for(S.decode_cache_axes(model), rules, mesh,
                                 cache)
            if shape.kind == "prefill":
                batch, b_axes = S.batch_specs(cfg, shape, "prefill")
                b_sh = shardings_for(b_axes, rules, mesh, batch)
                step = make_prefill_step(model)
                jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                                 out_shardings=(None, c_sh),
                                 donate_argnums=(2,))
                with mesh_context(mesh):
                    lowered = jitted.lower(params, batch, cache)
                    compiled = lowered.compile()
            else:
                batch, b_axes = S.batch_specs(cfg, shape, "decode")
                tok_sh = shardings_for(b_axes, rules, mesh, batch)["tokens"]
                step = make_decode_step(model)
                jitted = jax.jit(step, in_shardings=(p_sh, tok_sh, c_sh),
                                 out_shardings=(tok_sh, c_sh),
                                 donate_argnums=(2,))
                with mesh_context(mesh):
                    lowered = jitted.lower(params, batch["tokens"], cache)
                    compiled = lowered.compile()

    seconds = sp.elapsed_s
    if os.environ.get("REPRO_DUMP_HLO"):
        import gzip
        dump = (OUT_DIR.parent / "hlo" /
                f"{arch}.{shape_name}.{mesh_name}.{variant}.hlo.gz")
        dump.parent.mkdir(parents=True, exist_ok=True)
        with gzip.open(dump, "wt") as fh:
            fh.write(compiled.as_text())
    mf = hlo_analysis.model_flops_estimate(cfg, shape, n_dev)
    roof = hlo_analysis.roofline_from_compiled(compiled, model_flops=mf)
    ma = compiled.memory_analysis()
    extra = {
        "roofline": roof.as_dict(),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            # donated caches/state alias their outputs; peak ~= args + temps
            "peak_device_bytes": (ma.argument_size_in_bytes +
                                  ma.temp_size_in_bytes),
            "total_device_bytes": (ma.argument_size_in_bytes +
                                   ma.output_size_in_bytes +
                                   ma.temp_size_in_bytes),
        },
        "n_devices": n_dev,
    }
    del compiled, lowered
    return extra, seconds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both", "tiny_single",
                             "tiny_multi"],
                    help="tiny_* use a 2x2 / 2x2x2 mesh for CI-scale "
                         "machinery tests (set REPRO_XLA_FLAGS to force a "
                         "small device count)")
    ap.add_argument("--variant", default="auto",
                    help="bf16 | mxint | auto (serve cells run both)")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--rules", default="",
                    help="comma list rule=axis overrides, e.g. "
                         "fsdp=data,kv_seq=data")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    rules = ShardingRules()
    if args.rules:
        overrides = {}
        for kv in args.rules.split(","):
            k, _, v = kv.partition("=")
            overrides[k.strip()] = (None if v in ("", "None", "none")
                                    else v.strip())
        rules = dataclasses.replace(rules, **overrides)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if args.shape == "all" \
        else [args.shape]
    from repro.launch.mesh import make_test_mesh
    mesh_builders = {
        "single": [("single_16x16",
                    lambda: make_production_mesh(multi_pod=False))],
        "multi": [("multi_2x16x16",
                   lambda: make_production_mesh(multi_pod=True))],
        "both": [("single_16x16",
                  lambda: make_production_mesh(multi_pod=False)),
                 ("multi_2x16x16",
                  lambda: make_production_mesh(multi_pod=True))],
        "tiny_single": [("tiny_2x2",
                         lambda: make_test_mesh((2, 2),
                                                ("data", "model")))],
        "tiny_multi": [("tiny_2x2x2",
                        lambda: make_test_mesh((2, 2, 2),
                                               ("pod", "data", "model")))],
    }[args.mesh]

    results = []
    failures = 0
    for mesh_name, builder in mesh_builders:
        mesh = builder()
        for arch in archs:
            for shape_name in shapes:
                if not shape_supported(arch, shape_name):
                    results.append(_result(
                        True, mesh_name, arch, shape_name, "skip", "-",
                        extra={"skipped": True,
                               "reason": skip_reason(arch, shape_name)}))
                    continue
                kind = shape_by_name(shape_name).kind
                if args.variant != "auto":
                    variants = [args.variant]
                else:
                    variants = ["bf16"] if kind == "train" \
                        else ["bf16", "mxint"]
                for variant in variants:
                    tag = f"{arch}.{shape_name}.{mesh_name}.{variant}"
                    try:
                        extra, secs = run_cell(
                            arch, shape_name, mesh, mesh_name, rules,
                            variant=variant,
                            grad_compression=args.grad_compression,
                            microbatches=args.microbatches)
                        rec = _result(True, mesh_name, arch, shape_name,
                                      kind, variant, extra=extra,
                                      seconds=round(secs, 2))
                        print(f"[ok]   {tag}  compile={secs:.1f}s "
                              f"bottleneck={extra['roofline']['bottleneck']}",
                              flush=True)
                    except Exception:
                        failures += 1
                        rec = _result(False, mesh_name, arch, shape_name,
                                      kind, variant,
                                      error=traceback.format_exc())
                        print(f"[FAIL] {tag}", flush=True)
                        print(traceback.format_exc()[-2000:], flush=True)
                    results.append(rec)
                    fname = out_dir / (tag + (f".{args.tag}" if args.tag
                                              else "") + ".json")
                    fname.write_text(json.dumps(rec, indent=1))

    n_spans, mean_ms = T.span_stats("dryrun/compile")
    summary = {
        "cells": len(results),
        "failures": failures,
        "ok": failures == 0,
        "compile_spans": {"count": n_spans,
                          "mean_ms": round(mean_ms, 1)},
    }
    suffix = f".{args.tag}" if args.tag else ""
    (out_dir / f"summary.{args.mesh}.{args.arch}.{args.shape}{suffix}.json"
     ).write_text(json.dumps({"summary": summary, "results": results},
                             indent=1))
    print(json.dumps(summary))
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
