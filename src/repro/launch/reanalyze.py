"""Re-run the HLO cost parser over dumped .hlo.gz artifacts (no recompile).

Usage: PYTHONPATH=src python -m repro.launch.reanalyze [pattern]
Prints the refreshed roofline terms per dump.
"""
import gzip
import sys
from pathlib import Path

from repro.core.mx_types import PEAK_FLOPS_BF16, HBM_BW, ICI_BW
from repro.launch.hlo_cost import parse_program_costs

HLO_DIR = Path(__file__).resolve().parents[3] / "experiments" / "hlo"


def main():
    pattern = sys.argv[1] if len(sys.argv) > 1 else "*"
    for f in sorted(HLO_DIR.glob(f"{pattern}.hlo.gz")):
        txt = gzip.open(f, "rt").read()
        c = parse_program_costs(txt)
        comp = c.flops / PEAK_FLOPS_BF16
        mem = c.bytes / HBM_BW
        coll = c.collective_bytes / ICI_BW
        terms = {"compute": comp, "memory": mem, "collective": coll}
        bound = max(terms, key=terms.get)
        print(f"{f.name[:-7]}: compute={comp*1e3:.3f}ms "
              f"memory={mem*1e3:.3f}ms collective={coll*1e3:.3f}ms "
              f"bound={bound} unknown_trips={c.unknown_trip_counts}")


if __name__ == "__main__":
    main()
