"""Production mesh construction.

Importing this module never touches jax device state; meshes are built by
FUNCTIONS so the dry-run controls XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale integration tests (needs forced host devices
    >= prod(shape))."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-device mesh (plain CPU runs)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
