"""Production mesh construction.

Importing this module never touches jax device state; meshes are built by
FUNCTIONS so the dry-run controls XLA_FLAGS before first jax init.

``jax.sharding.AxisType`` only exists from jax 0.5; on older jax the
explicit-sharding axis types simply don't apply, so the shim below passes
``axis_types`` only when the running jax supports it.
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """axis_types kwarg when this jax has AxisType; empty dict otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def _make_mesh(shape, axes):
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))
    # very old jax: build the device mesh by hand
    from jax.experimental import mesh_utils
    devices = mesh_utils.create_device_mesh(shape)
    return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale integration tests (needs forced host devices
    >= prod(shape))."""
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh (plain CPU runs)."""
    return _make_mesh((1, 1), ("data", "model"))


def make_tp_mesh(n_shards: int):
    """1-D ("model",) mesh for tensor-parallel serving (DESIGN.md §10).

    Uses the first ``n_shards`` visible devices.  On CPU, force host
    devices first: ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (must be set before jax initializes its backend — see
    repro.serving.sharded_check for the pattern).
    """
    import jax as _jax
    n_dev = _jax.device_count()
    if n_dev < n_shards:
        raise ValueError(
            f"make_tp_mesh({n_shards}) needs {n_shards} devices, have "
            f"{n_dev}; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before the "
            "first jax call")
    return _make_mesh((n_shards,), ("model",))


def make_serving_mesh(dp: int, tp: int):
    """("data", "model") mesh for sharded serving: batch rows over ``dp``
    data shards, packed weight planes over ``tp`` model shards
    (DESIGN.md §10).  Needs ``dp * tp`` visible devices (on CPU force
    host devices first — see ``make_tp_mesh``)."""
    import jax as _jax
    need = dp * tp
    n_dev = _jax.device_count()
    if n_dev < need:
        raise ValueError(
            f"make_serving_mesh(dp={dp}, tp={tp}) needs {need} devices, "
            f"have {n_dev}; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before the "
            "first jax call")
    return _make_mesh((dp, tp), ("data", "model"))


def mesh_context(mesh):
    """Ambient-mesh context manager across jax versions.

    ``jax.set_mesh`` is the modern entry point; on older jax the Mesh
    object itself is the (legacy thread-resources) context manager.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh
