"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

Weak-type-correct, shardable, zero-allocation stand-ins for:
  train  — {'tokens': (B, S) i32} (+ vision_embeds / frames for stub
           frontends)
  prefill— same token layout at the prefill batch/seq
  decode — {'tokens': (B, 1) i32} + the KV/recurrent cache tree at S

plus the logical sharding axes of each input.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_api import ModelConfig, ShapeConfig


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                kind: str) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (specs, logical_axes) for the step input batch."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        axes = {"tokens": ("batch", None)}
        if cfg.vision_tokens:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.vision_dim), cfg.dtype)
            axes["vision_embeds"] = ("batch", None, None)
        if cfg.is_encoder_decoder:
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   cfg.dtype)
            axes["frames"] = ("batch", "seq", None)
        return specs, axes
    if kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        axes = {"tokens": ("batch", None)}
        if cfg.vision_tokens:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.vision_dim), cfg.dtype)
            axes["vision_embeds"] = ("batch", None, None)
        if cfg.is_encoder_decoder:
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   cfg.dtype)
            axes["frames"] = ("batch", "seq", None)
        return specs, axes
    if kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        axes = {"tokens": ("batch", None)}
        return specs, axes
    raise ValueError(kind)


def decode_cache_specs(model, shape: ShapeConfig):
    """Abstract cache tree for a decode cell (cache filled to seq_len)."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    cache = model.cache_init(B, S, abstract=True)
    if cfg.is_encoder_decoder:
        # enc-dec decode also carries the cross K/V from an S-frame prompt
        kvh, hd = cfg.n_kv_heads, cfg.hd
        kv = jax.ShapeDtypeStruct((cfg.n_layers, B, S, kvh, hd), cfg.dtype)
        cache = dict(cache)
        cache["enc_kv"] = (kv, kv)
    return cache


def decode_cache_axes(model):
    cfg = model.cfg
    if cfg.is_encoder_decoder:
        ca = ("layers", "batch", "kv_seq", "kv_heads", None)
        from repro.models.attention import CACHE_AXES
        self_axes = jax.tree_util.tree_map(
            lambda a: ("layers",) + tuple(a),
            {"k": CACHE_AXES, "v": CACHE_AXES},
            is_leaf=lambda x: isinstance(x, tuple) and all(
                y is None or isinstance(y, str) for y in x))
        return {"self": self_axes, "index": (), "enc_kv": (ca, ca)}
    return model.cache_axes()
