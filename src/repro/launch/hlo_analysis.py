"""Post-compile HLO analysis: collective bytes + roofline terms.

collective_bytes is not in cost_analysis(), so we parse compiled.as_text():
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op contributes its output-shape bytes.  Collectives that
live inside `while` bodies (scan-over-layers, chunked attention, microbatch
accumulation) execute trip_count times; the trip count is recovered from the
canonical counted-loop condition (`compare(iv, constant(N)), direction=LT`)
— best-effort, falling back to 1 with a warning flag.

Roofline terms (TPU v5e constants from repro.core.mx_types), using the
PER-DEVICE numbers XLA reports for the partitioned module:

  compute_s    = device_flops / peak_flops
  memory_s     = device_bytes / hbm_bw
  collective_s = device_collective_bytes / ici_bw
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core.mx_types import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_WHILE_RE = re.compile(
    r"=\s+\S+\s+while\(.*?condition=%?([\w.\-]+),.*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    unknown_trip_counts: int = 0

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its op lines."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", stripped)
        if m and stripped.endswith("{"):
            current = m.group(1)
            comps[current] = []
            continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    return m.group(1) if m else None


def _trip_count(cond_lines: List[str]) -> Optional[int]:
    """Counted loops compare the induction variable against a constant."""
    consts = []
    for ln in cond_lines:
        if "compare" in ln or "constant" in ln:
            consts += [int(c) for c in _CONST_RE.findall(ln)]
    return max(consts) if consts else None


def collect_collectives(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    stats = CollectiveStats(bytes_by_kind={k: 0.0 for k in _COLLECTIVES},
                            count_by_kind={k: 0 for k in _COLLECTIVES})

    def visit(comp: str, mult: float, seen: Tuple[str, ...] = ()):
        if comp not in comps or comp in seen:
            return
        for ln in comps[comp]:
            m = _OP_RE.search(ln)
            if m:
                shape_str, kind = m.group(1), m.group(2)
                stats.bytes_by_kind[kind] += _shape_bytes(shape_str) * mult
                stats.count_by_kind[kind] += 1
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                if trips is None:
                    trips = 1
                    stats.unknown_trip_counts += 1
                visit(body, mult * trips, seen + (comp,))
                continue
            cm = _CALL_RE.search(ln)
            if cm and ("call(" in ln or "fusion(" in ln or
                       "conditional(" in ln):
                visit(cm.group(1), mult, seen + (comp,))

    if entry:
        visit(entry, 1.0)
    else:   # fallback: flat scan
        for ln in hlo.splitlines():
            m = _OP_RE.search(ln)
            if m:
                stats.bytes_by_kind[m.group(2)] += _shape_bytes(m.group(1))
                stats.count_by_kind[m.group(2)] += 1
    return stats


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Roofline:
    device_flops: float
    device_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None
    useful_flops_ratio: Optional[float] = None
    per_device_hbm_bytes: Optional[float] = None
    unknown_trip_counts: int = 0
    xla_flops: float = 0.0          # raw cost_analysis (scan bodies x1)
    xla_bytes: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_from_compiled(compiled, *, model_flops: Optional[float] = None,
                           peak_flops: float = PEAK_FLOPS_BF16,
                           hbm_bw: float = HBM_BW,
                           ici_bw: float = ICI_BW) -> Roofline:
    """Three-term roofline from the while-aware HLO cost parser
    (launch.hlo_cost); XLA's own cost_analysis() under-counts scan bodies
    and is kept only as a cross-check in xla_flops/xla_bytes."""
    from repro.launch.hlo_cost import parse_program_costs
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        # older jax returns a one-entry list of per-device dicts
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    pc = parse_program_costs(hlo)
    flops = pc.flops
    byts = pc.bytes
    colls = CollectiveStats(bytes_by_kind=dict(pc.collective_by_kind),
                            count_by_kind=dict(pc.collective_counts),
                            unknown_trip_counts=pc.unknown_trip_counts)
    ma = compiled.memory_analysis()
    hbm = None
    if ma is not None:
        hbm = (ma.argument_size_in_bytes + ma.output_size_in_bytes +
               ma.temp_size_in_bytes)
    compute_s = flops / peak_flops
    memory_s = byts / hbm_bw
    collective_s = colls.total_bytes / ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    ratio = None
    if model_flops and flops > 0:
        ratio = model_flops / flops
    return Roofline(
        device_flops=flops, device_bytes=byts,
        collective_bytes=colls.total_bytes,
        collective_counts=colls.count_by_kind,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_flops_ratio=ratio, per_device_hbm_bytes=hbm,
        unknown_trip_counts=colls.unknown_trip_counts,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)))


# ---------------------------------------------------------------------------
def model_flops_estimate(cfg, shape, n_devices: int) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) per device, D = tokens processed.

    Training multiplies by 1 (the 6 already counts fwd+bwd: 2 fwd + 4 bwd);
    decode counts one token per sequence.
    """
    n_params, n_active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_devices
    tokens = shape.global_batch            # one step
    return 2.0 * n_active * tokens / n_devices


def param_counts(cfg) -> Tuple[float, float]:
    """(total, active) parameter counts from the config."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.hd
    counts = {"attn": d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2),
              "rec": 0.0, "mlstm": 0.0, "slstm": 0.0}
    w = cfg.lru_width or d
    counts["rec"] = 3 * d * w + 2 * w * w + cfg.conv_width * w
    counts["mlstm"] = 4 * d * (cfg.n_heads * hd) + 2 * d * cfg.n_heads + \
        3 * d * d
    counts["slstm"] = 8 * d * d + d * d
    if cfg.ffn_kind in ("swiglu", "geglu"):
        ffn_total = ffn_active = 3 * d * ff
    elif cfg.ffn_kind == "gelu":
        ffn_total = ffn_active = 2 * d * ff
    elif cfg.ffn_kind == "moe":
        ffn_total = cfg.moe.num_experts * 3 * d * ff + d * cfg.moe.num_experts
        ffn_active = cfg.moe.top_k * 3 * d * ff + d * cfg.moe.num_experts
    else:
        ffn_total = ffn_active = 0.0

    layers = list(cfg.unit) * cfg.resolved_n_units + list(cfg.tail)
    total = active = 0.0
    for kind in layers:
        total += counts[kind]
        active += counts[kind]
        if kind in ("attn", "rec"):
            total += ffn_total
            active += ffn_active
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    total += emb
    active += emb
    if cfg.is_encoder_decoder:
        enc = cfg.n_encoder_layers * (counts["attn"] + 2 * d * ff)
        cross = cfg.n_layers * counts["attn"]
        total += enc + cross
        active += enc + cross
    return total, active
