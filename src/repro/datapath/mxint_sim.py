"""``mxint_sim`` backend: the 'sim' and 'packed' execution modes.

Bit-accurate XLA emulation of the paper's MXInt datapaths — the
correctness oracle the Pallas kernels are asserted against.  Linears run
quantize-dequantize in 'sim' (exactly equal to the integer datapath:
products of <=8-bit mantissas are exact in f32 and the accumulator is
lossless) or consume pre-packed MXTensor planes in 'packed' (dequant
fused into the consuming XLA op).  When ``quantize_nonlinear`` routes an
op here, LayerNorm/Softmax/GELU execute the ``repro.core.nonlinear``
datapaths; the ``emulate``/``nl_emulate`` knobs swap in the paper's
Table II–V comparison baselines.  See DESIGN.md §4/§12.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.datapath.base import Datapath

_LOG2E = 1.4426950408889634


class MXIntSimDatapath(Datapath):
    name = "mxint_sim"
    quantized_nonlinear = True

    def __init__(self, qdq_linears: bool):
        self.qdq_linears = qdq_linears

    # -- baseline selection --------------------------------------------------
    def nl_emulate(self, q, op: str):
        """Active Table II–IV baseline for ``op``, or None (MXInt path)."""
        return q.nl_emulate if self.nl_on(q, op) else None

    # -- norms ---------------------------------------------------------------
    def rmsnorm(self, x, gamma, *, q, eps: float = 1e-6):
        from repro.core import nonlinear as nl
        if self.nl_emulate(q, "layernorm") == "fixedpoint":
            # 8-bit fixed-point RMS variant of the [9]/SDA integer datapath
            xf = nl._fixed_point_qdq(x.astype(jnp.float32), 8)
            y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) +
                                   eps)
            return (nl._fixed_point_qdq(y, 8) * gamma.value).astype(x.dtype)
        if self.nl_on(q, "layernorm"):
            y = nl.layernorm_value(x.astype(jnp.float32), gamma.value, None,
                                   q.nonlinear, q.act_fmt, rms_only=True)
            return y.astype(x.dtype)
        return self._float_rmsnorm(x, gamma, eps)

    def layernorm(self, x, gamma, beta, *, q, eps: float = 1e-6):
        from repro.core import nonlinear as nl
        if self.nl_emulate(q, "layernorm") == "fixedpoint":
            y = nl.fixedpoint_layernorm(x.astype(jnp.float32), gamma.value,
                                        beta.value, bits=8, eps=eps)
            return y.astype(x.dtype)
        if self.nl_on(q, "layernorm"):
            y = nl.layernorm_value(x.astype(jnp.float32), gamma.value,
                                   beta.value, q.nonlinear, q.act_fmt)
            return y.astype(x.dtype)
        return self._float_layernorm(x, gamma, beta, eps)

    # -- activations / softmax / exp -----------------------------------------
    def act(self, x, kind: str, *, q):
        from repro.core import nonlinear as nl
        em = self.nl_emulate(q, "gelu")
        if em == "fixedpoint":
            return nl.fixedpoint_gelu(x.astype(jnp.float32)).astype(x.dtype)
        if em == "relu6":
            return nl.relu6_gelu(x.astype(jnp.float32)).astype(x.dtype)
        if self.nl_on(q, "gelu"):
            f = {"gelu": nl.gelu_value, "silu": nl.silu_value}[kind]
            return f(x.astype(jnp.float32), q.nonlinear,
                     q.act_fmt).astype(x.dtype)
        return super().act(x, kind, q=q)

    def softmax(self, x, *, q, axis: int = -1):
        from repro.core import nonlinear as nl
        if self.nl_emulate(q, "softmax") in ("fixedpoint", "relu6"):
            return nl.fixedpoint_softmax(x.astype(jnp.float32),
                                         axis=axis).astype(x.dtype)
        if self.nl_on(q, "softmax"):
            y = nl.softmax_value(x.astype(jnp.float32), q.nonlinear,
                                 q.act_fmt, axis=axis)
            return y.astype(x.dtype)
        return jax.nn.softmax(x, axis=axis)

    def exp(self, x, *, q):
        """mLSTM exp gate through the Eq. 14-19 pow2 datapath when softmax
        routes through the MXInt LUTs."""
        if self.nl_on(q, "softmax"):
            from repro.core.nonlinear import exp_datapath
            return exp_datapath(x * _LOG2E, q.nonlinear.softmax_r_bits)
        return jnp.exp(x)

    # -- attention -----------------------------------------------------------
    def _attention_use_direct(self, q, s: int, kv_len: int) -> bool:
        # the MXInt softmax 'sim' datapath computes whole rows (the paper's
        # ViT/FPGA path) — always direct when non-linears are quantized
        return q.quantize_nonlinear or s * kv_len <= 512 * 512
