"""Pluggable execution backends for the quantized-op protocol.

One ``Datapath`` instance per ``QuantConfig.mode`` (DESIGN.md §12):

  'off' / 'fake'    -> ``xla_float``     (plain XLA; 'fake' adds QDQ)
  'sim' / 'packed'  -> ``mxint_sim``     (bit-accurate MXInt emulation,
                                          Table II–V baselines)
  'kernel'          -> ``pallas_kernel`` (Pallas MXInt kernels + the
                                          fused LN→linear composite)

``resolve(q)`` maps a config to its backend; models reach it through the
``QuantConfig.datapath`` cached property and never branch on mode strings
themselves (``tools/check_dispatch.py`` enforces the seam).  Third-party
backends register with ``register_backend`` — e.g. a future GPU/Triton
datapath claims a new mode without touching a single call site.
"""
from __future__ import annotations

from typing import Dict

from repro.datapath.base import Datapath
from repro.datapath.mxint_sim import MXIntSimDatapath
from repro.datapath.pallas_kernel import PallasKernelDatapath
from repro.datapath.xla_float import XLAFloatDatapath

__all__ = ["Datapath", "resolve", "register_backend", "backends",
           "XLAFloatDatapath", "MXIntSimDatapath", "PallasKernelDatapath"]

# mode -> stateless backend singleton.  Per-op knobs travel in the
# QuantConfig passed to every method, so two modes may share one instance
# class with different capability flags.
_BACKENDS: Dict[str, Datapath] = {}


def register_backend(mode: str, backend: Datapath,
                     override: bool = False) -> Datapath:
    """Register ``backend`` for ``QuantConfig.mode == mode``.

    ``override=True`` replaces an existing registration (tests swap in
    instrumented backends this way); otherwise double registration is an
    error so two imports cannot silently fight over a mode.
    """
    if not override and mode in _BACKENDS:
        raise ValueError(f"mode {mode!r} already has backend "
                         f"{_BACKENDS[mode].name!r}")
    _BACKENDS[mode] = backend
    return backend


def backends() -> Dict[str, Datapath]:
    """Copy of the mode -> backend registry."""
    return dict(_BACKENDS)


def resolve(q, scope=None) -> Datapath:
    """Backend for ``q.mode``.  Called once per config by the
    ``QuantConfig.datapath`` cached property.

    ``scope`` is the optional per-layer-group tag (DESIGN.md §16): the
    config's ``overrides`` are applied first (``q.scoped(scope)``), so a
    scope whose override swaps the mode resolves to a DIFFERENT backend
    than the base config — kernel attention + sim FFN in one model.
    """
    if scope is not None:
        q = q.scoped(scope)
    try:
        return _BACKENDS[q.mode]
    except KeyError:
        raise ValueError(f"no datapath backend registered for mode "
                         f"{q.mode!r}; known: {sorted(_BACKENDS)}") from None


register_backend("off", XLAFloatDatapath(qdq_linears=False))
register_backend("fake", XLAFloatDatapath(qdq_linears=True))
register_backend("sim", MXIntSimDatapath(qdq_linears=True))
register_backend("packed", MXIntSimDatapath(qdq_linears=False))
register_backend("kernel", PallasKernelDatapath())
