"""``xla_float`` backend: the 'off' and 'fake' execution modes.

Plain XLA float ops end to end.  'off' is the full-precision reference
path; 'fake' adds quantize-dequantize (straight-through grads) on linear
weights and activations — QAT-style sweeps — while the non-linear ops
stay float (``quantized_nonlinear`` is False, so ``nl_on`` never fires
here, matching the pre-refactor mode gate).  See DESIGN.md §12.
"""
from __future__ import annotations

from repro.datapath.base import Datapath


class XLAFloatDatapath(Datapath):
    name = "xla_float"
    quantized_nonlinear = False

    def __init__(self, qdq_linears: bool):
        self.qdq_linears = qdq_linears
