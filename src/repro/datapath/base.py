"""The ``Datapath`` backend protocol (DESIGN.md §12).

A *datapath* answers one question for every quantized operator the model
zoo emits: WHERE does this op execute and WITH WHAT numerics.  The paper's
whole design space — which ops run on the accelerator datapath, what fuses
with what — is exactly this choice, so it lives in one pluggable policy
object instead of per-op ``q.mode`` if-chains scattered through
``models/``.

One backend instance exists per ``QuantConfig.mode`` (stateless
singletons; all per-op knobs arrive via the ``q`` kwarg), registered in
``repro.datapath`` and resolved ONCE per config through the
``QuantConfig.datapath`` cached property.  ``models/layers.py`` and
``models/attention.py`` are thin forwarding wrappers over these methods —
the only place allowed to branch on mode strings is this package (plus the
mode validation in ``core/mx_types.py``), enforced by
``tools/check_dispatch.py`` in CI.

Composite hooks: an attribute that is ``None`` on the base class and a
bound method on backends that provide it.  Callers probe
``dp.layernorm_linear`` and fall back to the equivalent op sequence when
absent; a provided composite MUST be bit-identical to that fallback
sequence (the contract that lets blocks call it unconditionally —
DESIGN.md §12).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


class Datapath:
    """Execution backend for the quantized-op protocol.

    Subclasses implement the per-op methods below; the base class carries
    the shared float reference implementations and the attention
    orchestration helpers every XLA backend uses.  Capability flags:

      quantized_nonlinear: this backend CAN run the MXInt non-linear
        datapaths (``nl_on`` consults it — 'off'/'fake' never quantize
        LayerNorm/GELU/Softmax, matching the pre-refactor mode gate).
      qdq_linears: float weights/activations of linears pass through the
        quantize-dequantize grid ('fake'/'sim'; 'packed'/'kernel' consume
        pre-packed planes, 'off' is untouched float).

    Composite hooks (``None`` unless the backend provides them):

      layernorm_linear(x, gamma, beta, w, b, *, q, eps, rms_only) —
        LayerNorm/RMSNorm immediately followed by a quantized linear,
        with the normalized act-quantized tile staying on-chip
        (DESIGN.md §12).  Must be bit-identical to
        ``linear(layernorm(x), w, b)`` under the same config.
    """

    name: str = "base"
    quantized_nonlinear: bool = False
    qdq_linears: bool = False

    # composite hooks — None means "not provided; caller runs the op
    # sequence instead"
    layernorm_linear = None

    def nl_on(self, q, op: str) -> bool:
        """Does ``op`` run the MXInt non-linear datapath under ``q``?"""
        return (q.enabled and q.quantize_nonlinear and
                self.quantized_nonlinear and op in q.nl_ops)

    def fuses_norm_linear(self, q, x=None, w=None) -> bool:
        """Will ``layernorm_linear`` actually FUSE for this call?  When
        False, callers feeding several linears from one norm should
        normalize once and reuse (the composite, if present, would only
        replay the unfused sequence per consumer).  ``x``/``w`` let the
        backend consult shapes and weight sharding, not just the config;
        both optional (config-level answer without them)."""
        return False

    # -- linears ------------------------------------------------------------
    def qdq_weight(self, w: jnp.ndarray, *, q) -> jnp.ndarray:
        """Weight quantize-dequantize onto this backend's weight grid
        (identity unless ``qdq_linears``)."""
        if not self.qdq_linears:
            return w
        if q.emulate == "int":
            from repro.core.quantize import per_tensor_int_qdq
            return per_tensor_int_qdq(w, q.weight_fmt.mant_bits)
        if q.emulate == "fp8":
            from repro.core.quantize import fp8_e4m3_qdq
            return fp8_e4m3_qdq(w)
        from repro.core.quantize import fake_quant
        return fake_quant(w, q.weight_fmt.mant_bits,
                          q.weight_fmt.block_size, 0)

    def qdq_act(self, x: jnp.ndarray, *, q) -> jnp.ndarray:
        """Activation quantize-dequantize onto the act grid (identity
        unless ``qdq_linears``)."""
        if not self.qdq_linears:
            return x
        if q.emulate == "int":
            from repro.core.quantize import per_tensor_int_qdq
            return per_tensor_int_qdq(x, q.act_fmt.mant_bits)
        if q.emulate == "fp8":
            from repro.core.quantize import fp8_e4m3_qdq
            return fp8_e4m3_qdq(x)
        from repro.core.quantize import fake_quant
        return fake_quant(x, q.act_fmt.mant_bits, q.act_fmt.block_size, -1)

    def weight_value(self, wv, *, q, dtype) -> jnp.ndarray:
        """Materialize a weight leaf as float: dequantize packed MXTensor
        planes (fused into the consuming op by XLA) or QDQ float values."""
        import importlib
        # module object, not the `repro.core.quantize` FUNCTION re-export;
        # attribute call so tests can spy on the dequant seam
        qz = importlib.import_module("repro.core.quantize")
        if isinstance(wv, qz.MXTensor):
            return qz.dequantize(wv, dtype=dtype)
        return self.qdq_weight(wv, q=q).astype(dtype)

    def linear(self, x: jnp.ndarray, w, b=None, *, q) -> jnp.ndarray:
        """y = x @ w (+ b).  w/b are Params; w may hold packed planes."""
        wf = self.weight_value(w.value, q=q, dtype=x.dtype)
        xf = self.qdq_act(x, q=q)
        y = jnp.einsum("...k,kn->...n", xf, wf)
        if b is not None:
            y = y + b.value.astype(y.dtype)
        return y

    # -- norms --------------------------------------------------------------
    @staticmethod
    def _float_layernorm(x, gamma, beta, eps):
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * gamma.value + beta.value).astype(x.dtype)

    @staticmethod
    def _float_rmsnorm(x, gamma, eps):
        xf = x.astype(jnp.float32)
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * gamma.value).astype(x.dtype)

    def layernorm(self, x, gamma, beta, *, q, eps: float = 1e-6):
        return self._float_layernorm(x, gamma, beta, eps)

    def rmsnorm(self, x, gamma, *, q, eps: float = 1e-6):
        return self._float_rmsnorm(x, gamma, eps)

    # -- activations / softmax / exp ----------------------------------------
    def act(self, x, kind: str, *, q):
        return {"gelu": lambda v: jax.nn.gelu(v, approximate=False),
                "silu": jax.nn.silu}[kind](x)

    def softmax(self, x, *, q, axis: int = -1):
        return jax.nn.softmax(x, axis=axis)

    def exp(self, x, *, q):
        """e^x for scalar gate datapaths (mLSTM input gate)."""
        return jnp.exp(x)

    # -- attention ----------------------------------------------------------
    def _attention_use_direct(self, q, s: int, kv_len: int) -> bool:
        return s * kv_len <= 512 * 512

    def attention(self, qv, k, v, *, q, positions, causal: bool,
                  window: int, scale: float, chunk: int):
        """Cache-less attention core.  qv: (b, s, kv, g, hd);
        k/v: (b, S, kv, hd).  Returns (b, s, kv, g, hd)."""
        from repro.models import attention as A
        s = qv.shape[1]
        kv_len = k.shape[1]
        if self._attention_use_direct(q, s, kv_len):
            mask = A.positions_mask(positions, s, kv_len, causal, window)
            return A._direct_attention(qv, k, v, mask[:, None, None], q,
                                       scale)
        # per-row positions thread into the q-block masks: a left-padded
        # batch long enough to overflow the direct threshold must mask
        # exactly like ``positions_mask`` (ISSUE 6 ragged-chunked fix)
        return A._q_chunked_attention(qv, k, v, q_offset=0, causal=causal,
                                      window=window, chunk=chunk,
                                      scale=scale, positions=positions)

    def attention_decode(self, qv, ck, cv, valid, *, q, scale: float):
        """Single-position decode over a cache ring.  qv: (b, 1, kv, g, hd);
        ck/cv: (b, W, kv, hd); valid: (b, W) per-row ring validity (a (W,)
        vector broadcasts — shared validity).  Returns qv's shape."""
        from repro.models import attention as A
        v2 = valid if valid.ndim == 2 else valid[None]     # (1|b, W)
        mask = v2[:, None, None, None, :]                  # (1|b,1,1,1,W)
        sc = A._gqa_scores(qv, ck.astype(qv.dtype), scale)
        sc = jnp.where(mask, sc.astype(jnp.float32), A._NEG_INF)
        pr = self.softmax(sc, q=q, axis=-1).astype(qv.dtype)
        pr = jnp.where(mask, pr, 0.0)
        return jnp.einsum("bkgsS,bSkd->bskgd", pr, cv.astype(qv.dtype))
