"""``pallas_kernel`` backend: the 'kernel' execution mode.

Every op executes on the Pallas accelerator datapath
(``repro.kernels.ops``): linears feed packed int8 mantissa/exponent
planes straight into ``mxint_linear`` (no host-side dequantize — HBM
traffic is the quantized bytes), and when ``quantize_nonlinear`` is set
the non-linear ops run the in-kernel MXInt datapaths.  Numerically
identical to the ``mxint_sim`` oracle (same LUTs, same integer stages).
Inference-only: the Pallas calls carry no VJP.

Provides the ``layernorm_linear`` composite hook: LayerNorm/RMSNorm
fused into the consuming quantized matmul through
``ops.mxint_ln_linear_op``, which keeps the normalized, act-quantized
tile in VMEM and feeds it straight into the packed-plane contraction —
one full HBM round-trip of the normalized activations removed per block,
bit-identical to the unfused two-kernel sequence by construction
(DESIGN.md §12).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.datapath.base import Datapath


class PallasKernelDatapath(Datapath):
    name = "pallas_kernel"
    quantized_nonlinear = True
    qdq_linears = False

    # -- linears -------------------------------------------------------------
    @staticmethod
    def _packed(wv, q):
        from repro.core.quantize import MXTensor, pack_weight
        if isinstance(wv, MXTensor):
            return wv
        return pack_weight(jnp.asarray(wv, jnp.float32), q.weight_fmt,
                           axis=0)

    def linear(self, x, w, b=None, *, q):
        return self._linear_planes(x, self._packed(w.value, q), b, q)

    @staticmethod
    def _linear_planes(x, wv, b, q):
        from repro.kernels import ops
        # tp_axis/tp_mode are static MXTensor metadata stamped by
        # tp_shard_packed_params: inside a shard_map the kernel runs on the
        # local planes and mxint_linear inserts the matching collective
        # (all_gather / psum) before the bias add (DESIGN.md §10).
        return ops.mxint_linear(
            x, wv.mantissa, wv.exponent,
            None if b is None else b.value.astype(jnp.float32),
            w_block=wv.block_size, quantize_act=True,
            act_block=q.act_fmt.block_size,
            act_mant_bits=q.act_fmt.mant_bits,
            tp_axis=wv.tp_axis, tp_mode=wv.tp_mode)

    # -- norms ---------------------------------------------------------------
    def rmsnorm(self, x, gamma, *, q, eps: float = 1e-6):
        if not self.nl_on(q, "layernorm"):
            return self._float_rmsnorm(x, gamma, eps)
        from repro.kernels import ops
        y = ops.mxint_layernorm_op(
            x.astype(jnp.float32), gamma.value, None,
            act_block=q.act_fmt.block_size, mant_bits=q.act_fmt.mant_bits,
            lut_bits=q.nonlinear.ln_lut_bits, rms_only=True,
            quantize_out=True)
        return y.astype(x.dtype)

    def layernorm(self, x, gamma, beta, *, q, eps: float = 1e-6):
        if not self.nl_on(q, "layernorm"):
            return self._float_layernorm(x, gamma, beta, eps)
        from repro.kernels import ops
        y = ops.mxint_layernorm_op(
            x.astype(jnp.float32), gamma.value, beta.value,
            act_block=q.act_fmt.block_size, mant_bits=q.act_fmt.mant_bits,
            lut_bits=q.nonlinear.ln_lut_bits, quantize_out=True)
        return y.astype(x.dtype)

    # -- fused LN -> linear composite (DESIGN.md §12) ------------------------
    def fuses_norm_linear(self, q, x=None, w=None) -> bool:
        """Fusion needs the MXInt LN datapath (float LN has no kernel),
        un-psum-sharded planes (the contraction shard never sees the full
        row the LN normalizes) and — on compiled TPU — the tileability
        gate of ``mxint_ln_linear_op``; interpret mode pads any shape in.
        Callers hoist the norm whenever this says False, so the composite
        never degrades into replaying the unfused pair per consumer."""
        if not self.nl_on(q, "layernorm"):
            return False
        if w is None:
            return True
        from repro.core.quantize import MXTensor
        wv = w.value
        if isinstance(wv, MXTensor):
            if wv.tp_mode == "psum":
                return False
            n = wv.mantissa.shape[-1]
        else:
            n = wv.shape[-1]
        from repro.kernels import ops
        if ops._interpret() or x is None:
            return True
        m = 1
        for d in x.shape[:-1]:
            m *= d
        return m % 8 == 0 and x.shape[-1] % 128 == 0 and n % 128 == 0

    def _norm_then_linear(self, x, gamma, beta, wv, b, *, q, eps,
                          rms_only):
        """The unfused pair on pre-packed planes — the sequence the fused
        kernel is bit-identical to (single shared fallback)."""
        h = (self.rmsnorm(x, gamma, q=q, eps=eps) if rms_only
             else self.layernorm(x, gamma, beta, q=q, eps=eps))
        return self._linear_planes(h, wv, b, q)

    def layernorm_linear(self, x, gamma, beta, w, b=None, *, q,
                         eps: float = 1e-6, rms_only: bool = False):
        """Fused norm + quantized matmul; bit-identical to the unfused
        kernel sequence.  Falls back to the two-op path when the norm is
        not on the MXInt datapath or the weight planes are row/psum
        sharded (the fused kernel normalizes the FULL row, which a
        contraction-sharded plane never sees)."""
        wv = self._packed(w.value, q)
        if not self.nl_on(q, "layernorm") or wv.tp_mode == "psum":
            return self._norm_then_linear(x, gamma, beta, wv, b, q=q,
                                          eps=eps, rms_only=rms_only)
        from repro.kernels import ops
        return ops.mxint_ln_linear_op(
            x, gamma.value, None if beta is None else beta.value,
            wv.mantissa, wv.exponent,
            None if b is None else b.value.astype(jnp.float32),
            w_block=wv.block_size, act_block=q.act_fmt.block_size,
            mant_bits=q.act_fmt.mant_bits,
            lut_bits=q.nonlinear.ln_lut_bits, rms_only=rms_only,
            tp_axis=wv.tp_axis, tp_mode=wv.tp_mode)

    # -- activations / softmax -----------------------------------------------
    def act(self, x, kind: str, *, q):
        if not self.nl_on(q, "gelu"):
            return super().act(x, kind, q=q)
        from repro.kernels import ops
        cfg = q.nonlinear
        y = ops.mxint_gelu_op(
            x.astype(jnp.float32), fn=kind,
            act_block=q.act_fmt.block_size, mant_bits=q.act_fmt.mant_bits,
            lut_bits=cfg.gelu_lut_bits, domain=cfg.gelu_domain)
        return y.astype(x.dtype)

    def softmax(self, x, *, q, axis: int = -1):
        if not self.nl_on(q, "softmax"):
            return super().softmax(x, q=q, axis=axis)
        if axis in (-1, x.ndim - 1):
            from repro.kernels import ops
            y = ops.mxint_softmax_op(
                x.astype(jnp.float32), act_block=q.act_fmt.block_size,
                mant_bits=q.act_fmt.mant_bits,
                r_bits=q.nonlinear.softmax_r_bits, quantize_out=True)
            return y.astype(x.dtype)
        # non-trailing axis: the whole-row kernel does not apply — run the
        # bit-identical sim datapath
        from repro.core import nonlinear as nl
        y = nl.softmax_value(x.astype(jnp.float32), q.nonlinear, q.act_fmt,
                             axis=axis)
        return y.astype(x.dtype)

    # -- attention -----------------------------------------------------------
    def attention(self, qv, k, v, *, q, positions, causal: bool,
                  window: int, scale: float, chunk: int):
        # heads-major layout into attention_op.  'paper' variant =
        # whole-row MXInt softmax in the Pallas kernel (bit-identical to
        # the sim direct path); blocked mxint flash for long sequences;
        # float flash otherwise.
        from repro.kernels import ops as kops
        b, s, kvh, g, hd = qv.shape
        S = k.shape[1]
        qh = jnp.einsum("bskgd->bkgsd", qv).reshape(b, kvh * g, s, hd)
        kh = jnp.einsum("bSkd->bkSd", k)          # (b, kvh, S, hd), no copy
        vh = jnp.einsum("bSkd->bkSd", v)
        if self.nl_on(q, "softmax"):
            if s * S <= 512 * 512:
                # whole-row 'paper' softmax: bit-identical to the sim
                # direct path (the ViT / encoder production path)
                o = kops.attention_op(
                    qh, kh, vh, causal=causal, window=window,
                    softmax_variant="paper",
                    act_block=q.act_fmt.block_size,
                    mant_bits=q.act_fmt.mant_bits,
                    r_bits=q.nonlinear.softmax_r_bits)
            else:
                # long sequences: blocked mxint flash — the Eq. 14-20
                # datapath without the O(S^2) score matrix (DESIGN.md §11)
                o = kops.attention_op(
                    qh, kh, vh, causal=causal, window=window,
                    softmax_variant="online", exp_mode="mxint",
                    quantize_scores=True,
                    act_block=q.act_fmt.block_size,
                    mant_bits=q.act_fmt.mant_bits,
                    r_bits=q.nonlinear.softmax_r_bits)
        else:
            o = kops.attention_op(qh, kh, vh, causal=causal, window=window,
                                  exp_mode="float")
        return jnp.einsum("bkgsd->bskgd", o.reshape(b, kvh, g, s, hd))

    def attention_decode(self, qv, ck, cv, valid, *, q, scale: float):
        # Pallas decode: one fused kernel scores the ring, runs the
        # (optionally Eq. 14-20 quantized) online softmax and the p @ V
        # matmul — no XLA softmax on the decode path (DESIGN.md §11).
        # GQA groups fold into the kernel's sublane rows; ring validity
        # streams in as `valid`; the cache planes go in UNTRANSPOSED (the
        # kernel grid walks the native (b, W, kv, hd) layout).
        from repro.kernels import ops as kops
        qd = qv[:, 0]                              # (b, kv, g, hd)
        kd = ck.astype(qv.dtype)
        vd = cv.astype(qv.dtype)
        if self.nl_on(q, "softmax"):
            od = kops.attention_decode_op(
                qd, kd, vd, valid, exp_mode="mxint",
                r_bits=q.nonlinear.softmax_r_bits,
                quantize_scores=True,
                act_block=q.act_fmt.block_size,
                mant_bits=q.act_fmt.mant_bits)
        else:
            od = kops.attention_decode_op(qd, kd, vd, valid)
        return od[:, None]                         # (b, 1, kv, g, hd)
