from repro.serving.engine import (ServeConfig, make_prefill_step,
                                  make_decode_step, pack_params_mxint,
                                  ServingEngine, ViTServingEngine,
                                  make_engine)
from repro.serving.scheduler import (BatchScheduler, ClassifyRequest,
                                     ClassifyScheduler, Request)
