from repro.serving.engine import (ServeConfig, make_prefill_step,
                                  make_decode_step, pack_params_mxint,
                                  ServingEngine)
from repro.serving.scheduler import BatchScheduler, Request
