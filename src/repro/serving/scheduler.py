"""Continuous-batching request schedulers (host side; DESIGN.md §7).

Two schedulers share the same contract — a FIXED batch shape feeds one
jit specialization forever, while mixed-size request streams are packed
into it at step boundaries:

``BatchScheduler`` (token engines): maintains a fixed-width decode
batch with SLOT-level admission: the KV cache carries a per-row
``cache['index']`` vector, so a finished row is evicted and the next
queued request prefilled into that slot immediately (one batch-1
prefill scattered into the live cache — ``make_slot_prefill_step``)
while the other rows keep decoding.  No wave barrier: a slot freed at
step t serves a new request at step t+1.  ``admission='wave'`` retains
the old whole-batch-drain policy for throughput comparison
(benchmarks/kernel_bench.py ``lm_batching_rows``).

``ClassifyScheduler`` (ViT engines): classification is stateless, so
admission is fully continuous — each step packs up to ``batch`` images
from the queue front, ACROSS request boundaries, zero-padding only the
final partial chunk.  A request's images may span several steps; the
request completes when its last image is classified.  Because every
step runs the same (batch, H, W, 3) shape, the jit cache never grows
past one entry regardless of the request-size mix (asserted via
``engine.jit_cache_size()`` in tests/test_sharded_serving.py).

Both schedulers publish to ``repro.telemetry`` (DESIGN.md §15):
``scheduler/submitted``/``completed``/``admissions`` counters,
``queue_depth``/``slots_active``/``in_flight`` gauges (conserving
``submitted == completed + in_flight`` at step boundaries),
``request_latency_ms`` histograms, throughput gauges, and the
``serving/recompiles`` counter via the engine's jit-cache delta.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro import telemetry as T
from repro.serving.engine import _note_recompiles


@dataclasses.dataclass
class Request:
    """One token-generation request.

    prompt: (s,) int32 token ids; generated: filled by the scheduler;
    done: set on EOS or when ``max_new_tokens`` is reached."""
    uid: int
    prompt: np.ndarray                 # (s,) int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    _submit_ts: Optional[float] = None  # set by the scheduler at submit


class BatchScheduler:
    """Slot-level continuous batching around a token engine.

    engine: a ``ServingEngine`` (needs ``_prefill_slot``/``_decode``/
    ``params`` and ``model.cache_init``).  batch_size: fixed decode
    width.  eos_id: optional stop token.

    prefill_len: fixed (1, P) slot-prefill shape; prompts are
    RIGHT-padded to it (a longer prompt raises at ``submit``).  ``None``
    buckets each prompt to the next power of two — one jit
    specialization per bucket ever seen, flat after warmup.

    admission: 'slot' (default) admits a queued request into every
    freed slot at each step.  'wave' defers admission until the whole
    batch has drained — the policy the scalar cache index used to
    force; kept only as the throughput baseline.

    Token contract: a request's first generated token comes from its
    prefill logits (recorded at admission), the rest from decode steps
    — identical to running ``ServingEngine.generate`` on that request
    alone (property-tested against the unbatched oracle in
    tests/test_scheduler_properties.py).
    """

    def __init__(self, engine, batch_size: int, eos_id: Optional[int] = None,
                 prefill_len: Optional[int] = None, admission: str = "slot"):
        if admission not in ("slot", "wave"):
            raise ValueError(admission)
        self.engine = engine
        self.batch = batch_size
        self.eos = eos_id
        self.prefill_len = prefill_len
        self.admission = admission
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_size
        self.finished: List[Request] = []
        self._tok = None               # (batch, 1) int32 numpy
        self._cache = None

    def submit(self, req: Request):
        """Enqueue; admitted into the next freed slot (FIFO).  There is
        no capacity limit — the queue absorbs any submit burst."""
        if self.prefill_len is not None and \
                len(req.prompt) > self.prefill_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} > prefill_len "
                f"{self.prefill_len}")
        req._submit_ts = T.walltime()
        self.queue.append(req)
        T.counter("scheduler/submitted").inc()
        self._update_gauges()

    def _bucket(self, n: int) -> int:
        """Slot-prefill pad length for an ``n``-token prompt: the fixed
        ``prefill_len``, or the next power of two (>= 8) — each bucket
        is one jit specialization, so the cache stays flat once every
        bucket in the workload has been seen."""
        if self.prefill_len is not None:
            return self.prefill_len
        p = 8
        while p < n:
            p *= 2
        return p

    def _record(self, req: Request, tok: int):
        req.generated.append(tok)
        if (self.eos is not None and tok == self.eos) or \
                len(req.generated) >= req.max_new_tokens:
            req.done = True

    def _update_gauges(self):
        """Publish the queue/slot occupancy gauges.  The conservation
        invariant asserted by tests/test_scheduler_properties.py:
        ``scheduler/submitted == scheduler/completed +
        scheduler/in_flight`` at every step boundary (a done-but-not-
        evicted slot still counts as in flight — it completes at
        eviction)."""
        slots = sum(1 for r in self.active if r is not None)
        T.gauge("scheduler/queue_depth").set(len(self.queue))
        T.gauge("scheduler/slots_active").set(slots)
        T.gauge("scheduler/in_flight").set(len(self.queue) + slots)

    def _evict(self):
        """Move done requests out of their slots.  Slot mode frees each
        slot the step after its request finishes; wave mode holds every
        slot until the whole batch has drained."""
        if self.admission == "wave" and \
                any(r is not None and not r.done for r in self.active):
            return
        for i, r in enumerate(self.active):
            if r is not None and r.done:
                self.finished.append(r)
                self.active[i] = None
                T.counter("scheduler/completed").inc()
                if r._submit_ts is not None:
                    T.histogram("scheduler/request_latency_ms",
                                T.DEFAULT_MS_BUCKETS).record(
                        (T.walltime() - r._submit_ts) * 1e3)
        self._update_gauges()

    def _admit(self):
        """Fill free slots from the queue front, one batch-1 slot
        prefill each — the live rows' cache state is untouched (per-row
        index contract, DESIGN.md §7)."""
        if not self.queue:
            return
        if self.admission == "wave" and \
                any(r is not None for r in self.active):
            return
        for i in range(self.batch):
            if not self.queue or self.active[i] is not None:
                continue
            req = self.queue.popleft()
            if self._cache is None:
                self._cache = self.engine.model.cache_init(
                    self.batch, self.engine.cfg.max_len)
                self._tok = np.zeros((self.batch, 1), np.int32)
            n = len(req.prompt)
            P = self._bucket(n)
            tokens = np.zeros((1, P), np.int32)
            tokens[0, :n] = req.prompt
            T.histogram("serving/prefill_len",
                        T.DEFAULT_SIZE_BUCKETS).record(P)
            with T.span("scheduler/slot_prefill"):
                tok, self._cache = self.engine._prefill_slot(
                    self.engine.params, jnp.asarray(tokens), jnp.int32(n),
                    jnp.int32(i), self._cache)
                t = int(np.asarray(tok)[0])
            T.counter("scheduler/admissions").inc()
            self.active[i] = req
            self._record(req, t)
            self._tok[i, 0] = t

    def step(self) -> int:
        """Evict, admit, then one decode step across the batch; returns
        #live requests.

        Empty queue + empty batch is a no-op returning 0 (safe to call
        in a drain loop).  Done-but-not-yet-evicted rows and empty slots
        keep decoding as padding; their output is discarded.
        """
        self._evict()
        self._admit()
        live = [r for r in self.active if r is not None and not r.done]
        if not live:
            self._update_gauges()
            return 0
        with T.span("scheduler/decode_step", live=len(live)) as sp:
            tok, self._cache = self.engine._decode(
                self.engine.params, jnp.asarray(self._tok), self._cache)
            self._tok = np.array(tok)      # writable host copy
        ntok = 0
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            self._record(r, int(self._tok[i, 0]))
            ntok += 1
        T.counter("scheduler/tokens_generated").inc(ntok)
        if sp.elapsed_s:
            T.gauge("scheduler/tokens_per_s").set(ntok / sp.elapsed_s)
        _note_recompiles(self.engine)
        self._update_gauges()
        return sum(1 for r in self.active if r is not None and not r.done)

    def run(self, max_steps: int = 1024) -> List[Request]:
        """Drain queue + batch; returns every request seen (finished
        first, then the residual active slots).  Slot-level admission
        means a queued request can never starve behind long-running
        slots: every freed slot is refilled from the queue front on the
        very next step."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        self._evict()
        return self.finished + [r for r in self.active if r is not None]


# ---------------------------------------------------------------------------
# classification-side continuous batching (the ViT serving path)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ClassifyRequest:
    """One classification request of ``images.shape[0]`` images.

    images: (n, H, W, 3) float; logits/labels: (n, classes)/(n,) numpy,
    filled incrementally as the scheduler packs this request's images
    into fixed-shape batches; done: set when all n are classified.
    """
    uid: int
    images: np.ndarray
    logits: Optional[np.ndarray] = None
    labels: Optional[np.ndarray] = None
    done: bool = False
    _next: int = 0                     # images admitted so far
    _submit_ts: Optional[float] = None  # set by the scheduler at submit


class ClassifyScheduler:
    """Continuous batching for stateless classification.

    Port of the token-engine ``BatchScheduler`` to the classify side:
    because a classifier holds no per-request state, admission needs no
    wave barrier — every ``step()`` packs up to ``batch`` images from
    the FRONT of the queue, spanning request boundaries, and zero-pads
    only when the queue runs dry mid-chunk.  All steps reuse the one
    (batch, H, W, 3) jit specialization of ``engine._logits`` (sharded
    or not), so mixed request sizes never recompile.

    engine: a ``ViTServingEngine``; batch_size defaults to the engine's
    ``ServeConfig.batch``.
    """

    def __init__(self, engine, batch_size: Optional[int] = None):
        self.engine = engine
        self.batch = batch_size or engine.cfg.batch
        self.n_classes = int(getattr(engine.model.cfg, "n_classes", 0))
        self.queue: deque[ClassifyRequest] = deque()
        self.finished: List[ClassifyRequest] = []

    def submit(self, req: ClassifyRequest):
        """Enqueue a request; its images are admitted (possibly split
        across steps) in FIFO order.  A zero-image request completes in
        queue order too (with correctly shaped empty results), so
        position-based result/label pairing stays aligned."""
        req._submit_ts = T.walltime()
        self.queue.append(req)
        T.counter("scheduler/submitted").inc()
        self._update_gauges()

    def _update_gauges(self):
        """Classification holds no slots: in-flight is just the queue
        (same conservation invariant as ``BatchScheduler``)."""
        T.gauge("scheduler/queue_depth").set(len(self.queue))
        T.gauge("scheduler/in_flight").set(len(self.queue))

    def jit_cache_size(self) -> int:
        """Specialization count of the underlying jitted forward (see
        ``ViTServingEngine.jit_cache_size``)."""
        return self.engine.jit_cache_size()

    def _evict_completed(self):
        """Pop front requests whose images are all classified (including
        zero-image requests) to ``finished``, preserving FIFO order."""
        while self.queue and self.queue[0]._next >= \
                self.queue[0].images.shape[0]:
            req = self.queue.popleft()
            if req.logits is None:             # zero-image request
                req.logits = np.zeros((0, self.n_classes), np.float32)
                req.labels = np.zeros((0,), np.int64)
            req.done = True
            self.finished.append(req)
            T.counter("scheduler/completed").inc()
            if req._submit_ts is not None:
                T.histogram("scheduler/request_latency_ms",
                            T.DEFAULT_MS_BUCKETS).record(
                    (T.walltime() - req._submit_ts) * 1e3)
        self._update_gauges()

    def step(self) -> int:
        """Classify up to ``batch`` images off the queue front; returns
        the number of images classified (0 when the queue is empty)."""
        self._evict_completed()
        take: List[tuple] = []                 # (request, image index)
        for req in self.queue:
            while len(take) < self.batch and \
                    req._next < req.images.shape[0]:
                take.append((req, req._next))
                req._next += 1
            if len(take) >= self.batch:
                break
        if not take:
            return 0
        img = take[0][0].images
        chunk = np.zeros((self.batch,) + img.shape[1:], img.dtype)
        for j, (req, i) in enumerate(take):
            chunk[j] = req.images[i]
        with T.span("scheduler/classify_step", images=len(take)) as sp:
            logits = np.asarray(self.engine.logits_batch(chunk))
        # slot occupancy for a stateless batch = filled rows this step
        # (the rest of the fixed shape is zero padding)
        T.gauge("scheduler/slots_active").set(len(take))
        T.counter("scheduler/images_classified").inc(len(take))
        if sp.elapsed_s:
            T.gauge("scheduler/images_per_s").set(len(take) / sp.elapsed_s)
        _note_recompiles(self.engine)
        for j, (req, i) in enumerate(take):
            if req.logits is None:
                n = req.images.shape[0]
                req.logits = np.zeros((n, logits.shape[-1]), logits.dtype)
                req.labels = np.zeros((n,), np.int64)
            req.logits[i] = logits[j]
            req.labels[i] = int(np.argmax(logits[j]))
        self._evict_completed()
        return len(take)

    def run(self, max_steps: int = 4096) -> List[ClassifyRequest]:
        """Drain the queue; returns the finished requests in completion
        order."""
        for _ in range(max_steps):
            if self.step() == 0:
                break
        return self.finished
