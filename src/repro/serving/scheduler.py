"""Continuous-batching request schedulers (host side; DESIGN.md §7).

Two schedulers share the same contract — a FIXED batch shape feeds one
jit specialization forever, while mixed-size request streams are packed
into it at step boundaries:

``BatchScheduler`` (token engines): maintains a fixed-width decode
batch.  Admission is WAVE-synchronous: the model's KV cache carries one
scalar ``cache['index']`` shared by every row, so a prefill can only
(re)build the whole batch — freed slots therefore idle until the active
wave drains, then the next wave is admitted in one padded prefill.
Finished requests are evicted to ``self.finished`` at wave boundaries.

``ClassifyScheduler`` (ViT engines): classification is stateless, so
admission is fully continuous — each step packs up to ``batch`` images
from the queue front, ACROSS request boundaries, zero-padding only the
final partial chunk.  A request's images may span several steps; the
request completes when its last image is classified.  Because every
step runs the same (batch, H, W, 3) shape, the jit cache never grows
past one entry regardless of the request-size mix (asserted via
``engine.jit_cache_size()`` in tests/test_sharded_serving.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    """One token-generation request.

    prompt: (s,) int32 token ids; generated: filled by the scheduler;
    done: set on EOS or when ``max_new_tokens`` is reached."""
    uid: int
    prompt: np.ndarray                 # (s,) int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Wave-synchronous continuous batching around a token engine.

    engine: a ``ServingEngine`` (needs ``_prefill``/``_decode``/``params``
    and ``model.cache_init``).  batch_size: fixed decode width.  eos_id:
    optional stop token.
    """

    def __init__(self, engine, batch_size: int, eos_id: Optional[int] = None):
        self.engine = engine
        self.batch = batch_size
        self.eos = eos_id
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_size
        self.finished: List[Request] = []
        self._tok = None
        self._cache = None

    def submit(self, req: Request):
        """Enqueue; admission happens at the next wave boundary.  There is
        no capacity limit — the queue absorbs any submit burst."""
        self.queue.append(req)

    def _admit(self):
        """Admit a wave into free slots; one padded full-batch prefill.

        Deferred while ANY active request is still in flight: the KV
        cache keeps a single scalar index shared by all rows, so a
        prefill rebuilds the whole batch cache — admitting into a
        half-finished batch would clobber the in-flight rows' state
        (regression-tested by TestSchedulerEdgeCases).
        """
        if not self.queue:
            return
        if any(r is not None and not r.done for r in self.active):
            return                      # wave still draining
        # evict the finished wave
        for i, r in enumerate(self.active):
            if r is not None:
                self.finished.append(r)
                self.active[i] = None
        admitted = []
        for i in range(self.batch):
            if not self.queue:
                break
            self.active[i] = self.queue.popleft()
            admitted.append(i)
        if not admitted:
            return
        # pad all prompts to a common length, full-batch prefill
        max_len = max(len(self.active[i].prompt) for i in admitted)
        prompts = np.zeros((self.batch, max_len), np.int32)
        for i in admitted:
            p = self.active[i].prompt
            prompts[i, -len(p):] = p     # left-pad
        cache = self.engine.model.cache_init(self.batch,
                                             self.engine.cfg.max_len)
        logits, cache = self.engine._prefill(
            self.engine.params, {"tokens": jnp.asarray(prompts)}, cache)
        self._cache = cache
        self._tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    def step(self) -> int:
        """One decode step across the active batch; returns #live requests.

        Empty queue + empty batch is a no-op returning 0 (safe to call in
        a drain loop).  Rows whose request hit EOS keep decoding as
        padding until the wave drains; their output is discarded.
        """
        self._admit()
        live = [r for r in self.active if r is not None and not r.done]
        if not live or self._tok is None:
            return 0
        self._tok, self._cache = self.engine._decode(
            self.engine.params, self._tok, self._cache)
        toks = np.asarray(self._tok[:, 0])
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            t = int(toks[i])
            r.generated.append(t)
            if (self.eos is not None and t == self.eos) or \
                    len(r.generated) >= r.max_new_tokens:
                r.done = True
        return sum(1 for r in self.active if r is not None and not r.done)

    def run(self, max_steps: int = 1024) -> List[Request]:
        """Drain queue + batch; returns every request seen (finished waves
        first, then the residual active wave)."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.finished + [r for r in self.active if r is not None]


# ---------------------------------------------------------------------------
# classification-side continuous batching (the ViT serving path)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ClassifyRequest:
    """One classification request of ``images.shape[0]`` images.

    images: (n, H, W, 3) float; logits/labels: (n, classes)/(n,) numpy,
    filled incrementally as the scheduler packs this request's images
    into fixed-shape batches; done: set when all n are classified.
    """
    uid: int
    images: np.ndarray
    logits: Optional[np.ndarray] = None
    labels: Optional[np.ndarray] = None
    done: bool = False
    _next: int = 0                     # images admitted so far


class ClassifyScheduler:
    """Continuous batching for stateless classification.

    Port of the token-engine ``BatchScheduler`` to the classify side:
    because a classifier holds no per-request state, admission needs no
    wave barrier — every ``step()`` packs up to ``batch`` images from
    the FRONT of the queue, spanning request boundaries, and zero-pads
    only when the queue runs dry mid-chunk.  All steps reuse the one
    (batch, H, W, 3) jit specialization of ``engine._logits`` (sharded
    or not), so mixed request sizes never recompile.

    engine: a ``ViTServingEngine``; batch_size defaults to the engine's
    ``ServeConfig.batch``.
    """

    def __init__(self, engine, batch_size: Optional[int] = None):
        self.engine = engine
        self.batch = batch_size or engine.cfg.batch
        self.n_classes = int(getattr(engine.model.cfg, "n_classes", 0))
        self.queue: deque[ClassifyRequest] = deque()
        self.finished: List[ClassifyRequest] = []

    def submit(self, req: ClassifyRequest):
        """Enqueue a request; its images are admitted (possibly split
        across steps) in FIFO order.  A zero-image request completes in
        queue order too (with correctly shaped empty results), so
        position-based result/label pairing stays aligned."""
        self.queue.append(req)

    def jit_cache_size(self) -> int:
        """Specialization count of the underlying jitted forward (see
        ``ViTServingEngine.jit_cache_size``)."""
        return self.engine.jit_cache_size()

    def _evict_completed(self):
        """Pop front requests whose images are all classified (including
        zero-image requests) to ``finished``, preserving FIFO order."""
        while self.queue and self.queue[0]._next >= \
                self.queue[0].images.shape[0]:
            req = self.queue.popleft()
            if req.logits is None:             # zero-image request
                req.logits = np.zeros((0, self.n_classes), np.float32)
                req.labels = np.zeros((0,), np.int64)
            req.done = True
            self.finished.append(req)

    def step(self) -> int:
        """Classify up to ``batch`` images off the queue front; returns
        the number of images classified (0 when the queue is empty)."""
        self._evict_completed()
        take: List[tuple] = []                 # (request, image index)
        for req in self.queue:
            while len(take) < self.batch and \
                    req._next < req.images.shape[0]:
                take.append((req, req._next))
                req._next += 1
            if len(take) >= self.batch:
                break
        if not take:
            return 0
        img = take[0][0].images
        chunk = np.zeros((self.batch,) + img.shape[1:], img.dtype)
        for j, (req, i) in enumerate(take):
            chunk[j] = req.images[i]
        logits = np.asarray(self.engine.logits_batch(chunk))
        for j, (req, i) in enumerate(take):
            if req.logits is None:
                n = req.images.shape[0]
                req.logits = np.zeros((n, logits.shape[-1]), logits.dtype)
                req.labels = np.zeros((n,), np.int64)
            req.logits[i] = logits[j]
            req.labels[i] = int(np.argmax(logits[j]))
        self._evict_completed()
        return len(take)

    def run(self, max_steps: int = 4096) -> List[ClassifyRequest]:
        """Drain the queue; returns the finished requests in completion
        order."""
        for _ in range(max_steps):
            if self.step() == 0:
                break
        return self.finished
