"""Continuous-batching-lite request scheduler (host side).

Maintains a fixed-width decode batch; finished or empty slots are refilled
from the waiting queue at step boundaries (the cache slots are reused, the
jitted decode step never re-specializes because the batch shape is fixed).
This is the scheduling layer a real serving deployment needs around the
jitted steps; the dry-run lowers the steps themselves.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (s,) int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    def __init__(self, engine, batch_size: int, eos_id: Optional[int] = None):
        self.engine = engine
        self.batch = batch_size
        self.eos = eos_id
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_size
        self._tok = None
        self._cache = None

    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.active) if r is None or r.done]

    def _admit(self):
        """Fill free slots; prefill runs per admission wave (padded batch)."""
        free = self._free_slots()
        if not free or not self.queue:
            return
        admitted = []
        for i in free:
            if not self.queue:
                break
            self.active[i] = self.queue.popleft()
            admitted.append(i)
        if not admitted:
            return
        # pad all prompts to a common length, full-batch prefill
        max_len = max(len(self.active[i].prompt) for i in admitted
                      if self.active[i] is not None)
        prompts = np.zeros((self.batch, max_len), np.int32)
        for i in admitted:
            p = self.active[i].prompt
            prompts[i, -len(p):] = p     # left-pad
        cache = self.engine.model.cache_init(self.batch,
                                             self.engine.cfg.max_len)
        logits, cache = self.engine._prefill(
            self.engine.params, {"tokens": jnp.asarray(prompts)}, cache)
        self._cache = cache
        self._tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    def step(self) -> int:
        """One decode step across the active batch; returns #live requests."""
        self._admit()
        live = [r for r in self.active if r is not None and not r.done]
        if not live or self._tok is None:
            return 0
        self._tok, self._cache = self.engine._decode(
            self.engine.params, self._tok, self._cache)
        toks = np.asarray(self._tok[:, 0])
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            t = int(toks[i])
            r.generated.append(t)
            if (self.eos is not None and t == self.eos) or \
                    len(r.generated) >= r.max_new_tokens:
                r.done = True
        return sum(1 for r in self.active if r is not None and not r.done)

    def run(self, max_steps: int = 1024) -> List[Request]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return [r for r in self.active if r is not None]
