import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS") or
                           os.environ.get("XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=2")
"""Sharded kernel-mode serving self-check (DESIGN.md §10).

The first lines force host platform devices BEFORE any jax import (the
dryrun pattern) so a ≥2-device 'model' mesh exists on plain CPU.  Never
import this module from tests — run it as a subprocess:

    PYTHONPATH=src python -m repro.serving.sharded_check [--tp 2] [--bench]

Checks, emitted as one JSON object on stdout:
  1. PARITY — DeiT-Tiny-shape ``classify()`` on the sharded kernel-mode
     engine (packed int8 planes partitioned over the mesh, every linear
     through ``mxint_linear`` per shard under shard_map) equals the
     single-device ``mode='sim'`` XLA oracle BIT-FOR-BIT with the default
     column strategy; the row/psum strategy is reported with its max
     deviation (expected small, nonzero).
  2. SCHEDULING — a mixed-size request stream through
     ``ClassifyScheduler`` sustains a fixed-shape jit: after the warmup
     batch, the jit cache stays at ONE specialization.
  3. --dp N — the mesh grows a 'data' axis: batch rows shard over N data
     shards COMPOSED with the 'model' TP shards (one engine scales both
     axes).  Batch sharding is trivially bit-exact, so the same bitwise
     parity assertions run against the dp x tp engine, plus a dp-only
     (tp=1) engine when enough devices exist.
  4. --bench — off/sim/kernel(1 dev)/kernel(sharded) wall-clocks of the
     same forward, consumed by benchmarks/kernel_bench.py.
"""
import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as T
from repro.configs.deit import DEIT_TINY
from repro.core.mx_types import QuantConfig
from repro.launch.mesh import make_serving_mesh, make_tp_mesh
from repro.models import build_model
from repro.serving.engine import ServeConfig, ViTServingEngine
from repro.serving.scheduler import ClassifyRequest, ClassifyScheduler

SIM = QuantConfig(mode="sim", quantize_nonlinear=True)
KERNEL = QuantConfig(mode="kernel", quantize_nonlinear=True)


def _models(n_layers: int, n_classes: int):
    cfg = dataclasses.replace(DEIT_TINY, n_layers=n_layers,
                              n_classes=n_classes)
    m_sim = build_model(dataclasses.replace(cfg, quant=SIM))
    m_ker = build_model(dataclasses.replace(cfg, quant=KERNEL))
    params = m_sim.init(jax.random.key(0))
    return cfg, m_sim, m_ker, params


def _engine(m_ker, params, batch: int, mesh, strategy: str):
    return ViTServingEngine(
        m_ker, params,
        ServeConfig(batch=batch, pack_weights=True,
                    weight_fmt=KERNEL.weight_fmt, tp_strategy=strategy),
        mesh=mesh)


def parity_check(m_sim, m_ker, params, mesh, batch: int, image_size: int):
    rng = np.random.default_rng(0)
    imgs = np.asarray(rng.normal(size=(batch, image_size, image_size, 3)),
                      np.float32)
    want = np.asarray(jax.jit(m_sim.logits)(params, imgs))
    out = {}
    for strategy in ("column", "row"):
        eng = _engine(m_ker, params, batch, mesh, strategy)
        _, logits = eng.classify(imgs)
        got = np.asarray(logits)
        out[strategy] = {
            "bit_exact": bool(np.array_equal(got, want)),
            "max_abs_diff": float(np.max(np.abs(got - want))),
        }
    return out


def scheduler_check(m_ker, params, mesh, batch: int, image_size: int,
                    sizes=(3, 5, 1, 8, 2, 7, 4)):
    """Mixed request sizes; zero recompiles after the warmup step."""
    eng = _engine(m_ker, params, batch, mesh, "column")
    sched = ClassifyScheduler(eng)
    rng = np.random.default_rng(1)
    warm = np.asarray(rng.normal(size=(batch, image_size, image_size, 3)),
                      np.float32)
    eng.classify(warm)                          # warmup: 1 specialization
    cache_after_warmup = eng.jit_cache_size()
    for uid, n in enumerate(sizes):
        sched.submit(ClassifyRequest(
            uid=uid, images=np.asarray(
                rng.normal(size=(n, image_size, image_size, 3)), np.float32)))
    done = sched.run()
    ok_results = all(
        r.done and r.logits.shape == (sizes[r.uid], m_ker.cfg.n_classes)
        for r in done)
    return {
        "requests": len(done),
        "images": int(sum(sizes)),
        "all_classified": bool(ok_results and len(done) == len(sizes)),
        "jit_cache_after_warmup": cache_after_warmup,
        "jit_cache_after_stream": eng.jit_cache_size(),
        "recompiles_after_warmup":
            eng.jit_cache_size() - cache_after_warmup,
        # the telemetry view of the same contract (DESIGN.md §15): the
        # scheduler folds jit-cache deltas into this counter per step
        "recompiles_counter": T.counter("serving/recompiles").value,
    }


def bench_rows(m_sim, m_ker, params, mesh, batch: int, image_size: int,
               repeats: int = 3):
    """off / sim / kernel / kernel-sharded wall-clock of one forward.

    Timing goes through telemetry spans (``span/bench/<row>/ms``); the
    report is derived from ONE registry snapshot at the end, so the
    printed JSON and any exported metrics dump can never disagree."""
    from repro.serving.engine import pack_params_mxint
    rng = np.random.default_rng(2)
    imgs = jnp.asarray(rng.normal(size=(batch, image_size, image_size, 3))
                       .astype(np.float32))

    def timeit(fn, label):
        fn()                                    # compile
        for _ in range(repeats):
            with T.span(f"bench/{label}"):
                jax.block_until_ready(fn())

    cfg = m_sim.cfg
    m_off = build_model(dataclasses.replace(cfg, quant=QuantConfig()))
    timeit(lambda: jax.jit(m_off.logits)(params, imgs), "off")
    timeit(lambda: jax.jit(m_sim.logits)(params, imgs), "sim")
    packed = pack_params_mxint(params, KERNEL.weight_fmt)
    fwd1 = jax.jit(m_ker.logits)
    timeit(lambda: fwd1(packed, imgs), "kernel")
    eng = _engine(m_ker, params, batch, mesh, "column")
    tp_label = f"kernel_tp{mesh.shape['model']}"
    timeit(lambda: eng._logits(eng.params, imgs), tp_label)
    hists = T.snapshot()["histograms"]
    return {k: round(hists[f"span/bench/{k}/ms"]["mean"], 1)
            for k in ("off", "sim", "kernel", tp_label)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tp", type=int, default=2, help="model-axis shards")
    ap.add_argument("--dp", type=int, default=1, help="data-axis shards "
                    "(batch sharding composed with TP)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--classes", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--bench", action="store_true",
                    help="also time off/sim/kernel/sharded forwards")
    args = ap.parse_args(argv)

    mesh = (make_serving_mesh(args.dp, args.tp) if args.dp > 1
            else make_tp_mesh(args.tp))
    cfg, m_sim, m_ker, params = _models(args.layers, args.classes)
    report = {
        "devices": jax.device_count(),
        "tp": args.tp,
        "dp": args.dp,
        "arch": f"deit_tiny_L{args.layers}",
        "parity": parity_check(m_sim, m_ker, params, mesh, args.batch,
                               cfg.image_size),
        "scheduler": scheduler_check(m_ker, params, mesh, args.batch,
                                     cfg.image_size),
    }
    ok = (report["parity"]["column"]["bit_exact"] and
          report["scheduler"]["all_classified"] and
          report["scheduler"]["recompiles_after_warmup"] == 0)
    if args.dp > 1:
        # data-only engine (tp=1): batch shards, planes replicated — the
        # minimal 'data' axis configuration must be bit-exact too
        dp_mesh = make_serving_mesh(args.dp, 1)
        report["parity_dp_only"] = parity_check(
            m_sim, m_ker, params, dp_mesh, args.batch, cfg.image_size)
        ok = ok and report["parity_dp_only"]["column"]["bit_exact"]
    if args.bench:
        report["bench_ms"] = bench_rows(m_sim, m_ker, params, mesh,
                                        args.batch, cfg.image_size)
    report["ok"] = bool(ok)
    print(json.dumps(report))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
