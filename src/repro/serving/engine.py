"""Serving: packed-MXInt weights, prefill/decode step builders, engine.

``pack_params_mxint`` converts linear/embedding Param leaves to MXTensor
planes (int8 mantissas + int8 shared exponents) — the paper's weight
format.  The serving dry-run lowers with these packed leaves, so
``memory_analysis()`` shows the real ~4x HBM reduction (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.mx_types import MXFormat, QuantConfig
from repro.core.quantize import MXTensor, pack_weight
from repro.models.model_api import Param, is_param


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 4096
    batch: int = 8
    pack_weights: bool = False
    weight_fmt: MXFormat = None
    temperature: float = 0.0          # 0 = greedy

    def __post_init__(self):
        if self.pack_weights and self.weight_fmt is None:
            from repro.core.mx_types import MXINT6_WEIGHT
            object.__setattr__(self, "weight_fmt", MXINT6_WEIGHT)


# ---------------------------------------------------------------------------
# weight packing
# ---------------------------------------------------------------------------
_PACK_MIN_SIZE = 1 << 14       # don't pack tiny tensors (norm scales, biases)


def _should_pack(p: Param) -> bool:
    v = p.value
    shape = getattr(v, "shape", ())
    axes = p.axes
    if axes and axes[_contraction_axis(p)] is None:
        return False            # no logical contraction axis: positional
                                # tables (pos_embed, cls_token) are added,
                                # not matmul'd — never pack
    # the logical kernel excludes a leading stacked-layers dim
    eff = shape[1:] if axes and axes[0] == "layers" else shape
    if len(eff) < 2:
        return False            # norm scales / biases stay un-packed
    size = 1
    for s in shape:
        size *= s
    if size < _PACK_MIN_SIZE:
        return False
    # blocks along a tiny contraction dim (e.g. width-4 conv taps) are
    # pointless and would leave a degenerate exponent plane
    return shape[_contraction_axis(p)] >= 16


def _contraction_axis(p: Param) -> int:
    """Blocks run along the reduction dim of the consuming matmul:
      * expert-stacked kernels (E, d_in, d_out): axis 1;
      * embedding/unembedding tables (vocab, d): axis 1 (rows are looked up
        whole; unembed contracts d);
      * plain 2-D kernels (d_in, d_out): axis 0.
    Never a sharded-output axis, so shared exponents never straddle shards
    (DESIGN.md §8)."""
    axes = p.axes
    if axes and axes[0] == "expert":
        return 1
    if axes and axes[0] in ("vocab", "classes"):
        return len(axes) - 1
    return max(len(axes) - 2, 0)


_TP_LOGICAL = ("q_heads", "kv_heads", "heads", "mlp", "vocab", "expert",
               "lru")


def pack_params_mxint(params, fmt: MXFormat, abstract: bool = False,
                      tp_shards: int = 1):
    """Param tree -> Param tree with MXTensor values on large matmul
    weights.  ``abstract=True`` produces ShapeDtypeStruct planes for the
    dry-run (no allocation).

    ``tp_shards``: when the contraction axis is tensor-parallel (row-
    parallel wo/down projections), the block size is clamped to the
    PER-SHARD contraction length so shared exponents never straddle shard
    boundaries (DESIGN.md §8) and the exponent plane shards exactly like
    the mantissa plane.
    """
    import dataclasses as _dc
    from repro.core.quantize import _resolve_block

    def pack(p: Param) -> Param:
        if not _should_pack(p):
            return p
        axis = _contraction_axis(p)
        v = p.value
        k_len = v.shape[axis]
        eff_fmt = fmt
        if tp_shards > 1 and p.axes[axis] in _TP_LOGICAL and \
                k_len % tp_shards == 0:
            per_shard = k_len // tp_shards
            block = _resolve_block(per_shard, fmt.block_size)
            eff_fmt = _dc.replace(fmt, block_size=block)
        if abstract:
            block = _resolve_block(k_len, eff_fmt.block_size)
            eshape = list(v.shape)
            eshape[axis] //= block
            mx = MXTensor(
                jax.ShapeDtypeStruct(v.shape, eff_fmt.mant_dtype),
                jax.ShapeDtypeStruct(tuple(eshape), jnp.int8),
                axis - len(v.shape), eff_fmt.mant_bits, block)
        else:
            mx = pack_weight(v.astype(jnp.float32), eff_fmt, axis=axis)
        return Param(mx, p.axes)

    return jax.tree_util.tree_map(pack, params, is_leaf=is_param)


def packed_param_axes(params):
    """Axes prefix tree for packed params: MXTensor has two leaves
    (mantissa, exponent); the exponent inherits the mantissa's axes with the
    block axis shrunk — the same PartitionSpec applies to both, so the Param
    level prefix works unchanged."""
    from repro.models.model_api import axes_tree
    return axes_tree(params)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def make_prefill_step(model) -> Callable:
    cfg = model.cfg

    def prefill_step(params, batch, cache):
        if cfg.is_encoder_decoder:
            return model.prefill(params, batch["frames"], batch["tokens"],
                                 cache)
        return model.prefill(params, batch["tokens"], cache,
                             batch.get("vision_embeds"))

    return prefill_step


def make_decode_step(model, temperature: float = 0.0) -> Callable:
    def decode_step(params, tokens, cache, rng=None):
        logits, cache = model.decode_step(params, tokens, cache)
        if temperature > 0.0 and rng is not None:
            nxt = jax.random.categorical(
                rng, logits[:, -1].astype(jnp.float32) / temperature)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        return nxt.astype(jnp.int32)[:, None], cache

    return decode_step


# ---------------------------------------------------------------------------
# engine (host-side loop; used by examples and integration tests)
# ---------------------------------------------------------------------------
class ServingEngine:
    def __init__(self, model, params, serve_cfg: ServeConfig):
        self.model = model
        self.cfg = serve_cfg
        if serve_cfg.pack_weights:
            params = pack_params_mxint(params, serve_cfg.weight_fmt)
        self.params = params
        self._prefill = jax.jit(make_prefill_step(model))
        self._decode = jax.jit(make_decode_step(model,
                                                serve_cfg.temperature))

    def generate(self, batch, max_new_tokens: int = 16):
        cache = self.model.cache_init(batch["tokens"].shape[0],
                                      self.cfg.max_len)
        logits, cache = self._prefill(self.params, batch, cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = [tok]
        for _ in range(max_new_tokens - 1):
            tok, cache = self._decode(self.params, tok, cache)
            out.append(tok)
        return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# ViT classification engine (the paper's deployment scenario)
# ---------------------------------------------------------------------------
class ViTServingEngine:
    """Batched image-classification serving for ViT/DeiT models.

    The token engines above are prefill/decode state machines; a classifier
    is a stateless batched forward, so this engine only needs weight packing
    plus fixed-shape batching (requests are padded to ``serve_cfg.batch`` so
    one jit specialization serves every request size).

    With ``pack_weights=True`` and a model config in ``mode='kernel'`` this
    is the paper's full deployment: packed int8 planes in HBM, every linear
    and non-linear op on the accelerator through the Pallas MXInt kernels.
    """

    def __init__(self, model, params, serve_cfg: ServeConfig):
        self.model = model
        self.cfg = serve_cfg
        if serve_cfg.pack_weights:
            params = pack_params_mxint(params, serve_cfg.weight_fmt)
        self.params = params
        self._logits = jax.jit(model.logits)

    def classify(self, images: jnp.ndarray):
        """(n, H, W, 3) images -> (labels (n,), logits (n, classes)).

        ``n`` is arbitrary: requests are served in fixed ``cfg.batch``
        chunks, the final partial chunk zero-padded (and the padding rows
        dropped from the result).
        """
        n = images.shape[0]
        batch = self.cfg.batch
        chunks = []
        for i in range(0, n, batch):
            chunk = images[i:i + batch]
            pad = batch - chunk.shape[0]
            if pad:
                chunk = jnp.concatenate(
                    [chunk, jnp.zeros((pad,) + chunk.shape[1:],
                                      chunk.dtype)])
            logits = self._logits(self.params, chunk)
            chunks.append(logits[:batch - pad] if pad else logits)
        logits = jnp.concatenate(chunks, axis=0)
        return jnp.argmax(logits, axis=-1), logits


def make_engine(model, params, serve_cfg: ServeConfig):
    """Family-aware engine constructor."""
    if getattr(model.cfg, "family", None) == "vit":
        return ViTServingEngine(model, params, serve_cfg)
    return ServingEngine(model, params, serve_cfg)
