"""Serving: packed-MXInt weights, prefill/decode step builders, engines.

``pack_params_mxint`` converts linear/embedding Param leaves to MXTensor
planes (int8 mantissas + int8 shared exponents) — the paper's weight
format.  The serving dry-run lowers with these packed leaves, so
``memory_analysis()`` shows the real ~4x HBM reduction (DESIGN.md §8).

``ViTServingEngine`` additionally serves SHARDED: given a mesh with a
'model' axis, the packed planes are partitioned over the shards
(mantissa and exponent planes with the same PartitionSpec — they shard
together by construction) and every linear runs ``mxint_linear`` on its
local planes under ``shard_map``, bit-identical to the single-device
kernel/sim path (DESIGN.md §10).  A 'data' mesh axis composes: batch
rows shard over it (trivially bit-exact) so one engine scales both TP
and DP (DESIGN.md §12).  Continuous batching for classification lives
in ``repro.serving.scheduler.ClassifyScheduler`` (DESIGN.md §7).

Token engines batch SLOT-level: ``make_slot_prefill_step`` admits one
request into one row of a live cache, enabled by the per-row
``cache['index']`` vector (DESIGN.md §7).  Because that index is
batch-local — row i's cache state never reads another row's index —
the same 'data'-axis composition applies to LM serving: batch rows
(and their index entries) shard over 'data' with no cross-shard
traffic, bit-exact by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import telemetry as T
from repro.core.mx_types import MXFormat, QuantConfig
from repro.core.quantize import MXTensor, pack_weight
from repro.models.model_api import Param, is_param


def _note_recompiles(engine) -> None:
    """Fold the engine's ``jit_cache_size()`` into the
    ``serving/recompiles`` counter (DESIGN.md §15).

    The first observation on an engine sets its baseline without
    counting — warmup compiles are expected; every later POSITIVE delta
    is a recompile and increments the counter.  The counter is created
    eagerly so a warm, recompile-free run still exports it at 0 (the
    continuous-batching contract the metrics snapshot now witnesses).
    Engines whose jax build hides cache stats (size -1) keep the
    counter at 0 rather than guessing.
    """
    counter = T.counter("serving/recompiles")
    probe = getattr(engine, "jit_cache_size", None)
    size = probe() if probe is not None else -1
    if size < 0:                  # stats hidden (or a stub engine)
        return
    seen = getattr(engine, "_jit_cache_seen", None)
    engine._jit_cache_seen = size
    if seen is not None and size > seen:
        counter.inc(size - seen)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs.

    max_len: KV-cache capacity (token engines only).
    batch: the fixed jit batch shape — requests are padded/packed to it.
    pack_weights / weight_fmt: pack large matmul weights to MXInt planes.
    temperature: 0 = greedy decode.
    tp_strategy: how ``ViTServingEngine`` splits packed planes when given
      a mesh — 'column' (output-axis shards + all_gather, bit-exact) or
      'row' (contraction-axis shards + psum, faster on real
      interconnects but re-orders the f32 accumulation; DESIGN.md §10).
    """
    max_len: int = 4096
    batch: int = 8
    pack_weights: bool = False
    weight_fmt: MXFormat = None
    temperature: float = 0.0          # 0 = greedy
    tp_strategy: str = "column"

    def __post_init__(self):
        if self.pack_weights and self.weight_fmt is None:
            from repro.core.mx_types import MXINT6_WEIGHT
            object.__setattr__(self, "weight_fmt", MXINT6_WEIGHT)
        if self.tp_strategy not in ("column", "row"):
            raise ValueError(self.tp_strategy)


# ---------------------------------------------------------------------------
# weight packing
# ---------------------------------------------------------------------------
_PACK_MIN_SIZE = 1 << 14       # don't pack tiny tensors (norm scales, biases)


def _should_pack(p: Param) -> bool:
    v = p.value
    shape = getattr(v, "shape", ())
    axes = p.axes
    if axes and axes[_contraction_axis(p)] is None:
        return False            # no logical contraction axis: positional
                                # tables (pos_embed, cls_token) are added,
                                # not matmul'd — never pack
    # the logical kernel excludes a leading stacked-layers dim
    eff = shape[1:] if axes and axes[0] == "layers" else shape
    if len(eff) < 2:
        return False            # norm scales / biases stay un-packed
    size = 1
    for s in shape:
        size *= s
    if size < _PACK_MIN_SIZE:
        return False
    # blocks along a tiny contraction dim (e.g. width-4 conv taps) are
    # pointless and would leave a degenerate exponent plane
    return shape[_contraction_axis(p)] >= 16


def _contraction_axis(p: Param) -> int:
    """Blocks run along the reduction dim of the consuming matmul:
      * expert-stacked kernels (E, d_in, d_out): axis 1;
      * embedding/unembedding tables (vocab, d): axis 1 (rows are looked up
        whole; unembed contracts d);
      * plain 2-D kernels (d_in, d_out): axis 0.
    Never a sharded-output axis, so shared exponents never straddle shards
    (DESIGN.md §8)."""
    axes = p.axes
    if axes and axes[0] == "expert":
        return 1
    if axes and axes[0] in ("vocab", "classes"):
        return len(axes) - 1
    return max(len(axes) - 2, 0)


_TP_LOGICAL = ("q_heads", "kv_heads", "heads", "mlp", "vocab", "expert",
               "lru")


def pack_params_mxint(params, fmt: MXFormat, abstract: bool = False,
                      tp_shards: int = 1):
    """Param tree -> Param tree with MXTensor values on large matmul
    weights.  ``abstract=True`` produces ShapeDtypeStruct planes for the
    dry-run (no allocation).

    A packed (d_in, d_out) kernel becomes two planes: an int8 mantissa
    plane of the original shape and an int8 shared-exponent plane of
    shape (d_in / block, d_out) — blocks always run along the
    contraction axis (``_contraction_axis``), so both planes partition
    identically along any non-block axis.  Norm scales, biases and
    positional tables stay un-packed (``_should_pack``).

    ``tp_shards``: when the contraction axis is tensor-parallel (row-
    parallel wo/down projections; ``ServeConfig(tp_strategy='row')``),
    the block size is clamped to the PER-SHARD contraction length so
    shared exponents never straddle shard boundaries (DESIGN.md §8) and
    the exponent plane shards exactly like the mantissa plane.  The
    column-parallel serving default shards output axes only and packs
    with ``tp_shards=1`` — byte-identical to single-device packing.
    """
    import dataclasses as _dc
    from repro.core.quantize import _resolve_block

    def pack(p: Param) -> Param:
        if not _should_pack(p):
            return p
        axis = _contraction_axis(p)
        v = p.value
        k_len = v.shape[axis]
        eff_fmt = fmt
        if tp_shards > 1 and p.axes[axis] in _TP_LOGICAL and \
                k_len % tp_shards == 0:
            per_shard = k_len // tp_shards
            block = _resolve_block(per_shard, fmt.block_size)
            eff_fmt = _dc.replace(fmt, block_size=block)
        if abstract:
            block = _resolve_block(k_len, eff_fmt.block_size)
            eshape = list(v.shape)
            eshape[axis] //= block
            mx = MXTensor(
                jax.ShapeDtypeStruct(v.shape, eff_fmt.mant_dtype),
                jax.ShapeDtypeStruct(tuple(eshape), jnp.int8),
                axis - len(v.shape), eff_fmt.mant_bits, block)
        else:
            mx = pack_weight(v.astype(jnp.float32), eff_fmt, axis=axis)
        return Param(mx, p.axes)

    return jax.tree_util.tree_map(pack, params, is_leaf=is_param)


def packed_param_axes(params):
    """Axes prefix tree for packed params: MXTensor has two leaves
    (mantissa, exponent); the exponent inherits the mantissa's axes with the
    block axis shrunk — the same PartitionSpec applies to both, so the Param
    level prefix works unchanged."""
    from repro.models.model_api import axes_tree
    return axes_tree(params)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def make_prefill_step(model) -> Callable:
    cfg = model.cfg

    def prefill_step(params, batch, cache):
        if cfg.is_encoder_decoder:
            return model.prefill(params, batch["frames"], batch["tokens"],
                                 cache)
        return model.prefill(params, batch["tokens"], cache,
                             batch.get("vision_embeds"))

    return prefill_step


def make_slot_prefill_step(model, max_len: int) -> Callable:
    """Prefill ONE request into ONE slot of a LIVE batch cache.

    The slot-level admission primitive (DESIGN.md §7): runs a batch-1
    prefill of the right-padded prompt ``tokens`` (1, P) with real
    length ``length`` into a fresh temporary cache, then scatters every
    temporary leaf into row ``slot`` of the live ``cache`` along its
    'batch' axis (found via ``model.cache_axes()``), leaving the other
    rows' state untouched — which is exactly what the per-row
    ``cache['index']`` contract makes sound.  Returns ``(tok, cache)``
    where ``tok`` (1,) is the greedy first generated token.

    Shapes are fixed per P, so one jit specialization serves every
    (slot, length) pair — zero recompiles after warmup.
    """
    axes = model.cache_axes()

    def slot_prefill(params, tokens, length, slot, cache):
        tmp = model.cache_init(1, max_len)
        logits, tmp = model.prefill(params, tokens, tmp,
                                    lengths=jnp.reshape(length, (1,)))
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        tmp_leaves = treedef.flatten_up_to(tmp)
        ax_leaves = treedef.flatten_up_to(axes)
        out = []
        for dst, src, ax in zip(leaves, tmp_leaves, ax_leaves):
            bi = ax.index("batch")
            starts = tuple(slot if j == bi else jnp.int32(0)
                           for j in range(dst.ndim))
            out.append(jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), starts))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        return tok, jax.tree_util.tree_unflatten(treedef, out)

    return slot_prefill


def make_decode_step(model, temperature: float = 0.0) -> Callable:
    def decode_step(params, tokens, cache, rng=None):
        logits, cache = model.decode_step(params, tokens, cache)
        if temperature > 0.0 and rng is not None:
            nxt = jax.random.categorical(
                rng, logits[:, -1].astype(jnp.float32) / temperature)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        return nxt.astype(jnp.int32)[:, None], cache

    return decode_step


# ---------------------------------------------------------------------------
# engine (host-side loop; used by examples and integration tests)
# ---------------------------------------------------------------------------
class ServingEngine:
    def __init__(self, model, params, serve_cfg: ServeConfig):
        self.model = model
        self.cfg = serve_cfg
        if serve_cfg.pack_weights:
            params = pack_params_mxint(params, serve_cfg.weight_fmt)
        self.params = params
        self._prefill = jax.jit(make_prefill_step(model))
        self._decode = jax.jit(make_decode_step(model,
                                                serve_cfg.temperature))
        self._prefill_slot = jax.jit(
            make_slot_prefill_step(model, serve_cfg.max_len))

    def jit_cache_size(self) -> int:
        """Total jit specializations of the decode + slot-prefill steps
        (-1 when this jax build hides cache stats).  The slot-level
        batching contract: flat after warmup for ANY request mix —
        decode always sees the one (batch, 1) shape, slot prefill one
        shape per prompt-length bucket (tests/test_scheduler_properties)."""
        total = 0
        for fn in (self._decode, self._prefill_slot):
            cs = getattr(fn, "_cache_size", None)
            if cs is None:
                return -1
            total += int(cs())
        return total

    def generate(self, batch, max_new_tokens: int = 16):
        bsz, plen = batch["tokens"].shape[:2]
        T.histogram("serving/batch_size",
                    T.DEFAULT_SIZE_BUCKETS).record(bsz)
        T.histogram("serving/prefill_len",
                    T.DEFAULT_SIZE_BUCKETS).record(plen)
        with T.span("serving/generate", batch=bsz, new_tokens=max_new_tokens):
            cache = self.model.cache_init(bsz, self.cfg.max_len)
            logits, cache = self._prefill(self.params, batch, cache)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            out = [tok]
            for _ in range(max_new_tokens - 1):
                tok, cache = self._decode(self.params, tok, cache)
                out.append(tok)
            result = jnp.concatenate(out, axis=1)
        _note_recompiles(self)
        return result


# ---------------------------------------------------------------------------
# ViT classification engine (the paper's deployment scenario)
# ---------------------------------------------------------------------------
class ViTServingEngine:
    """Batched image-classification serving for ViT/DeiT models.

    The token engines above are prefill/decode state machines; a classifier
    is a stateless batched forward, so this engine only needs weight packing
    plus fixed-shape batching (requests are padded to ``serve_cfg.batch`` so
    one jit specialization serves every request size).

    With ``pack_weights=True`` and a model config in ``mode='kernel'`` this
    is the paper's full deployment: packed int8 planes in HBM, every linear
    and non-linear op on the accelerator through the Pallas MXInt kernels.

    Sharded serving: pass a ``mesh`` with a 'model' axis (e.g.
    ``repro.launch.mesh.make_tp_mesh(2)``).  The packed planes are
    device_put pre-sharded over the mesh — per-device HBM holds 1/S of
    the packed bytes — and ``classify`` runs one ``shard_map``-wrapped
    jit in which each shard feeds its local int8 planes to
    ``mxint_linear``.  With the default ``tp_strategy='column'`` the
    sharded forward is BIT-IDENTICAL to the single-device ``mode='sim'``
    oracle (asserted by tests/test_sharded_serving.py; design and
    exactness argument in DESIGN.md §10).

    Data parallelism composes: a mesh with a 'data' axis (e.g.
    ``repro.launch.mesh.make_serving_mesh(dp, tp)``) additionally shards
    the BATCH dimension — each data shard classifies ``batch/dp`` images
    through the full (model-sharded) forward.  Batch rows are
    independent everywhere in the datapath (row-wise quantizer blocks,
    per-row norms/softmax), so data sharding is trivially bit-exact and
    one engine scales both TP and DP (DESIGN.md §10/§12).  Requires
    ``serve_cfg.batch % dp == 0``; the params stay replicated over
    'data' (their PartitionSpecs name only 'model').
    """

    def __init__(self, model, params, serve_cfg: ServeConfig, mesh=None):
        self.model = model
        self.cfg = serve_cfg
        self.mesh = mesh
        tp = mesh.shape.get("model", 1) if mesh is not None else 1
        dp = mesh.shape.get("data", 1) if mesh is not None else 1
        if tp > 1 or dp > 1:
            if not serve_cfg.pack_weights:
                raise ValueError("sharded serving shards the PACKED planes; "
                                 "set ServeConfig(pack_weights=True)")
            if serve_cfg.batch % dp:
                raise ValueError(
                    f"data sharding needs batch % dp == 0, got "
                    f"batch={serve_cfg.batch} dp={dp}")
            self.params, self._logits = self._build_sharded(
                model, params, serve_cfg, mesh, tp, dp)
            return
        if serve_cfg.pack_weights:
            params = pack_params_mxint(params, serve_cfg.weight_fmt)
        self.params = params
        self._logits = jax.jit(model.logits)

    @staticmethod
    def _build_sharded(model, params, serve_cfg: ServeConfig, mesh, tp: int,
                       dp: int = 1):
        """Pack -> mark/shard planes -> device_put -> shard_map'd jit."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.sharding import (shard_map_compat,
                                             tp_shard_packed_params)
        strategy = serve_cfg.tp_strategy
        packed = pack_params_mxint(
            params, serve_cfg.weight_fmt,
            # row-parallel splits the contraction axis: clamp block sizes
            # to the per-shard length so shared exponents never straddle
            # shard boundaries.  Column-parallel never splits blocks, so
            # packing stays byte-identical to the single-device engine.
            tp_shards=tp if strategy == "row" else 1)
        if tp > 1:
            marked, specs = tp_shard_packed_params(packed, tp, "model",
                                                   strategy)
        else:
            # data-only mesh: planes stay whole and replicated (marking
            # them for a 1-way 'model' axis would emit collectives over
            # an axis the mesh may not even carry)
            marked = packed
            specs = jax.tree_util.tree_map(lambda p: P(), packed,
                                           is_leaf=is_param)

        def put(p: Param, spec) -> Param:
            ns = NamedSharding(mesh, spec)
            v = p.value
            if isinstance(v, MXTensor):
                v = v._replace(mantissa=jax.device_put(v.mantissa, ns),
                               exponent=jax.device_put(v.exponent, ns))
            else:
                v = jax.device_put(v, ns)
            return Param(v, p.axes)

        placed = jax.tree_util.tree_map(put, marked, specs, is_leaf=is_param)
        # batch sharding over 'data' (replicated when the mesh has no data
        # axis): every data shard runs the identical model-sharded forward
        # on its batch/dp rows
        img_spec = P("data") if dp > 1 else P()
        fwd = shard_map_compat(lambda p, imgs: model.logits(p, imgs),
                               mesh, in_specs=(specs, img_spec),
                               out_specs=img_spec)
        return placed, jax.jit(fwd)

    def jit_cache_size(self) -> int:
        """Number of jit specializations of the classify forward (-1 when
        this jax build does not expose cache stats).  The continuous-
        batching contract: stays at 1 after warmup for ANY request-size
        mix (tests/test_sharded_serving.py)."""
        fn = getattr(self._logits, "_cache_size", None)
        return int(fn()) if fn is not None else -1

    def logits_batch(self, chunk) -> jnp.ndarray:
        """One jitted forward on a FIXED-shape (cfg.batch, H, W, 3) chunk.

        The single funnel into ``self._logits`` — both ``classify`` and
        ``ClassifyScheduler`` go through it with an identical argument
        signature (shape/dtype/sharding), which is what keeps the jit
        cache at one specialization across arbitrary request mixes.
        """
        return self._logits(self.params, jnp.asarray(chunk))

    def classify(self, images: jnp.ndarray):
        """(n, H, W, 3) images -> (labels (n,), logits (n, classes)).

        ``n`` is arbitrary: requests are served in fixed ``cfg.batch``
        chunks, the final partial chunk zero-padded (and the padding rows
        dropped from the result).
        """
        images = jnp.asarray(images)
        n = images.shape[0]
        batch = self.cfg.batch
        T.histogram("serving/batch_size",
                    T.DEFAULT_SIZE_BUCKETS).record(batch)
        with T.span("serving/classify", images=n):
            chunks = []
            for i in range(0, n, batch):
                chunk = images[i:i + batch]
                pad = batch - chunk.shape[0]
                if pad:
                    chunk = jnp.concatenate(
                        [chunk, jnp.zeros((pad,) + chunk.shape[1:],
                                          chunk.dtype)])
                logits = self.logits_batch(chunk)
                chunks.append(logits[:batch - pad] if pad else logits)
            logits = jnp.concatenate(chunks, axis=0)
        _note_recompiles(self)
        return jnp.argmax(logits, axis=-1), logits


def make_engine(model, params, serve_cfg: ServeConfig, mesh=None):
    """Family-aware engine constructor.  ``mesh`` enables sharded serving
    for the ViT family (token engines are single-device for now)."""
    if getattr(model.cfg, "family", None) == "vit":
        return ViTServingEngine(model, params, serve_cfg, mesh=mesh)
    return ServingEngine(model, params, serve_cfg)
