"""Pallas TPU kernel: blocked (flash) attention with the MXInt softmax datapath.

Online-softmax attention over (batch*heads, seq, head_dim) operands with
BlockSpec VMEM tiling:

  grid = (bh, q_blocks, k_blocks), k innermost; running max / sum / output
  accumulator live in VMEM scratch across the k dimension.

``exp_mode``:
  'float'  — exact exp (standard flash attention; the Float baseline).
  'mxint'  — the paper's Eq. 14-19 datapath: 2^n * LUT_pow2(r) with r_bits
             fractional bits, applied to both the new-block exponentials and
             the running-accumulator rescale (both arguments are <= 0, the
             datapath's domain).

``quantize_scores`` (requires exp_mode='mxint') adds the REST of the paper
softmax (DESIGN.md §11): per-row-block MXInt quantization of the incoming
score tile (Eq. 2-3: shared exponents per ``act_block`` lanes, requantize to
the tile-row max exponent) before the exp LUT, and Eq. 20 probability
quantization before the p @ V matmul.  The final k block's matmul is
deferred to the flush so its probabilities are quantized FULLY NORMALIZED
(the true Eq. 20 output); interior blocks quantize their unnormalized
probabilities (their shared exponents absorb the pending normalization up
to the Eq. 20 mantissa divide).  When a single k block covers the whole
row this degenerates to exactly the whole-row 'paper' kernel.

Supports causal masking and sliding-window (SWA) masking — window > 0 masks
keys older than ``window`` positions (Mixtral-style).  ``kv_len`` marks
wrapper padding (keys added to reach tile multiples): padded lanes are
numerically INVISIBLE — zeroed for the quantizer's amax, excluded from the
row max, the Eq. 19 sum and the accumulator — unlike model-masked lanes,
which are filled with ``NEG_INF`` BEFORE quantization exactly as the
whole-row 'sim' datapath fills them.

``flash_attention_decode`` is the single-query variant: one query position
per KV head (the G query heads of a GQA group folded into sublane rows),
K/V streamed from the cache ring in k blocks, slot validity supplied as an
explicit ``valid`` vector (ring/window masking is the caller's slot
arithmetic, not in-kernel position math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import luts
from repro.core.quantize import _resolve_block
from repro.kernels.mxint_layernorm import (block_quantize_rows,
                                           requantize_rows,
                                           requantize_to_grid)
from repro.kernels.mxint_softmax import exp2_datapath

_LOG2E = 1.4426950408889634
# Masking sentinel, unified with models/attention.py and kernels/ops.py.
# The Eq. 2-3 score quantization runs on the MASKED tile (sim parity), so
# kernel, wrapper and model must fill with the same value — the single
# definition lives in core/mx_types.py (re-exported here for kernel code).
from repro.core.mx_types import NEG_INF
_NEG_INF = NEG_INF
# Fill value for wrapper-padding lanes during score quantization: must be
# (a) too small to ever win an act block's amax against real scores, so a
# mixed real/pad block keeps the unpadded shared exponent, and (b) nonzero,
# because an all-zero block quantizes to exponent 0 — which would RAISE the
# tile's row-max exponent above typical score exponents (~2^-6) and
# re-floor the real mantissas, breaking whole-row parity.
_PAD_FILL = 2.0 ** -100


def _softmax_block_update(s, mask, pad_mask, v, write, m_sc, l_sc, acc_sc,
                          lut, *, exp_mode: str, r_bits: int,
                          quantize_scores: bool, act_block: int,
                          mant_bits: int, kb, n_k: int):
    """Online-softmax update for one (bq, bk) score tile (DESIGN.md §11).

    ``mask`` is the MODEL mask (causal / window / cache validity): masked
    lanes are filled with NEG_INF BEFORE the Eq. 2-3 score quantization,
    matching the whole-row 'paper' datapath.  ``pad_mask`` (True = real
    key) marks wrapper padding: those lanes are numerically invisible.
    """
    s = jnp.where(mask, s, NEG_INF)
    if quantize_scores:
        if pad_mask is not None:
            # padding must not poison the shared exponents: fill with
            # _PAD_FILL for the quantizer's amax (see its comment),
            # reinstate NEG_INF after dequantization
            s = jnp.where(pad_mask, s, _PAD_FILL)
        m, e = block_quantize_rows(s, act_block, mant_bits)
        mf, lam = requantize_rows(m, e)
        # exact dequantize: integer-valued f32 mantissas times a power of
        # two — (mf_i - mf_max) * 2^lam stays exact, so the z fed to the
        # LUT is bit-identical to the whole-row kernel's mantissa-domain
        # subtract when one k block covers the row
        s = mf.reshape(s.shape) * jnp.exp2(lam.astype(jnp.float32))
    if pad_mask is not None:
        s = jnp.where(pad_mask, s, NEG_INF)

    m_prev = m_sc[...]                                     # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    if exp_mode == "mxint":
        p = exp2_datapath((s - m_new) * _LOG2E, lut, r_bits)
    else:
        p = jnp.exp(s - m_new)
    # The running rescale alpha is kept exact: the FPGA design is
    # row-at-once and never rescales, so quantizing alpha would compound
    # LUT error across k blocks with no hardware analogue — exact alpha is
    # the faithful blocked reading (DESIGN.md §11).
    alpha = jnp.exp(m_prev - m_new)
    # fully-masked row guard (SWA can mask a whole block)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)

    live = mask if pad_mask is None else (mask & pad_mask)
    if quantize_scores:
        # Eq. 19 sum includes model-masked lanes (their p is the datapath's
        # 2^-126 tail, exactly as the whole-row kernel sums them) but never
        # wrapper padding.
        p_l = p if pad_mask is None else jnp.where(pad_mask, p, 0.0)
    else:
        p = jnp.where(live, p, 0.0)
        p_l = p
    psum = jnp.sum(p_l, axis=-1, keepdims=True)

    if quantize_scores:
        @pl.when(kb < n_k - 1)
        def _interior():
            # interior blocks: probabilities leave on the MXInt act grid
            # before the p @ V matmul, still unnormalized (the Eq. 20
            # divide is a pending per-row scalar applied at flush)
            pq = requantize_to_grid(p, act_block, mant_bits)
            pq = jnp.where(live, pq, 0.0)
            l_sc[...] = l_sc[...] * alpha + psum
            acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
                pq, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_sc[...] = m_new

        @pl.when(kb == n_k - 1)
        def _flush():
            l = l_sc[...] * alpha + psum
            # Eq. 20: division in (mantissa, exponent) form
            l_m, l_e = jnp.frexp(jnp.maximum(l, 1e-30))
            inv_e = jnp.exp2(-l_e.astype(jnp.float32))
            y = (p / l_m) * inv_e
            yq = requantize_to_grid(y, act_block, mant_bits)
            yq = jnp.where(live, yq, 0.0)
            o = (acc_sc[...] * alpha) / l_m * inv_e + jax.lax.dot_general(
                yq, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            write(o)
    else:
        l_sc[...] = l_sc[...] * alpha + psum
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

        @pl.when(kb == n_k - 1)
        def _flush():
            l = l_sc[...]
            # Eq. 20: division in (mantissa, exponent) form
            l_m, l_e = jnp.frexp(jnp.maximum(l, 1e-30))
            o = acc_sc[...] / l_m * jnp.exp2(-l_e.astype(jnp.float32))
            write(o)


def _flash_kernel(q_ref, k_ref, v_ref, lut_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  scale: float, causal: bool, window: int,
                  kv_len: int | None, exp_mode: str, r_bits: int,
                  quantize_scores: bool, act_block: int, mant_bits: int,
                  block_q: int, block_k: int, n_k: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                       # (bq, d)
    k = k_ref[0].astype(jnp.float32)                       # (bk, d)
    v = v_ref[0].astype(jnp.float32)                       # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    pad_mask = (k_pos < kv_len) if kv_len is not None else None

    def write(o):
        o_ref[0] = o.astype(o_ref.dtype)

    _softmax_block_update(s, mask, pad_mask, v, write, m_sc, l_sc, acc_sc,
                          lut_ref[...], exp_mode=exp_mode, r_bits=r_bits,
                          quantize_scores=quantize_scores,
                          act_block=act_block, mant_bits=mant_bits,
                          kb=kb, n_k=n_k)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "exp_mode", "r_bits", "quantize_scores", "act_block",
    "mant_bits", "block_q", "block_k", "scale", "kv_len", "kv_groups",
    "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    exp_mode: str = "float", r_bits: int = 2,
                    quantize_scores: bool = False, act_block: int = 16,
                    mant_bits: int = 8,
                    block_q: int = 128, block_k: int = 128,
                    scale: float | None = None, kv_len: int | None = None,
                    kv_groups: int = 1,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BH // kv_groups, Sk, D).  Returns (BH, Sq, D).

    ``kv_len``: number of REAL keys when the caller padded Sk to a tile
    multiple — lanes >= kv_len are numerically invisible (see module doc).
    ``quantize_scores`` runs the full Eq. 14-20 datapath and requires
    ``exp_mode='mxint'``.  ``kv_groups``: GQA — query head b attends KV
    head b // kv_groups via the BlockSpec index map (q heads must be laid
    out KV-major), so grouped K/V are NEVER broadcast-copied.
    """
    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    assert bh == bhkv * kv_groups, (bh, bhkv, kv_groups)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    if quantize_scores:
        assert exp_mode == "mxint", "quantize_scores is the MXInt datapath"
        act_block = _resolve_block(block_k, act_block)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    n_k = sk // block_k
    lut = luts.pow2_lut(r_bits)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        kv_len=kv_len if (kv_len is not None and kv_len < sk) else None,
        exp_mode=exp_mode, r_bits=r_bits, quantize_scores=quantize_scores,
        act_block=act_block, mant_bits=mant_bits, block_q=block_q,
        block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(bh, sq // block_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, i, j: (b // kv_groups, j, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, i, j: (b // kv_groups, j, 0)),
            pl.BlockSpec((lut.shape[0],), lambda b, i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        # (batch*head, q-block) tiles are independent; the key axis
        # carries the online-softmax (m, l, acc) scratch sequentially
        # (DESIGN.md §14).
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, lut)


# ---------------------------------------------------------------------------
# single-query decode variant (DESIGN.md §11)
# ---------------------------------------------------------------------------
def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, lut_ref, o_ref,
                   m_sc, l_sc, acc_sc, *, scale: float, w_len: int | None,
                   exp_mode: str, r_bits: int, quantize_scores: bool,
                   act_block: int, mant_bits: int, block_k: int, n_k: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0].astype(jnp.float32)                    # (g, d)
    k = k_ref[0, :, 0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0, :, 0].astype(jnp.float32)                 # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    mask = jnp.broadcast_to((valid_ref[0] > 0)[None, :], s.shape)
    if w_len is not None:
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        pad_mask = k_pos < w_len
    else:
        pad_mask = None

    def write(o):
        o_ref[0, 0] = o.astype(o_ref.dtype)

    _softmax_block_update(s, mask, pad_mask, v, write, m_sc, l_sc, acc_sc,
                          lut_ref[...], exp_mode=exp_mode, r_bits=r_bits,
                          quantize_scores=quantize_scores,
                          act_block=act_block, mant_bits=mant_bits,
                          kb=kb, n_k=n_k)


@functools.partial(jax.jit, static_argnames=(
    "exp_mode", "r_bits", "quantize_scores", "act_block", "mant_bits",
    "block_k", "scale", "w_len", "interpret"))
def flash_attention_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           valid: jnp.ndarray, *, exp_mode: str = "float",
                           r_bits: int = 2, quantize_scores: bool = False,
                           act_block: int = 16, mant_bits: int = 8,
                           block_k: int = 128, scale: float | None = None,
                           w_len: int | None = None,
                           interpret: bool = True) -> jnp.ndarray:
    """Single-position decode attention over a KV cache ring.

    q: (B, Hkv, G, D) — the G query heads sharing each KV head folded
    into sublane rows, all at ONE sequence position; k, v:
    (B, W, Hkv, D) cache rings in the model's NATIVE layout — the kernel
    grid indexes the W and Hkv axes directly via BlockSpecs, so the
    caller never transposes/copies the cache per decode step; valid:
    (B, W) bool/int — nonzero where row b's slot holds a live key (the
    caller's PER-ROW ring/window slot arithmetic; a shared (W,) vector
    broadcasts over the batch).  Returns (B, Hkv, G, D).

    Invalid-but-real slots follow the model's NEG_INF masking (quantized
    with the row, sim parity); slots >= ``w_len`` are wrapper padding and
    numerically invisible.  One q block of G rows per (batch, KV head);
    K/V stream through the grid in ``block_k`` slices with online
    softmax scratch.
    """
    b, hkv, g, d = q.shape
    W = k.shape[1]
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None, :], (b, W))
    block_k = min(block_k, W)
    assert W % block_k == 0
    if quantize_scores:
        assert exp_mode == "mxint", "quantize_scores is the MXInt datapath"
        act_block = _resolve_block(block_k, act_block)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    n_k = W // block_k
    lut = luts.pow2_lut(r_bits)

    kernel = functools.partial(
        _decode_kernel, scale=scale,
        w_len=w_len if (w_len is not None and w_len < W) else None,
        exp_mode=exp_mode, r_bits=r_bits, quantize_scores=quantize_scores,
        act_block=act_block, mant_bits=mant_bits, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(b, hkv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, h, j: (i, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda i, h, j: (i, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda i, h, j: (i, j, h, 0)),
            pl.BlockSpec((1, block_k), lambda i, h, j: (i, j)),
            pl.BlockSpec((lut.shape[0],), lambda i, h, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, h, j: (i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        # (batch, kv-head) tiles are independent; the cache-window axis
        # carries the online-softmax scratch sequentially (DESIGN.md §14).
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, valid.astype(jnp.int32), lut)
