"""Pallas TPU kernel: blocked (flash) attention with optional MXInt softmax.

Online-softmax attention over (batch*heads, seq, head_dim) operands with
BlockSpec VMEM tiling:

  grid = (bh, q_blocks, k_blocks), k innermost; running max / sum / output
  accumulator live in VMEM scratch across the k dimension.

``exp_mode``:
  'float'  — exact exp (standard flash attention; the Float baseline).
  'mxint'  — the paper's Eq. 14-19 datapath: 2^n * LUT_pow2(r) with r_bits
             fractional bits, applied to both the new-block exponentials and
             the running-accumulator rescale (both arguments are <= 0, the
             datapath's domain).  This is the paper's softmax embedded in a
             fused attention kernel — beyond-paper: the FPGA design streams
             whole rows, while the TPU version never materializes the
             (Sq, Sk) score matrix at all.

Supports causal masking and sliding-window (SWA) masking — window > 0 masks
keys older than ``window`` positions (Mixtral-style).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import luts
from repro.kernels.mxint_softmax import exp2_datapath

_LOG2E = 1.4426950408889634
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, lut_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  scale: float, causal: bool, window: int, exp_mode: str,
                  r_bits: int, block_q: int, block_k: int, n_k: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, _NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                       # (bq, d)
    k = k_ref[0].astype(jnp.float32)                       # (bk, d)
    v = v_ref[0].astype(jnp.float32)                       # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_sc[...]                                     # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))

    if exp_mode == "mxint":
        # p through the paper's LUT datapath.  The running rescale alpha is
        # kept exact: the FPGA design is row-at-once and never rescales, so
        # quantizing alpha would compound LUT error across k blocks with no
        # hardware analogue — exact alpha is the faithful blocked reading.
        p = exp2_datapath((s - m_new) * _LOG2E, lut_ref[...], r_bits)
    else:
        p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, p, 0.0)
    # fully-masked row guard (SWA can mask a whole block)
    alpha = jnp.where(m_prev <= _NEG_INF / 2, 0.0, alpha)

    l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(kb == n_k - 1)
    def _flush():
        l = l_sc[...]
        # Eq. 20: division in (mantissa, exponent) form
        l_m, l_e = jnp.frexp(jnp.maximum(l, 1e-30))
        o = acc_sc[...] / l_m * jnp.exp2(-l_e.astype(jnp.float32))
        o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "exp_mode", "r_bits", "block_q", "block_k", "scale",
    "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    exp_mode: str = "float", r_bits: int = 2,
                    block_q: int = 128, block_k: int = 128,
                    scale: float | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BH, Sk, D).  Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    n_k = sk // block_k
    lut = luts.pow2_lut(r_bits)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        exp_mode=exp_mode, r_bits=r_bits, block_q=block_q, block_k=block_k,
        n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(bh, sq // block_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((lut.shape[0],), lambda b, i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lut)
