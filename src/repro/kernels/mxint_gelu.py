"""Pallas TPU kernel: MXInt GELU / SiLU datapath (paper §III-B-2, Eq. 12).

Elementwise 3-piece activation on a VMEM tile:

    y = x                      for x >= a       (ReLU tail)
    y = LUT[fix(x)]            for -a < x < a   (2^k-entry table, Fig. 6)
    y = 0                      for x <= -a

The input tile is block-quantized first so the LUT sees exactly the MXInt
value grid (the kernel's numerics match `repro.core.nonlinear.mxint_gelu`:
quantize -> lookup -> requantize onto the forwarded block exponent).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import luts
from repro.core.mx_types import NonlinearConfig
from repro.kernels.mxint_layernorm import block_quantize_rows, lut_lookup


def _mxint_gelu_kernel(x_ref, lut_ref, o_ref, *, act_block: int,
                       mant_bits: int, index_bits: int, domain: float):
    x = x_ref[...].astype(jnp.float32)                       # (br, d)
    m, e = block_quantize_rows(x, act_block, mant_bits)
    scale = jnp.exp2(e.astype(jnp.float32))[..., None]
    xq = (m * scale).reshape(x.shape)                        # on-grid values

    n = 2 ** index_bits
    idx = jnp.clip(jnp.floor((xq + domain) * (n / (2.0 * domain)))
                   .astype(jnp.int32), 0, n - 1)
    y_small = lut_lookup(idx, lut_ref[...])
    y = jnp.where(xq >= domain, xq, jnp.where(xq <= -domain, 0.0, y_small))

    # requantize onto the forwarded input exponent (paper: exponent is
    # "directly forwarded to the output")
    r, d = x.shape
    lim = float(2 ** (mant_bits - 1) - 1)
    yb = y.reshape(r, d // min(act_block, d), min(act_block, d))
    ym = jnp.clip(jnp.round(yb / scale), -lim, lim)
    o_ref[...] = (ym * scale).reshape(r, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "act_block", "mant_bits", "lut_bits", "domain", "fn", "block_rows",
    "interpret"))
def mxint_gelu(x: jnp.ndarray, *, act_block: int = 16, mant_bits: int = 8,
               lut_bits: int = 5, domain: float = 3.0, fn: str = "gelu",
               block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    """Elementwise MXInt GELU (or SiLU) over a 2-D (rows, d) array."""
    rows, d = x.shape
    br = min(block_rows, rows)
    assert rows % br == 0
    act_block = min(act_block, d)
    assert d % act_block == 0

    cfg = NonlinearConfig(gelu_lut_bits=lut_bits, gelu_domain=domain)
    if fn == "gelu":
        index_bits = cfg.gelu_index_bits
        lut = luts.gelu_lut(index_bits, domain)
        eff_domain = domain
    elif fn == "silu":
        eff_domain = 2.0 * domain
        index_bits = cfg.gelu_index_bits + 1
        import numpy as np
        nent = 2 ** index_bits
        centers = -eff_domain + (2.0 * eff_domain / nent) * (np.arange(nent) + 0.5)
        lut = jnp.asarray(centers / (1.0 + np.exp(-centers)), dtype=jnp.float32)
    else:
        raise ValueError(fn)

    kernel = functools.partial(_mxint_gelu_kernel, act_block=act_block,
                               mant_bits=mant_bits, index_bits=index_bits,
                               domain=eff_domain)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((lut.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        # Row blocks touch disjoint state: the whole grid is
        # parallel (DESIGN.md §14).
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, lut)
