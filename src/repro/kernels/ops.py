"""Public jit'd wrappers around the Pallas kernels.

These handle shape plumbing (leading-dim flattening, row padding to tile
multiples), backend selection (Pallas compiled on TPU, interpret=True on
CPU, pure-XLA fallback for odd shapes) and expose the kernels under the
names the model zoo consumes.

This module is the dispatch layer behind ``QuantConfig(mode='kernel')``:
`models/layers.py` and `models/attention.py` call these wrappers, and each
wrapper feeds the packed int8 mantissa/exponent planes (weights) or the
raw activations straight into the corresponding Pallas kernel.  Block
sizes are resolved exactly like ``repro.core.quantize`` resolves them
(clamp to the dim, largest divisor), so the kernel datapath is
numerically identical to the ``mode='sim'`` oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantize import _resolve_block
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mxint_gelu import mxint_gelu as _gelu_kernel
from repro.kernels.mxint_layernorm import mxint_layernorm as _ln_kernel
from repro.kernels.mxint_matmul import mxint_matmul as _mm_kernel
from repro.kernels.mxint_softmax import mxint_softmax as _sm_kernel

_NEG_INF = -2.0e38     # matches models/attention.py masking


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _flatten_rows(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_rows(x, multiple):
    rows = x.shape[0]
    pad = (-rows) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, rows


def _pick_block_rows(rows: int, cap: int = 256) -> int:
    for b in (cap, 128, 64, 32, 16, 8, 4, 2, 1):
        if b <= cap and rows % b == 0:
            return b
    return 1


# ---------------------------------------------------------------------------
def mxint_linear(x: jnp.ndarray, w_mant: jnp.ndarray, w_exp: jnp.ndarray,
                 bias: jnp.ndarray | None = None, *, w_block: int,
                 quantize_act: bool = False, act_block: int = 16,
                 act_mant_bits: int = 8, tp_axis: str | None = None,
                 tp_mode: str | None = None) -> jnp.ndarray:
    """y = x @ W_mx (+ bias) for arbitrary leading dims of x.

    Args:
      x: activations, float, shape (..., K).
      w_mant: packed int8 mantissa plane, shape (K, N) — or the local
        shard (K, N/S) / (K/S, N) when called inside a ``shard_map``
        with ``tp_axis`` set (DESIGN.md §10).
      w_exp: packed int8 shared-exponent plane, shape (K/w_block, N)
        (sharded exactly like ``w_mant``: the block axis is the
        contraction axis, so the exponent plane inherits the mantissa
        plane's PartitionSpec).
      bias: optional float (N,) bias, added AFTER any tensor-parallel
        collective so sharded and single-device execution add it to
        identical full-width tiles.
      w_block: weight block size the planes were packed with (static).
      quantize_act / act_block / act_mant_bits: in-kernel MXInt
        quantization of the activation tile (the full integer datapath of
        paper Fig. 2b).
      tp_axis: mesh axis name when running inside a ``shard_map`` whose
        in_specs shard the weight planes; None for single-device.
      tp_mode: 'gather' — planes are sharded along N (column-parallel):
        each shard contracts the FULL K for its column slice and the
        shards are concatenated with a tiled all_gather.  Pure data
        movement, so the result is bit-identical to the single-device
        kernel.  'psum' — planes are sharded along K (row-parallel):
        ``x`` arrives replicated with the full K, is sliced to this
        shard's K rows, and the partial products are summed with a psum.
        The f32 psum re-associates the accumulation, so this mode is
        numerically close but NOT bit-exact (DESIGN.md §10).

    The packed planes go into the Pallas kernel untouched — HBM traffic is
    the quantized bytes (the paper's memory win).  In interpret mode
    (CPU/CI) rows are padded to the sublane multiple and output columns to
    the lane multiple so ANY model shape runs through the kernel; the K
    contraction stays a single tile, which keeps the accumulation order
    identical to the XLA einsum of the 'sim' oracle (bit-exact parity).
    On TPU the MXU-aligned multi-tile path is used, falling back to the
    jnp oracle for shapes the compiled kernel cannot tile.
    """
    x2, lead = _flatten_rows(x)
    if tp_axis is not None and tp_mode == "psum":
        # row-parallel: slice the replicated activations to this shard's
        # K rows (the weight planes arrive pre-sharded along K)
        k_local = w_mant.shape[0]
        x2 = jax.lax.dynamic_slice_in_dim(
            x2, jax.lax.axis_index(tp_axis) * k_local, k_local, axis=1)
    M, K = x2.shape
    N = w_mant.shape[1]
    act_block = _resolve_block(K, act_block)
    interp = _interpret()
    if interp:
        x2p, rows = _pad_rows(x2, 8)
        npad = (-N) % 128
        wm, we = w_mant, w_exp
        if npad:
            wm = jnp.pad(wm, ((0, 0), (0, npad)))
            we = jnp.pad(we, ((0, 0), (0, npad)))
        y = _mm_kernel(x2p, wm, we, w_block=w_block,
                       act_block=act_block, act_mant_bits=act_mant_bits,
                       quantize_act=quantize_act,
                       bm=_pick_block_rows(x2p.shape[0], 128),
                       bn=128, bk=K, interpret=True)[:rows, :N]
    elif M % 8 == 0 and K % 128 == 0 and N % 128 == 0:
        bm = _pick_block_rows(M, 128)
        bk = 512 if K % 512 == 0 else 128
        bn = 128
        y = _mm_kernel(x2, w_mant, w_exp, w_block=w_block,
                       act_block=act_block, act_mant_bits=act_mant_bits,
                       quantize_act=quantize_act, bm=bm, bn=bn, bk=bk,
                       interpret=False)
    else:
        y = ref.mxint_matmul_ref(x2, w_mant, w_exp, w_block=w_block,
                                 act_block=act_block,
                                 act_mant_bits=act_mant_bits,
                                 quantize_act=quantize_act)
    if tp_axis is not None:
        if tp_mode == "gather":
            y = jax.lax.all_gather(y, tp_axis, axis=1, tiled=True)
        elif tp_mode == "psum":
            y = jax.lax.psum(y, tp_axis)
        else:
            raise ValueError(f"unknown tp_mode {tp_mode!r}")
        N = y.shape[1]
    if bias is not None:
        y = y + bias
    return y.reshape(*lead, N).astype(x.dtype)


def mxint_layernorm_op(x: jnp.ndarray, gamma: jnp.ndarray,
                       beta: jnp.ndarray | None = None, *,
                       act_block: int = 16, mant_bits: int = 8,
                       lut_bits: int = 5, rms_only: bool = False,
                       quantize_out: bool = False):
    """In-kernel MXInt LayerNorm/RMSNorm (paper Fig. 3 datapath).

    x: float (..., d) activations, normalized over the last axis.
    gamma/beta: float (d,) scale/shift (beta=None with ``rms_only``).
    act_block/mant_bits: input block-quantization format; lut_bits: width
    of the rsqrt LUT.  ``quantize_out`` appends the output MXInt
    quantize stage (the epilogue the kernel datapath feeds the next
    quantized linear with — DESIGN.md §5).  Returns float, shape of x.
    """
    x2, lead = _flatten_rows(x)
    beta_arr = beta if beta is not None else jnp.zeros_like(gamma)
    x2p, rows = _pad_rows(x2, 8)
    y = _ln_kernel(x2p, gamma, beta_arr,
                   act_block=_resolve_block(x.shape[-1], act_block),
                   mant_bits=mant_bits, lut_bits=lut_bits, rms_only=rms_only,
                   quantize_out=quantize_out,
                   block_rows=_pick_block_rows(x2p.shape[0]),
                   interpret=_interpret())
    return y[:rows].reshape(*lead, x.shape[-1])


def mxint_softmax_op(x: jnp.ndarray, *, act_block: int = 16,
                     mant_bits: int = 8, r_bits: int = 2,
                     quantize_out: bool = False) -> jnp.ndarray:
    """Whole-row MXInt softmax over the last axis (paper Eq. 14-20).

    x: float (..., S) score rows; r_bits: the exp-datapath residual LUT
    width; ``quantize_out`` quantizes the probabilities (Eq. 20) exactly
    as the FPGA streams them to the p @ V matmul.  Returns float, same
    shape (DESIGN.md §5).
    """
    x2, lead = _flatten_rows(x)
    x2p, rows = _pad_rows(x2, 8)
    y = _sm_kernel(x2p, act_block=_resolve_block(x.shape[-1], act_block),
                   mant_bits=mant_bits, r_bits=r_bits,
                   quantize_out=quantize_out,
                   block_rows=_pick_block_rows(x2p.shape[0]),
                   interpret=_interpret())
    return y[:rows].reshape(x.shape)


def mxint_gelu_op(x: jnp.ndarray, *, fn: str = "gelu", act_block: int = 16,
                  mant_bits: int = 8, lut_bits: int = 5,
                  domain: float = 3.0) -> jnp.ndarray:
    """Elementwise MXInt GELU/SiLU through the LUT datapath (paper Eq. 12).

    x: float (..., d); fn: 'gelu' | 'silu'; lut_bits/domain parameterize
    the folded LUT.  Output is MXInt-quantized by construction (the LUT
    emits mantissas).  Returns float, same shape as x.
    """
    x2, lead = _flatten_rows(x)
    x2p, rows = _pad_rows(x2, 8)
    y = _gelu_kernel(x2p, act_block=_resolve_block(x.shape[-1], act_block),
                     mant_bits=mant_bits,
                     lut_bits=lut_bits, domain=domain, fn=fn,
                     block_rows=_pick_block_rows(x2p.shape[0]),
                     interpret=_interpret())
    return y[:rows].reshape(x.shape)


def _paper_softmax_attention(qf, kf, vf, *, causal: bool, window: int,
                             scale: float, act_block: int, mant_bits: int,
                             r_bits: int, groups: int = 1) -> jnp.ndarray:
    """Whole-row attention with the Pallas MXInt softmax kernel.

    The paper's FPGA design streams entire score rows through the softmax
    datapath (no online rescale), which is also what the 'sim' oracle
    emulates — so this path is the bit-exact kernel reading of the ViT
    attention: score matmul on the MXU, Eq. 14-20 softmax in the Pallas
    kernel (including the final quantize of the probabilities), p @ V on
    the MXU.

    GQA: ``groups`` query heads share each KV head.  qf packs them as
    (b*kv_heads, groups*sq, d) — group-major rows — so K/V are contracted
    once per KV head with NO per-query-head broadcast copy; the query
    position of row i is ``i % sq``.
    """
    bh, gsq, d = qf.shape
    sq = gsq // groups
    sk = kf.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", qf.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    q_pos = (jnp.arange(gsq) % sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((gsq, sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    masked = bool(causal or window > 0)
    if masked:
        s = jnp.where(mask[None], s, _NEG_INF)
    p = mxint_softmax_op(s, act_block=act_block, mant_bits=mant_bits,
                         r_bits=r_bits, quantize_out=True)
    if masked:
        p = jnp.where(mask[None], p, 0.0)
    o = jnp.einsum("bqk,bkd->bqd", p, vf.astype(jnp.float32))
    return o.astype(qf.dtype)


def attention_op(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                 causal: bool = True, window: int = 0,
                 exp_mode: str = "float", r_bits: int = 2,
                 softmax_variant: str = "online",
                 act_block: int = 16, mant_bits: int = 8) -> jnp.ndarray:
    """(B, H, S, D) attention through the Pallas kernels.

    softmax_variant:
      'online' — blocked flash kernel (online softmax); ``exp_mode='mxint'``
                 runs the Eq. 14-19 exp LUT inside the flash kernel.  The
                 long-sequence LM path.
      'paper'  — whole-row MXInt softmax through the Pallas softmax kernel
                 (quantized scores AND quantized probabilities, Eq. 14-20
                 exactly as the FPGA streams rows).  The ViT / encoder path;
                 bit-identical to the 'sim' oracle.

    GQA: k/v may carry fewer heads than q (q heads must be a multiple,
    laid out KV-major: q[:, i] attends k[:, i // groups]).  The 'paper'
    variant folds the group dim into query rows — K/V are never copied
    per query head; the flash path broadcasts (the flash kernel wants
    matched head counts).
    """
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    sk = k.shape[2]
    groups = h // hkv
    scale = d ** -0.5
    if softmax_variant == "paper":
        o = _paper_softmax_attention(
            q.reshape(b * hkv, groups * sq, d),
            k.reshape(b * hkv, sk, d), v.reshape(b * hkv, sk, d),
            causal=causal, window=window, scale=scale, act_block=act_block,
            mant_bits=mant_bits, r_bits=r_bits, groups=groups)
        return o.reshape(b, h, sq, d)
    if groups > 1:
        k = jnp.broadcast_to(k[:, :, None], (b, hkv, groups, sk, d)
                             ).reshape(b, h, sk, d)
        v = jnp.broadcast_to(v[:, :, None], (b, hkv, groups, sk, d)
                             ).reshape(b, h, sk, d)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    if sq % 8 == 0 and sk % 128 == 0 and d % 128 == 0:
        o = flash_attention(qf, kf, vf, causal=causal, window=window,
                            exp_mode=exp_mode, r_bits=r_bits,
                            interpret=_interpret())
    else:
        o = ref.attention_ref(qf, kf, vf, causal=causal, window=window,
                              exp_mode=exp_mode, r_bits=r_bits)
    return o.reshape(b, h, sq, d)
