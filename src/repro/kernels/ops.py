"""Public jit'd wrappers around the Pallas kernels.

These handle shape plumbing (leading-dim flattening, row padding to tile
multiples), backend selection (Pallas compiled on TPU, interpret=True on
CPU, pure-XLA fallback for odd shapes) and expose the kernels under the
names the model zoo consumes.

This module is the execution layer behind the ``pallas_kernel`` datapath
backend (``QuantConfig(mode='kernel')`` — DESIGN.md §12):
``repro.datapath.pallas_kernel`` calls these wrappers, and each wrapper
feeds the packed int8 mantissa/exponent planes (weights) or the raw
activations straight into the corresponding Pallas kernel.  Block sizes
are resolved exactly like ``repro.core.quantize`` resolves them (clamp
to the dim, largest divisor), so the kernel datapath is numerically
identical to the ``mode='sim'`` oracle.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core.quantize import _resolve_block
from repro.kernels import ref
from repro.kernels.flash_attention import (NEG_INF, flash_attention,
                                           flash_attention_decode)
from repro.kernels.mxint_gelu import mxint_gelu as _gelu_kernel
from repro.kernels.mxint_layernorm import mxint_layernorm as _ln_kernel
from repro.kernels.mxint_matmul import mxint_matmul as _mm_kernel
from repro.kernels.mxint_softmax import mxint_softmax as _sm_kernel

_NEG_INF = NEG_INF     # unified sentinel (defined in core/mx_types.py)

# ---------------------------------------------------------------------------
# flash-attention fallback accounting.  The shape gate is STATIC (python
# control flow over shapes at trace time), so a fallback is counted once per
# jit specialization that takes it — exactly the granularity at which the
# Pallas kernel is or is not in the compiled program.  tests assert DeiT
# shapes never land here (ISSUE 3 acceptance).
#
# The counts live in the ``repro.telemetry`` default registry under
# ``kernels/attention_fallback/<reason>`` (DESIGN.md §15), so a metrics
# snapshot carries them alongside the serving counters.  ``FALLBACKS``
# stays importable as a read view with the Counter semantics the tests
# use (zero counts are absent, ``clear()`` resets).
# ---------------------------------------------------------------------------
_FALLBACK_PREFIX = "kernels/attention_fallback/"


class _FallbackView:
    """dict/Counter-shaped read view over the telemetry fallback
    counters; the historical ``ops.FALLBACKS`` surface."""

    def _counts(self) -> dict:
        from repro import telemetry as T
        return T.default_registry().counters_with_prefix(_FALLBACK_PREFIX)

    def __getitem__(self, reason: str) -> int:
        return self._counts().get(reason, 0)

    def __contains__(self, reason: str) -> bool:
        return reason in self._counts()

    def __iter__(self):
        return iter(self._counts())

    def __len__(self) -> int:
        return len(self._counts())

    def __eq__(self, other) -> bool:
        return self._counts() == dict(other)

    def __repr__(self) -> str:
        return f"FALLBACKS({self._counts()!r})"

    def keys(self):
        return self._counts().keys()

    def items(self):
        return self._counts().items()

    def values(self):
        return self._counts().values()

    def clear(self) -> None:
        from repro import telemetry as T
        T.reset(_FALLBACK_PREFIX)


FALLBACKS = _FallbackView()

# interpret-mode pathology guard: a (block_q, d) + 2*(block_k, d) f32 tile
# set beyond this head dim blows past any useful VMEM budget and the
# interpreter's memory; everything smaller is padded and runs in-kernel.
_FLASH_MAX_HEAD_DIM = 2048


def attention_fallback_counts() -> dict:
    """Copy of the per-reason fallback counts (trace-time granularity)."""
    return FALLBACKS._counts()


def reset_attention_fallbacks() -> None:
    FALLBACKS.clear()


def _count_fallback(reason: str, detail: str) -> None:
    from repro import telemetry as T
    T.counter(_FALLBACK_PREFIX + reason).inc()
    warnings.warn(
        f"attention_op fell back to the XLA reference ({reason}: {detail}); "
        "the Pallas flash kernel is NOT in this program (the MXInt "
        "quantization datapath, if requested, still runs via the whole-row "
        "oracle)", stacklevel=3)


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _pad_dim(x: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    spec = [(0, 0)] * x.ndim
    spec[axis] = (0, pad)
    return jnp.pad(x, spec)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _flatten_rows(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_rows(x, multiple):
    rows = x.shape[0]
    pad = (-rows) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, rows


def _pick_block_rows(rows: int, cap: int = 256) -> int:
    for b in (cap, 128, 64, 32, 16, 8, 4, 2, 1):
        if b <= cap and rows % b == 0:
            return b
    return 1


def _pick_exp_block_rows(K: int, w_block: int, bk: int) -> int | None:
    """Widen the exponent-plane fetch to the native int8 (32, 128) tile
    when the plane shape allows it (ROADMAP "int8 exponent-plane
    tiling"); None keeps the per-K-step (bk/w_block, bn) fetch."""
    if bk < w_block:
        return None
    kb = bk // w_block
    native = 32                   # int8 sublane rows
    if kb >= native or native % kb or (K // w_block) % native:
        return None
    return native


# ---------------------------------------------------------------------------
def mxint_linear(x: jnp.ndarray, w_mant: jnp.ndarray, w_exp: jnp.ndarray,
                 bias: jnp.ndarray | None = None, *, w_block: int,
                 quantize_act: bool = False, act_block: int = 16,
                 act_mant_bits: int = 8, tp_axis: str | None = None,
                 tp_mode: str | None = None) -> jnp.ndarray:
    """y = x @ W_mx (+ bias) for arbitrary leading dims of x.

    Args:
      x: activations, float, shape (..., K).
      w_mant: packed int8 mantissa plane, shape (K, N) — or the local
        shard (K, N/S) / (K/S, N) when called inside a ``shard_map``
        with ``tp_axis`` set (DESIGN.md §10).
      w_exp: packed int8 shared-exponent plane, shape (K/w_block, N)
        (sharded exactly like ``w_mant``: the block axis is the
        contraction axis, so the exponent plane inherits the mantissa
        plane's PartitionSpec).
      bias: optional float (N,) bias, added AFTER any tensor-parallel
        collective so sharded and single-device execution add it to
        identical full-width tiles.
      w_block: weight block size the planes were packed with (static).
      quantize_act / act_block / act_mant_bits: in-kernel MXInt
        quantization of the activation tile (the full integer datapath of
        paper Fig. 2b).
      tp_axis: mesh axis name when running inside a ``shard_map`` whose
        in_specs shard the weight planes; None for single-device.
      tp_mode: 'gather' — planes are sharded along N (column-parallel):
        each shard contracts the FULL K for its column slice and the
        shards are concatenated with a tiled all_gather.  Pure data
        movement, so the result is bit-identical to the single-device
        kernel.  'psum' — planes are sharded along K (row-parallel):
        ``x`` arrives replicated with the full K, is sliced to this
        shard's K rows, and the partial products are summed with a psum.
        The f32 psum re-associates the accumulation, so this mode is
        numerically close but NOT bit-exact (DESIGN.md §10).

    The packed planes go into the Pallas kernel untouched — HBM traffic is
    the quantized bytes (the paper's memory win).  In interpret mode
    (CPU/CI) rows are padded to the sublane multiple and output columns to
    the lane multiple so ANY model shape runs through the kernel; the K
    contraction stays a single tile, which keeps the accumulation order
    identical to the XLA einsum of the 'sim' oracle (bit-exact parity).
    On TPU the MXU-aligned multi-tile path is used, falling back to the
    jnp oracle for shapes the compiled kernel cannot tile.
    """
    x2, lead = _flatten_rows(x)
    if tp_axis is not None and tp_mode == "psum":
        # row-parallel: slice the replicated activations to this shard's
        # K rows (the weight planes arrive pre-sharded along K)
        k_local = w_mant.shape[0]
        x2 = jax.lax.dynamic_slice_in_dim(
            x2, jax.lax.axis_index(tp_axis) * k_local, k_local, axis=1)
    M, K = x2.shape
    N = w_mant.shape[1]
    act_block = _resolve_block(K, act_block)
    interp = _interpret()
    if interp:
        x2p, rows = _pad_rows(x2, 8)
        npad = (-N) % 128
        wm, we = w_mant, w_exp
        if npad:
            wm = jnp.pad(wm, ((0, 0), (0, npad)))
            we = jnp.pad(we, ((0, 0), (0, npad)))
        y = _mm_kernel(x2p, wm, we, w_block=w_block,
                       act_block=act_block, act_mant_bits=act_mant_bits,
                       quantize_act=quantize_act,
                       bm=_pick_block_rows(x2p.shape[0], 128),
                       bn=128, bk=K, interpret=interp)[:rows, :N]
    elif M % 8 == 0 and K % 128 == 0 and N % 128 == 0:
        bm = _pick_block_rows(M, 128)
        bk = 512 if K % 512 == 0 else 128
        bn = 128
        y = _mm_kernel(x2, w_mant, w_exp, w_block=w_block,
                       act_block=act_block, act_mant_bits=act_mant_bits,
                       quantize_act=quantize_act, bm=bm, bn=bn, bk=bk,
                       exp_block_rows=_pick_exp_block_rows(K, w_block, bk),
                       interpret=False)
    else:
        y = ref.mxint_matmul_ref(x2, w_mant, w_exp, w_block=w_block,
                                 act_block=act_block,
                                 act_mant_bits=act_mant_bits,
                                 quantize_act=quantize_act)
    if tp_axis is not None:
        if tp_mode == "gather":
            y = jax.lax.all_gather(y, tp_axis, axis=1, tiled=True)
        elif tp_mode == "psum":
            y = jax.lax.psum(y, tp_axis)
        else:
            raise ValueError(f"unknown tp_mode {tp_mode!r}")
        N = y.shape[1]
    if bias is not None:
        y = y + bias
    return y.reshape(*lead, N).astype(x.dtype)


def mxint_layernorm_op(x: jnp.ndarray, gamma: jnp.ndarray,
                       beta: jnp.ndarray | None = None, *,
                       act_block: int = 16, mant_bits: int = 8,
                       lut_bits: int = 5, rms_only: bool = False,
                       quantize_out: bool = False):
    """In-kernel MXInt LayerNorm/RMSNorm (paper Fig. 3 datapath).

    x: float (..., d) activations, normalized over the last axis.
    gamma/beta: float (d,) scale/shift (beta=None with ``rms_only``).
    act_block/mant_bits: input block-quantization format; lut_bits: width
    of the rsqrt LUT.  ``quantize_out`` appends the output MXInt
    quantize stage (the epilogue the kernel datapath feeds the next
    quantized linear with — DESIGN.md §5).  Returns float, shape of x.
    """
    x2, lead = _flatten_rows(x)
    beta_arr = beta if beta is not None else jnp.zeros_like(gamma)
    x2p, rows = _pad_rows(x2, 8)
    y = _ln_kernel(x2p, gamma, beta_arr,
                   act_block=_resolve_block(x.shape[-1], act_block),
                   mant_bits=mant_bits, lut_bits=lut_bits, rms_only=rms_only,
                   quantize_out=quantize_out,
                   block_rows=_pick_block_rows(x2p.shape[0]),
                   interpret=_interpret())
    return y[:rows].reshape(*lead, x.shape[-1])


def mxint_ln_linear_op(x: jnp.ndarray, gamma: jnp.ndarray,
                       beta: jnp.ndarray | None,
                       w_mant: jnp.ndarray, w_exp: jnp.ndarray,
                       bias: jnp.ndarray | None = None, *, w_block: int,
                       act_block: int = 16, mant_bits: int = 8,
                       lut_bits: int = 5, rms_only: bool = False,
                       tp_axis: str | None = None,
                       tp_mode: str | None = None) -> jnp.ndarray:
    """Fused MXInt LayerNorm/RMSNorm -> linear (DESIGN.md §12).

    y = MXIntLN(x) @ W_mx (+ bias) for arbitrary leading dims of x — the
    composite behind ``Datapath.layernorm_linear``: the normalized,
    act-quantized tile stays in VMEM and feeds the packed-plane
    contraction directly, removing the full HBM round-trip of the
    normalized activations that the two-kernel sequence pays.  Argument
    semantics match ``mxint_layernorm_op`` (gamma/beta/lut_bits/rms_only)
    plus ``mxint_linear`` (planes/bias/tp_axis/tp_mode); output
    quantization of the LN stage is always on (the kernel-mode epilogue).

    Bit-identical to ``mxint_layernorm_op(...)`` followed by
    ``mxint_linear(...)`` — same stages, same order, same single-tile K
    contraction; the fused VMEM scratch holds the model dtype so even the
    unfused path's dtype round-trip is reproduced.  Only the 'gather'
    tensor-parallel mode composes (the collective moves output columns —
    pure data movement after the fused kernel); 'psum' shards the
    contraction axis, which the full-row LN never sees, so callers fall
    back to the two-op sequence (``repro.datapath.pallas_kernel``).
    Shapes the kernel cannot tile fall back to that same unfused pair —
    numerically identical by the same argument.
    """
    from repro.kernels.mxint_ln_matmul import mxint_ln_matmul

    if tp_mode not in (None, "gather") or \
            (tp_axis is not None and tp_mode is None):
        # mirror mxint_linear: a sharded call with anything but 'gather'
        # fails loudly (the fused kernel and its unfused fallback must
        # never diverge on the same arguments)
        raise ValueError(f"fused ln_linear shards only with "
                         f"tp_mode='gather', got tp_axis={tp_axis!r} "
                         f"tp_mode={tp_mode!r}")
    x2, lead = _flatten_rows(x)
    M, K = x2.shape
    N = w_mant.shape[1]
    act_block = _resolve_block(K, act_block)
    interp = _interpret()
    if interp:
        x2p, rows = _pad_rows(x2, 8)
        npad = (-N) % 128
        wm, we = w_mant, w_exp
        if npad:
            wm = jnp.pad(wm, ((0, 0), (0, npad)))
            we = jnp.pad(we, ((0, 0), (0, npad)))
        y = mxint_ln_matmul(x2p, gamma, beta, wm, we, w_block=w_block,
                            act_block=act_block, mant_bits=mant_bits,
                            lut_bits=lut_bits, rms_only=rms_only,
                            bm=_pick_block_rows(x2p.shape[0], 128), bn=128,
                            interpret=interp)[:rows, :N]
    elif M % 8 == 0 and K % 128 == 0 and N % 128 == 0:
        y = mxint_ln_matmul(x2, gamma, beta, w_mant, w_exp, w_block=w_block,
                            act_block=act_block, mant_bits=mant_bits,
                            lut_bits=lut_bits, rms_only=rms_only,
                            bm=_pick_block_rows(M, 128), bn=128,
                            interpret=False)
    else:
        # untileable on compiled TPU: unfused two-kernel sequence (the
        # numerics the fused kernel replicates, so this is not a fallback
        # in the FALLBACKS sense — same datapath, one extra HBM trip)
        h = mxint_layernorm_op(
            x2.astype(jnp.float32), gamma, beta, act_block=act_block,
            mant_bits=mant_bits, lut_bits=lut_bits, rms_only=rms_only,
            quantize_out=True).astype(x.dtype)
        return mxint_linear(h, w_mant, w_exp, bias, w_block=w_block,
                            quantize_act=True, act_block=act_block,
                            act_mant_bits=mant_bits, tp_axis=tp_axis,
                            tp_mode=tp_mode).reshape(*lead, -1)
    if tp_axis is not None and tp_mode == "gather":
        y = jax.lax.all_gather(y, tp_axis, axis=1, tiled=True)
        N = y.shape[1]
    if bias is not None:
        y = y + bias
    return y.reshape(*lead, N).astype(x.dtype)


def mxint_softmax_op(x: jnp.ndarray, *, act_block: int = 16,
                     mant_bits: int = 8, r_bits: int = 2,
                     quantize_out: bool = False) -> jnp.ndarray:
    """Whole-row MXInt softmax over the last axis (paper Eq. 14-20).

    x: float (..., S) score rows; r_bits: the exp-datapath residual LUT
    width; ``quantize_out`` quantizes the probabilities (Eq. 20) exactly
    as the FPGA streams them to the p @ V matmul.  Returns float, same
    shape (DESIGN.md §5).
    """
    x2, lead = _flatten_rows(x)
    x2p, rows = _pad_rows(x2, 8)
    y = _sm_kernel(x2p, act_block=_resolve_block(x.shape[-1], act_block),
                   mant_bits=mant_bits, r_bits=r_bits,
                   quantize_out=quantize_out,
                   block_rows=_pick_block_rows(x2p.shape[0]),
                   interpret=_interpret())
    return y[:rows].reshape(x.shape)


def mxint_gelu_op(x: jnp.ndarray, *, fn: str = "gelu", act_block: int = 16,
                  mant_bits: int = 8, lut_bits: int = 5,
                  domain: float = 3.0) -> jnp.ndarray:
    """Elementwise MXInt GELU/SiLU through the LUT datapath (paper Eq. 12).

    x: float (..., d); fn: 'gelu' | 'silu'; lut_bits/domain parameterize
    the folded LUT.  Output is MXInt-quantized by construction (the LUT
    emits mantissas).  Returns float, same shape as x.
    """
    x2, lead = _flatten_rows(x)
    x2p, rows = _pad_rows(x2, 8)
    y = _gelu_kernel(x2p, act_block=_resolve_block(x.shape[-1], act_block),
                     mant_bits=mant_bits,
                     lut_bits=lut_bits, domain=domain, fn=fn,
                     block_rows=_pick_block_rows(x2p.shape[0]),
                     interpret=_interpret())
    return y[:rows].reshape(x.shape)


def _paper_softmax_attention(qf, kf, vf, *, causal: bool, window: int,
                             scale: float, act_block: int, mant_bits: int,
                             r_bits: int, groups: int = 1) -> jnp.ndarray:
    """Whole-row attention with the Pallas MXInt softmax kernel.

    The paper's FPGA design streams entire score rows through the softmax
    datapath (no online rescale), which is also what the 'sim' oracle
    emulates — so this path is the bit-exact kernel reading of the ViT
    attention: score matmul on the MXU, Eq. 14-20 softmax in the Pallas
    kernel (including the final quantize of the probabilities), p @ V on
    the MXU.

    GQA: ``groups`` query heads share each KV head.  qf packs them as
    (b*kv_heads, groups*sq, d) — group-major rows — so K/V are contracted
    once per KV head with NO per-query-head broadcast copy; the query
    position of row i is ``i % sq``.
    """
    bh, gsq, d = qf.shape
    sq = gsq // groups
    sk = kf.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", qf.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    q_pos = (jnp.arange(gsq) % sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((gsq, sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    masked = bool(causal or window > 0)
    if masked:
        s = jnp.where(mask[None], s, _NEG_INF)
    p = mxint_softmax_op(s, act_block=act_block, mant_bits=mant_bits,
                         r_bits=r_bits, quantize_out=True)
    if masked:
        p = jnp.where(mask[None], p, 0.0)
    o = jnp.einsum("bqk,bkd->bqd", p, vf.astype(jnp.float32))
    return o.astype(qf.dtype)


def attention_op(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                 causal: bool = True, window: int = 0,
                 exp_mode: str = "float", r_bits: int = 2,
                 quantize_scores: bool = False,
                 softmax_variant: str = "online",
                 act_block: int = 16, mant_bits: int = 8) -> jnp.ndarray:
    """(B, H, S, D) attention through the Pallas kernels.

    softmax_variant:
      'online' — blocked flash kernel (online softmax); ``exp_mode='mxint'``
                 runs the Eq. 14-19 exp LUT inside the flash kernel, and
                 ``quantize_scores=True`` adds the Eq. 2-3 score and Eq. 20
                 probability quantization stages (the full paper datapath,
                 blocked — DESIGN.md §11).  The long-sequence LM path.
      'paper'  — whole-row MXInt softmax through the Pallas softmax kernel
                 (quantized scores AND quantized probabilities, Eq. 14-20
                 exactly as the FPGA streams rows).  The ViT / encoder path;
                 bit-identical to the 'sim' oracle.

    Padding contract ('online' path): ANY shape reaches the flash kernel —
    query rows are padded to the sublane multiple (8), keys and head lanes
    to the lane multiple (128), and the pads are sliced off the result.
    Padded KEYS are masked inside the kernel via the static ``kv_len``
    cutoff and are numerically INVISIBLE (excluded from the quantizer's
    shared exponents, the row max, the Eq. 19 sum and the accumulator),
    unlike model-masked keys which are filled with the unified ``NEG_INF``
    sentinel BEFORE quantization (sim parity).  Padded query rows compute
    garbage that is sliced away.  The XLA reference fallback remains ONLY
    for interpret-mode pathologies (head dim beyond
    ``_FLASH_MAX_HEAD_DIM``) and is counted + warned via ``FALLBACKS`` —
    it is never taken silently.

    GQA: k/v may carry fewer heads than q (q heads must be a multiple,
    laid out KV-major: q[:, i] attends k[:, i // groups]).  Neither path
    copies K/V per query head: the 'paper' variant folds the group dim
    into query rows, the flash path maps query head b to KV head
    b // groups in its BlockSpec index map (``kv_groups``); only the
    pathological-head-dim oracle fallback broadcasts.
    """
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    sk = k.shape[2]
    groups = h // hkv
    scale = d ** -0.5
    if softmax_variant == "paper":
        o = _paper_softmax_attention(
            q.reshape(b * hkv, groups * sq, d),
            k.reshape(b * hkv, sk, d), v.reshape(b * hkv, sk, d),
            causal=causal, window=window, scale=scale, act_block=act_block,
            mant_bits=mant_bits, r_bits=r_bits, groups=groups)
        return o.reshape(b, h, sq, d)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)
    d_p = _ceil_to(d, 128)
    if d_p > _FLASH_MAX_HEAD_DIM:
        _count_fallback("head_dim", f"d={d} pads to {d_p}")
        if groups > 1:                     # oracles want matched heads
            kf = jnp.broadcast_to(k[:, :, None], (b, hkv, groups, sk, d)
                                  ).reshape(b * h, sk, d)
            vf = jnp.broadcast_to(v[:, :, None], (b, hkv, groups, sk, d)
                                  ).reshape(b * h, sk, d)
        if quantize_scores:
            # the fallback must keep the Eq. 2-3 / Eq. 20 datapath, not
            # just the exp LUT — use the whole-row quantized oracle
            o = ref.mxint_flash_attention_ref(
                qf, kf, vf, causal=causal, window=window,
                act_block=act_block, mant_bits=mant_bits, r_bits=r_bits,
                scale=scale)
        else:
            o = ref.attention_ref(qf, kf, vf, causal=causal, window=window,
                                  exp_mode=exp_mode, r_bits=r_bits,
                                  scale=scale)
    else:
        sq_p = _ceil_to(sq, 8)
        sk_p = _ceil_to(sk, 128)
        qp = _pad_dim(_pad_dim(qf, 1, sq_p), 2, d_p)
        kp = _pad_dim(_pad_dim(kf, 1, sk_p), 2, d_p)
        vp = _pad_dim(_pad_dim(vf, 1, sk_p), 2, d_p)
        o = flash_attention(qp, kp, vp, causal=causal, window=window,
                            exp_mode=exp_mode, r_bits=r_bits,
                            quantize_scores=quantize_scores,
                            act_block=act_block, mant_bits=mant_bits,
                            block_q=_pick_block_rows(sq_p, 128),
                            block_k=min(128, sk_p), scale=scale,
                            kv_len=sk if sk != sk_p else None,
                            kv_groups=groups,
                            interpret=_interpret())[:, :sq, :d]
    return o.reshape(b, h, sq, d)


def attention_decode_op(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        valid: jnp.ndarray, *, exp_mode: str = "float",
                        r_bits: int = 2, quantize_scores: bool = False,
                        act_block: int = 16,
                        mant_bits: int = 8) -> jnp.ndarray:
    """Single-position decode attention over a KV cache ring (DESIGN.md §11).

    q: (B, Hkv, G, D) — the G query heads sharing each KV head folded
    into rows, all at the current decode position; k, v: (B, W, Hkv, D)
    cache rings in the model's NATIVE layout (the kernel grid indexes W
    and Hkv directly — no per-step transpose/copy of the cache); valid:
    (B, W) bool/int — nonzero where row b's slot holds a live key (the
    caller's PER-ROW ring/window slot arithmetic; a shared (W,) vector
    broadcasts over the batch).  Returns (B, Hkv, G, D).

    Padding contract: G is padded to the sublane multiple (8), W and D to
    the lane multiple (128).  Padded SLOTS are masked via the static
    ``w_len`` cutoff and numerically invisible; invalid-but-real slots
    follow the model's NEG_INF masking through the quantizer (sim
    parity).  Fallback to the jnp oracle only for pathological head dims,
    counted + warned exactly like ``attention_op`` (and it keeps the
    Eq. 2-3 / Eq. 20 datapath via the whole-row oracle).
    """
    b, hkv, g, d = q.shape
    W = k.shape[1]
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None, :], (b, W))
    d_p = _ceil_to(d, 128)
    if d_p > _FLASH_MAX_HEAD_DIM:
        _count_fallback("head_dim", f"decode d={d} pads to {d_p}")
        qf = q.reshape(b * hkv, g, d)
        kf = jnp.einsum("bwhd->bhwd", k).reshape(b * hkv, W, d)
        vf = jnp.einsum("bwhd->bhwd", v).reshape(b * hkv, W, d)
        # per-row validity follows the (b, hkv) fold: row b's mask
        # repeats across its hkv head rows
        validf = jnp.repeat(valid, hkv, axis=0)
        if quantize_scores:
            o = ref.mxint_flash_attention_ref(
                qf, kf, vf, causal=False, key_mask=validf.astype(jnp.int32),
                act_block=act_block, mant_bits=mant_bits, r_bits=r_bits,
                scale=d ** -0.5)
        else:
            o = ref.decode_attention_ref(qf, kf, vf, validf,
                                         exp_mode=exp_mode, r_bits=r_bits)
        return o.reshape(b, hkv, g, d)
    g_p = _ceil_to(g, 8)
    W_p = _ceil_to(W, 128)
    qp = _pad_dim(_pad_dim(q, 2, g_p), 3, d_p)
    kp = _pad_dim(_pad_dim(k, 1, W_p), 3, d_p)
    vp = _pad_dim(_pad_dim(v, 1, W_p), 3, d_p)
    validp = _pad_dim(valid.astype(jnp.int32), 1, W_p)
    o = flash_attention_decode(qp, kp, vp, validp, exp_mode=exp_mode,
                               r_bits=r_bits,
                               quantize_scores=quantize_scores,
                               act_block=act_block, mant_bits=mant_bits,
                               block_k=min(128, W_p), scale=d ** -0.5,
                               w_len=W if W != W_p else None,
                               interpret=_interpret())
    return o[:, :, :g, :d]
