"""Public jit'd wrappers around the Pallas kernels.

These handle shape plumbing (leading-dim flattening, row padding to tile
multiples), backend selection (Pallas compiled on TPU, interpret=True on
CPU, pure-XLA fallback for odd shapes) and expose the kernels under the
names the model zoo consumes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mxint_gelu import mxint_gelu as _gelu_kernel
from repro.kernels.mxint_layernorm import mxint_layernorm as _ln_kernel
from repro.kernels.mxint_matmul import mxint_matmul as _mm_kernel
from repro.kernels.mxint_softmax import mxint_softmax as _sm_kernel


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _flatten_rows(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_rows(x, multiple):
    rows = x.shape[0]
    pad = (-rows) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, rows


def _pick_block_rows(rows: int, cap: int = 256) -> int:
    for b in (cap, 128, 64, 32, 16, 8, 4, 2, 1):
        if b <= cap and rows % b == 0:
            return b
    return 1


# ---------------------------------------------------------------------------
def mxint_linear(x: jnp.ndarray, w_mant: jnp.ndarray, w_exp: jnp.ndarray,
                 bias: jnp.ndarray | None = None, *, w_block: int,
                 quantize_act: bool = False, act_block: int = 16,
                 act_mant_bits: int = 8) -> jnp.ndarray:
    """y = x @ W_mx (+ bias) for arbitrary leading dims of x."""
    x2, lead = _flatten_rows(x)
    M, K = x2.shape
    N = w_mant.shape[1]
    tiled = (M % 8 == 0 and K % 128 == 0 and N % 128 == 0)
    if tiled:
        bm = _pick_block_rows(M, 128)
        bk = 512 if K % 512 == 0 else 128
        bn = 128
        y = _mm_kernel(x2, w_mant, w_exp, w_block=w_block,
                       act_block=act_block, act_mant_bits=act_mant_bits,
                       quantize_act=quantize_act, bm=bm, bn=bn, bk=bk,
                       interpret=_interpret())
    else:
        y = ref.mxint_matmul_ref(x2, w_mant, w_exp, w_block=w_block,
                                 act_block=act_block,
                                 act_mant_bits=act_mant_bits,
                                 quantize_act=quantize_act)
    if bias is not None:
        y = y + bias
    return y.reshape(*lead, N).astype(x.dtype)


def mxint_layernorm_op(x: jnp.ndarray, gamma: jnp.ndarray,
                       beta: jnp.ndarray | None = None, *,
                       act_block: int = 16, mant_bits: int = 8,
                       lut_bits: int = 5, rms_only: bool = False):
    x2, lead = _flatten_rows(x)
    beta_arr = beta if beta is not None else jnp.zeros_like(gamma)
    x2p, rows = _pad_rows(x2, 8)
    y = _ln_kernel(x2p, gamma, beta_arr, act_block=act_block,
                   mant_bits=mant_bits, lut_bits=lut_bits, rms_only=rms_only,
                   block_rows=_pick_block_rows(x2p.shape[0]),
                   interpret=_interpret())
    return y[:rows].reshape(*lead, x.shape[-1])


def mxint_softmax_op(x: jnp.ndarray, *, act_block: int = 16,
                     mant_bits: int = 8, r_bits: int = 2) -> jnp.ndarray:
    x2, lead = _flatten_rows(x)
    x2p, rows = _pad_rows(x2, 8)
    y = _sm_kernel(x2p, act_block=act_block, mant_bits=mant_bits,
                   r_bits=r_bits, block_rows=_pick_block_rows(x2p.shape[0]),
                   interpret=_interpret())
    return y[:rows].reshape(x.shape)


def mxint_gelu_op(x: jnp.ndarray, *, fn: str = "gelu", act_block: int = 16,
                  mant_bits: int = 8, lut_bits: int = 5,
                  domain: float = 3.0) -> jnp.ndarray:
    x2, lead = _flatten_rows(x)
    x2p, rows = _pad_rows(x2, 8)
    y = _gelu_kernel(x2p, act_block=act_block, mant_bits=mant_bits,
                     lut_bits=lut_bits, domain=domain, fn=fn,
                     block_rows=_pick_block_rows(x2p.shape[0]),
                     interpret=_interpret())
    return y[:rows].reshape(x.shape)


def attention_op(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                 causal: bool = True, window: int = 0,
                 exp_mode: str = "float", r_bits: int = 2) -> jnp.ndarray:
    """(B, H, S, D) attention through the flash kernel."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    if sq % 8 == 0 and sk % 128 == 0 and d % 128 == 0:
        o = flash_attention(qf, kf, vf, causal=causal, window=window,
                            exp_mode=exp_mode, r_bits=r_bits,
                            interpret=_interpret())
    else:
        o = ref.attention_ref(qf, kf, vf, causal=causal, window=window,
                              exp_mode=exp_mode, r_bits=r_bits)
    return o.reshape(b, h, sq, d)
