"""Pallas TPU kernel: MXInt matmul (paper Fig. 2b, adapted to the MXU).

The paper's dot-product unit multiplies integer mantissas and applies ONE
dynamic shift per block (the shared-exponent product).  The TPU-native
reading of that datapath:

  * weight mantissas live in HBM as int8 planes; the shared exponents are a
    (K/B, N) int8 plane — HBM->VMEM traffic is the *quantized* bytes, which
    is the paper's memory win, preserved;
  * inside the kernel each (bk, bn) mantissa tile is scaled by
    2^exponent once per block — the "one dynamic shift per block", expressed
    as a broadcasted `exp2` multiply feeding the MXU;
  * optionally the activation tile is block-quantized in-register and the
    product runs as int8 x int8 -> int32 on the MXU (2x peak vs bf16), with
    the combined scale 2^(e_x + e_w) applied on the int32 tile — the full
    integer-only datapath of Fig. 2b;
  * accumulation is a f32 VMEM scratch across the K grid dimension
    (TPU gives a lossless >=int32 accumulator for free; the paper's 12-bit
    accumulator DSE is subsumed — DESIGN.md §2).

Grid: (M/bm, N/bn, K/bk), K innermost so the accumulator stays resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _broadcast_block_exp(e_tile: jnp.ndarray, block: int) -> jnp.ndarray:
    """(kb, bn) int8 exponents -> (kb*block, bn) f32 scales, 2^e."""
    kb, bn = e_tile.shape
    s = jnp.exp2(e_tile.astype(jnp.float32))
    s = jnp.broadcast_to(s[:, None, :], (kb, block, bn))
    return s.reshape(kb * block, bn)


def _quantize_act_tile(x: jnp.ndarray, block: int, mant_bits: int):
    """In-register block quantization of an activation tile along K.

    Returns (int mantissa tile as f32-exact ints, per-block scale 2^e with
    shape (bm, bk/block)).  Mirrors repro.core.quantize numerics exactly.
    """
    bm, bk = x.shape
    xb = x.reshape(bm, bk // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)                      # (bm, kb)
    _, k = jnp.frexp(jnp.maximum(amax, jnp.finfo(jnp.float32).tiny))
    e = k - 1 - (mant_bits - 2)
    e = jnp.where(amax > 0, e, 0)
    e = jnp.clip(e, -127, 127)
    scale = jnp.exp2(-e.astype(jnp.float32))
    lim = float(2 ** (mant_bits - 1) - 1)
    m = jnp.clip(jnp.round(xb * scale[..., None]), -lim, lim)
    return m.reshape(bm, bk), jnp.exp2(e.astype(jnp.float32))


def _mxint_matmul_kernel(x_ref, wm_ref, we_ref, o_ref, acc_ref, *,
                         w_block: int, act_block: int, act_mant_bits: int,
                         quantize_act: bool, n_k: int, n_exp_sub: int = 1):
    """One (bm, bn) output tile; K accumulated across grid dim 2."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                        # (bm, bk)
    wm = wm_ref[...].astype(jnp.float32)                      # (bk, bn) ints
    e = we_ref[...]                                           # int8 exponents
    if n_exp_sub > 1:
        # The exponent block spans n_exp_sub K-steps (native-sublane
        # fetch); slice this step's (bk/w_block) rows out of it.
        kb_rows = e.shape[0] // n_exp_sub
        sub = jax.lax.rem(pl.program_id(2), n_exp_sub)
        e = jax.lax.dynamic_slice_in_dim(e, sub * kb_rows, kb_rows, axis=0)
    w_scale = _broadcast_block_exp(e, w_block)                # (bk, bn)

    if quantize_act:
        # Full integer datapath: int mantissas into the MXU, one combined
        # scale per (act-block x weight-block) pair.
        xm, x_scale = _quantize_act_tile(x, act_block, act_mant_bits)
        # Fold the per-(row x K-block) activation scale into the mantissas,
        # then one MXU contraction per tile.  On real TPU hardware this is
        # the int8 x int8 -> int32 MXU path with the combined 2^(e_x + e_w)
        # applied to the int32 tile; the f32 emulation here is exact for
        # <=11-bit mantissa products.
        bm_, bk_ = xm.shape
        nb = bk_ // act_block
        xg = (xm.reshape(bm_, nb, act_block) * x_scale[:, :, None])
        acc_ref[...] += jax.lax.dot_general(
            xg.reshape(bm_, bk_), wm * w_scale, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        w = wm * w_scale                                      # dequant once/blk
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "w_block", "act_block", "act_mant_bits", "quantize_act",
    "bm", "bn", "bk", "exp_block_rows", "interpret", "out_dtype"))
def mxint_matmul(x: jnp.ndarray, w_mant: jnp.ndarray, w_exp: jnp.ndarray, *,
                 w_block: int, act_block: int = 16, act_mant_bits: int = 8,
                 quantize_act: bool = False, bm: int = 128, bn: int = 128,
                 bk: int = 512, exp_block_rows: int | None = None,
                 interpret: bool = True,
                 out_dtype=jnp.float32) -> jnp.ndarray:
    """y[M,N] = x[M,K] @ (w_mant * 2^w_exp)[K,N] with MXInt weights.

    w_mant: (K, N) int8 mantissas; w_exp: (K/w_block, N) int8 exponents.
    exp_block_rows widens the exponent-plane fetch to that many rows per
    block (32 matches the int8 native sublane tile, so Mosaic needs no
    relayout on real hardware); the kernel slices the current K-step's
    rows out of the wider resident block.
    """
    M, K = x.shape
    K2, N = w_mant.shape
    assert K == K2, (K, K2)
    assert w_exp.shape == (K // w_block, N), (w_exp.shape, K, w_block, N)

    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % w_block == 0 or w_block % bk == 0
    if quantize_act:
        assert bk % act_block == 0
    n_k = K // bk

    n_exp_sub = 1
    if bk >= w_block:
        kb = bk // w_block
        if exp_block_rows is not None and exp_block_rows > kb:
            # Native-tile exponent fetch (ROADMAP "int8 exponent-plane
            # tiling"): one (exp_block_rows, bn) block covers
            # exp_block_rows/kb consecutive K-steps.
            assert exp_block_rows % kb == 0, (exp_block_rows, kb)
            assert (K // w_block) % exp_block_rows == 0, \
                (K, w_block, exp_block_rows)
            n_exp_sub = exp_block_rows // kb
            we_spec = pl.BlockSpec((exp_block_rows, bn),
                                   lambda i, j, k: (k // n_exp_sub, j))
        else:
            we_spec = pl.BlockSpec((kb, bn), lambda i, j, k: (k, j))
        eff_w_block = w_block
    else:
        # several K tiles share one exponent row
        ratio = w_block // bk
        we_spec = pl.BlockSpec((1, bn), lambda i, j, k: (k // ratio, j))
        eff_w_block = bk

    kernel = functools.partial(
        _mxint_matmul_kernel, w_block=eff_w_block, act_block=act_block,
        act_mant_bits=act_mant_bits, quantize_act=quantize_act, n_k=n_k,
        n_exp_sub=n_exp_sub)

    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            we_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        # M/N tiles are independent; K revisits the acc scratch and the
        # output block, so it must stay sequential (DESIGN.md §14).
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_mant, w_exp)
