"""Pallas TPU kernel: MXInt softmax datapath (paper §III-B-3, Eq. 14-20).

Row softmax with the llama.cpp-style exponential lifted into the kernel:

  1. block-quantize the row to MXInt, requantize to the row-max exponent,
  2. integer max-subtract in the mantissa domain,
  3. z = t * 2^lambda * log2(e); split z = n + r,
  4. e^x ~= 2^n * LUT_pow2(r)  (LUT_pow2 has 2^r_bits entries — 4 for the
     paper's final 2-bit design),
  5. accumulate, then divide in (mantissa, exponent) form (Eq. 20):
     frexp on the sum == the hardware's leading-zero-count + shift.

One kernel instance owns a (rows_block, n) tile; attention-shaped inputs
(b*h*q, k) stream through the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import luts
from repro.kernels.mxint_layernorm import (block_quantize_rows, lut_lookup,
                                           requantize_rows,
                                           requantize_to_grid)

_LOG2E = 1.4426950408889634


def exp2_datapath(z: jnp.ndarray, table: jnp.ndarray, r_bits: int):
    """2^z for z <= 0 via 2^n * LUT_pow2(r)."""
    n = jnp.floor(z)
    r = z - n
    nmax = 2 ** r_bits
    idx = jnp.clip(jnp.floor(r * nmax).astype(jnp.int32), 0, nmax - 1)
    p_m = lut_lookup(idx, table)
    return p_m * jnp.exp2(jnp.maximum(n, -126.0))


def _mxint_softmax_kernel(x_ref, lut_ref, o_ref, *, act_block: int,
                          mant_bits: int, r_bits: int, quantize_out: bool):
    x = x_ref[...].astype(jnp.float32)                  # (br, n)
    m, e = block_quantize_rows(x, act_block, mant_bits)
    mf, lam = requantize_rows(m, e)
    mf = mf.reshape(x.shape)
    t = mf - jnp.max(mf, axis=-1, keepdims=True)        # <= 0, mantissa units
    z = t * jnp.exp2(lam.astype(jnp.float32)) * _LOG2E
    p = exp2_datapath(z, lut_ref[...], r_bits)
    s = jnp.sum(p, axis=-1, keepdims=True)
    s_m, s_e = jnp.frexp(s)                             # LZC + shift in HW
    y = (p / s_m) * jnp.exp2(-s_e.astype(jnp.float32))
    if quantize_out:
        # probabilities leave on the MXInt act grid (the 'sim' datapath's
        # final quantize before the p @ V matmul)
        y = requantize_to_grid(y, act_block, mant_bits)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "act_block", "mant_bits", "r_bits", "quantize_out", "block_rows",
    "interpret"))
def mxint_softmax(x: jnp.ndarray, *, act_block: int = 16, mant_bits: int = 8,
                  r_bits: int = 2, quantize_out: bool = False,
                  block_rows: int = 256,
                  interpret: bool = True) -> jnp.ndarray:
    """Row softmax over the last axis of a 2-D array via the MXInt datapath."""
    rows, n = x.shape
    br = min(block_rows, rows)
    assert rows % br == 0
    act_block = min(act_block, n)
    assert n % act_block == 0, (n, act_block)
    lut = luts.pow2_lut(r_bits)

    kernel = functools.partial(_mxint_softmax_kernel, act_block=act_block,
                               mant_bits=mant_bits, r_bits=r_bits,
                               quantize_out=quantize_out)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((lut.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        # Row blocks touch disjoint state: the whole grid is
        # parallel (DESIGN.md §14).
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, lut)
