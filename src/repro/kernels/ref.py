"""Pure-jnp oracles for every Pallas kernel in this package.

Each function mirrors the corresponding kernel's *mathematics* through the
independent `repro.core` implementation path (quantize.py / nonlinear.py),
so a kernel bug and an oracle bug would have to coincide to pass the tests.
LUT contents are shared via `repro.core.luts` by construction — the tables
ARE the spec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import luts
from repro.core.mx_types import MXFormat, NonlinearConfig
from repro.core.nonlinear import (_rsqrt_datapath, exp_datapath, mxint_gelu,
                                  mxint_silu)
from repro.core.quantize import (MXTensor, dequantize, quantize,
                                 quantize_dequantize,
                                 requantize_to_max_exponent)

from repro.core.mx_types import NEG_INF as _NEG_INF

_LOG2E = 1.4426950408889634


# ---------------------------------------------------------------------------
# mxint_matmul oracle
# ---------------------------------------------------------------------------
def mxint_matmul_ref(x: jnp.ndarray, w_mant: jnp.ndarray, w_exp: jnp.ndarray,
                     *, w_block: int, act_block: int = 16,
                     act_mant_bits: int = 8,
                     quantize_act: bool = False) -> jnp.ndarray:
    """Dequantize-then-dot reference."""
    k, n = w_mant.shape
    w = MXTensor(w_mant, w_exp, 0, 8, w_block)
    wf = dequantize(w)
    xf = x.astype(jnp.float32)
    if quantize_act:
        fmt = MXFormat(mant_bits=act_mant_bits, block_size=act_block)
        xf = quantize_dequantize(xf, fmt, axis=-1)
    return jnp.dot(xf, wf, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# mxint_layernorm oracle
# ---------------------------------------------------------------------------
def mxint_layernorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                        *, act_block: int = 16, mant_bits: int = 8,
                        lut_bits: int = 5, rms_only: bool = False):
    """Quantize -> requantize -> integer LN -> LUT rsqrt, NO output requant
    (the kernel hands the scaled f32 tile to the next op)."""
    fmt = MXFormat(mant_bits=mant_bits, block_size=act_block)
    t = quantize(x, fmt, axis=-1)
    m, _lam = requantize_to_max_exponent(t, axis=-1)
    mf = m.astype(jnp.float32)
    if rms_only:
        centered = mf
    else:
        centered = mf - jnp.mean(mf, axis=-1, keepdims=True)
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = _rsqrt_datapath(var, lut_bits)
    y = centered * inv * gamma[None, :]
    if not rms_only:
        y = y + beta[None, :]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# mxint_softmax oracle
# ---------------------------------------------------------------------------
def mxint_softmax_ref(x: jnp.ndarray, *, act_block: int = 16,
                      mant_bits: int = 8, r_bits: int = 2) -> jnp.ndarray:
    fmt = MXFormat(mant_bits=mant_bits, block_size=min(act_block, x.shape[-1]))
    t = quantize(x, fmt, axis=-1)
    m, lam = requantize_to_max_exponent(t, axis=-1)
    mf = m.astype(jnp.float32)
    tt = mf - jnp.max(mf, axis=-1, keepdims=True)
    z = tt * jnp.exp2(lam.astype(jnp.float32)) * _LOG2E
    p = exp_datapath(z, r_bits)
    s = jnp.sum(p, axis=-1, keepdims=True)
    s_m, s_e = jnp.frexp(s)
    return ((p / s_m) * jnp.exp2(-s_e.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# mxint_gelu oracle
# ---------------------------------------------------------------------------
def mxint_gelu_ref(x: jnp.ndarray, *, act_block: int = 16, mant_bits: int = 8,
                   lut_bits: int = 5, domain: float = 3.0,
                   fn: str = "gelu") -> jnp.ndarray:
    fmt = MXFormat(mant_bits=mant_bits, block_size=min(act_block, x.shape[-1]))
    cfg = NonlinearConfig(gelu_lut_bits=lut_bits, gelu_domain=domain)
    t = quantize(x, fmt, axis=-1)
    out = mxint_gelu(t, cfg) if fn == "gelu" else mxint_silu(t, cfg)
    return dequantize(out).astype(x.dtype)


# ---------------------------------------------------------------------------
# flash_attention oracle
# ---------------------------------------------------------------------------
def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  exp_mode: str = "float", r_bits: int = 2,
                  scale: float | None = None) -> jnp.ndarray:
    """Unblocked attention; exp through the same datapath when requested."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    if exp_mode == "mxint":
        p = exp_datapath((s - m) * _LOG2E, r_bits)
    else:
        p = jnp.exp(s - m)
    p = jnp.where(mask[None], p, 0.0)
    sm = jnp.sum(p, axis=-1, keepdims=True)
    s_m, s_e = jnp.frexp(jnp.maximum(sm, 1e-30))
    p = (p / s_m) * jnp.exp2(-s_e.astype(jnp.float32))
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# whole-row 'paper' oracle for the quantize_scores flash datapath
# ---------------------------------------------------------------------------
def mxint_flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                              *, causal: bool = True, window: int = 0,
                              act_block: int = 16, mant_bits: int = 8,
                              r_bits: int = 2, scale: float | None = None,
                              key_mask: jnp.ndarray | None = None
                              ) -> jnp.ndarray:
    """Whole-row Eq. 14-20 attention oracle (DESIGN.md §11).

    The full paper softmax on MASKED score rows: Eq. 2-3 score
    quantization (the NEG_INF fill goes through the quantizer, sim
    parity), Eq. 14-19 exp LUT, Eq. 20 divide, probability quantization
    onto the act grid, zero the masked lanes, then p @ V.  This is what
    ``flash_attention(exp_mode='mxint', quantize_scores=True)`` computes
    blocked; when one k block covers the row the kernel matches this
    oracle exactly.  ``key_mask``: optional (Sk,) or per-row (BH, Sk)
    validity (the decode variant's ring mask).
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    mask = mask[None]                                      # (1, sq, sk)
    if key_mask is not None:
        km = (key_mask > 0)
        mask = mask & (km[:, None, :] if km.ndim == 2 else km[None, None, :])
    s = jnp.where(mask, s, _NEG_INF)
    fmt = MXFormat(mant_bits, act_block)
    t = quantize(s, fmt, axis=-1)
    m, lam = requantize_to_max_exponent(t, axis=-1)
    mf = m.astype(jnp.float32)
    tt = mf - jnp.max(mf, axis=-1, keepdims=True)
    z = tt * jnp.exp2(lam.astype(jnp.float32)) * _LOG2E
    p = exp_datapath(z, r_bits)
    sm = jnp.sum(p, axis=-1, keepdims=True)
    s_m, s_e = jnp.frexp(jnp.maximum(sm, 1e-30))
    y = (p / s_m) * jnp.exp2(-s_e.astype(jnp.float32))
    y = quantize_dequantize(y, fmt, axis=-1)
    y = jnp.where(mask, y, 0.0)
    return jnp.einsum("bqk,bkd->bqd", y, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode-variant oracle
# ---------------------------------------------------------------------------
def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid: jnp.ndarray, *, exp_mode: str = "float",
                         r_bits: int = 2,
                         scale: float | None = None) -> jnp.ndarray:
    """Unblocked single-position decode oracle.

    q: (BH, G, D); k, v: (BH, W, D) cache rings; valid: (W,) shared or
    (BH, W) per-row slot validity.  Masked softmax over the ring with
    the requested exp datapath — the jnp mirror of
    ``flash_attention_decode``.
    """
    bh, g, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bgd,bwd->bgw", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    vm = valid > 0
    mask = vm[:, None, :] if vm.ndim == 2 else vm[None, None, :]
    s = jnp.where(mask, s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    if exp_mode == "mxint":
        p = exp_datapath((s - m) * _LOG2E, r_bits)
    else:
        p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    sm = jnp.sum(p, axis=-1, keepdims=True)
    s_m, s_e = jnp.frexp(jnp.maximum(sm, 1e-30))
    p = (p / s_m) * jnp.exp2(-s_e.astype(jnp.float32))
    return jnp.einsum("bgw,bwd->bgd", p, v.astype(jnp.float32)).astype(q.dtype)
