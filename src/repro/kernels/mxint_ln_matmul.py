"""Pallas TPU kernel: fused MXInt LayerNorm -> matmul (DESIGN.md §12).

The unfused kernel path runs Fig. 3 LayerNorm and the consuming quantized
linear as two ``pallas_call``s: the normalized, act-quantized tile is
written to HBM by the first kernel and read straight back by the second —
a full round-trip of (rows, d) activation bytes that exists only because
the ops are separate program launches.  This kernel fuses them: the
LayerNorm datapath runs once per row block into a VMEM scratch, and every
N-tile of the matmul contracts directly against that resident tile.

Grid: (rows/bm, N/bn), N innermost — the same scratch-persistence pattern
as the matmul accumulator, but inverted: instead of one output tile
surviving across K steps, one *input* tile survives across N steps.

  j == 0:  x tile (bm, d) -> block-quantize -> row-max requantize ->
           integer mean/var -> rsqrt LUT -> gamma/beta -> output
           quantization (Eq. 2-3 epilogue) -> VMEM scratch ``y``
           (stored in the model dtype, so the scratch round-trip is
           bit-identical to the unfused HBM round-trip);
  all j:   y -> in-register act quantization -> mantissa x mantissa
           contraction against the packed (d, bn) weight planes
           (identical stages to mxint_matmul with quantize_act=True).

Bit-exactness vs the unfused sequence holds by construction: both paths
execute the same float ops in the same order on the same tiles (the K
contraction is a single tile in both, matching the interpret-mode
``mxint_linear``); asserted in tests/test_datapath.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import luts
from repro.kernels.mxint_layernorm import (_rsqrt_lut_stage,
                                           block_quantize_rows,
                                           requantize_rows,
                                           requantize_to_grid)
from repro.kernels.mxint_matmul import (_broadcast_block_exp,
                                        _quantize_act_tile)


def _mxint_ln_matmul_kernel(x_ref, g_ref, b_ref, lut_ref, wm_ref, we_ref,
                            o_ref, y_ref, *, act_block: int, mant_bits: int,
                            lut_bits: int, rms_only: bool, w_block: int):
    """One (bm, bn) output tile; the LN stage runs only at j == 0 and its
    result stays resident in the ``y_ref`` VMEM scratch for every j."""

    @pl.when(pl.program_id(1) == 0)
    def _ln():
        x = x_ref[...].astype(jnp.float32)             # (bm, d)
        m, e = block_quantize_rows(x, act_block, mant_bits)
        mf, _ = requantize_rows(m, e)                  # lambda cancels
        mf = mf.reshape(x.shape)
        if rms_only:
            centered = mf
        else:
            centered = mf - jnp.mean(mf, axis=-1, keepdims=True)
        var = jnp.mean(centered * centered, axis=-1, keepdims=True)
        inv = _rsqrt_lut_stage(var, lut_ref[...], lut_bits)
        y = centered * inv
        y = y * g_ref[...][None, :]
        if not rms_only:
            y = y + b_ref[...][None, :]
        y = requantize_to_grid(y, act_block, mant_bits)
        y_ref[...] = y.astype(y_ref.dtype)

    # matmul stage — identical to _mxint_matmul_kernel's quantize_act path
    # with a single K tile (bk == d)
    y = y_ref[...].astype(jnp.float32)                 # (bm, d)
    wm = wm_ref[...].astype(jnp.float32)               # (d, bn) ints
    w_scale = _broadcast_block_exp(we_ref[...], w_block)
    xm, x_scale = _quantize_act_tile(y, act_block, mant_bits)
    bm_, bk_ = xm.shape
    nb = bk_ // act_block
    xg = (xm.reshape(bm_, nb, act_block) * x_scale[:, :, None])
    o_ref[...] = jax.lax.dot_general(
        xg.reshape(bm_, bk_), wm * w_scale, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "w_block", "act_block", "mant_bits", "lut_bits", "rms_only",
    "bm", "bn", "interpret"))
def mxint_ln_matmul(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                    w_mant: jnp.ndarray, w_exp: jnp.ndarray, *,
                    w_block: int, act_block: int = 16, mant_bits: int = 8,
                    lut_bits: int = 5, rms_only: bool = False,
                    bm: int = 128, bn: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """y[M,N] = MXIntLN(x)[M,K] @ (w_mant * 2^w_exp)[K,N], one kernel.

    x: (rows, d) activations (any float dtype — the LN stage computes in
    f32 and the scratch holds the model dtype); gamma/beta: (d,) scale /
    shift (beta ignored with ``rms_only``); w_mant: (d, N) int8 mantissas;
    w_exp: (d/w_block, N) int8 shared exponents.  The output is NOT
    bias-added (the wrapper adds bias after any tensor-parallel
    collective, like ``mxint_linear``).
    """
    rows, d = x.shape
    K, N = w_mant.shape
    assert K == d, (K, d)
    assert d % w_block == 0, (d, w_block)
    assert w_exp.shape == (d // w_block, N), (w_exp.shape, d, w_block, N)
    bm = min(bm, rows)
    bn = min(bn, N)
    assert rows % bm == 0 and N % bn == 0, (rows, N, bm, bn)
    assert d % min(act_block, d) == 0
    act_block = min(act_block, d)
    lut = luts.rsqrt_lut(lut_bits)
    beta_arr = beta if beta is not None else jnp.zeros_like(gamma)

    kernel = functools.partial(
        _mxint_ln_matmul_kernel, act_block=act_block, mant_bits=mant_bits,
        lut_bits=lut_bits, rms_only=rms_only, w_block=w_block)

    return pl.pallas_call(
        kernel,
        grid=(rows // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((lut.shape[0],), lambda i, j: (0,)),
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),
            pl.BlockSpec((d // w_block, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, d), x.dtype)],
        # Row blocks are independent; the N axis reuses the normalised
        # tile cached in scratch at j == 0, so it must run in order
        # (DESIGN.md §14).
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, gamma, beta_arr, lut, w_mant, w_exp)
