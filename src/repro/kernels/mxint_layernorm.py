"""Pallas TPU kernel: MXInt LayerNorm / RMSNorm datapath (paper Fig. 3).

Stages inside one kernel invocation (a (rows_block, d) tile resident in
VMEM):

  1. block-quantize the activation row to MXInt (act_block shared exponents),
  2. requantize every block to the row-max exponent — integer right shifts,
  3. integer mean / variance on mantissas (lambda cancels, Eq. 5-7),
  4. variance -> (v_m, v_e); 1/sqrt via the tiny LUT with the even/odd
     exponent split of Eq. 9; exponent handled by shift,
  5. scale, gamma/beta, write.

The LUT lives in VMEM and is applied as a one-hot contraction — on TPU a
32-entry lookup over a (rows, d) tile is a (rows*d, 32) x (32,) matvec, which
the MXU eats for free; this is the TPU-native analogue of the FPGA LUT
(DESIGN.md §2) and is bit-identical to `jnp.take` (one-hot rows select a
single f32 entry exactly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import luts


def lut_lookup(idx: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """One-hot-matmul LUT gather (MXU-friendly, exact)."""
    entries = table.shape[0]
    onehot = (idx[..., None] == jnp.arange(entries, dtype=jnp.int32)
              ).astype(table.dtype)
    return jax.lax.dot_general(
        onehot.reshape(-1, entries), table[:, None],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(idx.shape)


def block_quantize_rows(x: jnp.ndarray, block: int, mant_bits: int):
    """Quantize (rows, d) along d in blocks; returns (mantissa f32, exp i32)."""
    r, d = x.shape
    xb = x.reshape(r, d // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    _, k = jnp.frexp(jnp.maximum(amax, jnp.finfo(jnp.float32).tiny))
    e = jnp.where(amax > 0, k - 1 - (mant_bits - 2), 0)
    e = jnp.clip(e, -127, 127)
    lim = float(2 ** (mant_bits - 1) - 1)
    m = jnp.clip(jnp.round(xb * jnp.exp2(-e.astype(jnp.float32))[..., None]),
                 -lim, lim)
    return m, e.astype(jnp.int32)                      # (r, nb, blk), (r, nb)


def requantize_rows(m: jnp.ndarray, e: jnp.ndarray):
    """Align all blocks of each row to the row-max exponent (Eq. 3)."""
    e_max = jnp.max(e, axis=-1, keepdims=True)
    shift = jnp.minimum(e_max - e, 31)
    # arithmetic right shift on integer-valued f32 mantissas: floor of the
    # exact power-of-two scale matches >> for the int32 the hardware holds
    # (incl. negatives, floor -> -inf), and unlike `1 << shift` it cannot
    # overflow at the shift=31 saturation point (hit when masked -inf
    # scores share a row with real scores).
    mi = jnp.floor(m * jnp.exp2(-shift.astype(jnp.float32))[..., None])
    return mi, e_max


def requantize_to_grid(y: jnp.ndarray, block: int, mant_bits: int):
    """Snap a (rows, d) tile onto the MXInt act grid (quantize-dequantize).

    The shared epilogue of the LayerNorm and softmax kernels: the 'sim'
    datapath quantizes each op's output back to act_fmt before the next op
    consumes it.
    """
    m, e = block_quantize_rows(y, block, mant_bits)
    return (m * jnp.exp2(e.astype(jnp.float32))[..., None]).reshape(y.shape)


def _rsqrt_lut_stage(var: jnp.ndarray, table: jnp.ndarray, bits: int):
    var = jnp.maximum(var, 2.0 ** -24)
    v_m, v_e = jnp.frexp(var)
    v_m, v_e = v_m * 2.0, v_e - 1
    odd = (v_e % 2) != 0
    u = jnp.where(odd, v_m * 0.5, v_m)
    e_half = jnp.where(odd, (v_e + 1) // 2, v_e // 2)
    n = 2 ** bits
    idx = jnp.clip(jnp.floor((u - 0.5) * (n / 1.5)).astype(jnp.int32), 0, n - 1)
    r = lut_lookup(idx, table)
    return r * jnp.exp2(-e_half.astype(jnp.float32))


def _mxint_layernorm_kernel(x_ref, g_ref, b_ref, lut_ref, o_ref, *,
                            act_block: int, mant_bits: int, lut_bits: int,
                            rms_only: bool, quantize_out: bool):
    x = x_ref[...].astype(jnp.float32)                 # (br, d)
    m, e = block_quantize_rows(x, act_block, mant_bits)
    mf, _ = requantize_rows(m, e)                      # lambda cancels
    mf = mf.reshape(x.shape)
    if rms_only:
        centered = mf
    else:
        centered = mf - jnp.mean(mf, axis=-1, keepdims=True)
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = _rsqrt_lut_stage(var, lut_ref[...], lut_bits)
    y = centered * inv
    y = y * g_ref[...][None, :]
    if not rms_only:
        y = y + b_ref[...][None, :]
    if quantize_out:
        y = requantize_to_grid(y, act_block, mant_bits)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "act_block", "mant_bits", "lut_bits", "rms_only", "quantize_out",
    "block_rows", "interpret"))
def mxint_layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, *,
                    act_block: int = 16, mant_bits: int = 8,
                    lut_bits: int = 5, rms_only: bool = False,
                    quantize_out: bool = False,
                    block_rows: int = 256, interpret: bool = True):
    """(rows, d) MXInt LayerNorm over the last axis."""
    rows, d = x.shape
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    assert d % min(act_block, d) == 0
    act_block = min(act_block, d)
    lut = luts.rsqrt_lut(lut_bits)

    kernel = functools.partial(
        _mxint_layernorm_kernel, act_block=act_block, mant_bits=mant_bits,
        lut_bits=lut_bits, rms_only=rms_only, quantize_out=quantize_out)

    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((lut.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        # Row blocks touch disjoint state: the whole grid is
        # parallel (DESIGN.md §14).
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, gamma, beta, lut)
