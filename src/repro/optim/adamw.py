"""AdamW on raw pytrees (Param wrappers pass through transparently).

Moments are stored in f32 regardless of param dtype (bf16 params keep f32
master statistics; the update is computed in f32 and cast back).  Moment
trees share the params' logical sharding axes, so optimizer state shards
exactly like the parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    f32zeros = lambda v: jnp.zeros(v.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(f32zeros, params),
        nu=jax.tree_util.tree_map(f32zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), \
        norm


def adamw_update(grads, state: AdamWState, params, lr: jnp.ndarray,
                 cfg: AdamWConfig):
    """Returns (new_params, new_state, grad_norm)."""
    if cfg.clip_norm > 0:
        grads, norm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        norm = global_norm(grads)
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m_new, v_new

    flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    is_triple = lambda x: (isinstance(x, tuple) and len(x) == 3
                           and not hasattr(x, "_fields"))
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=is_triple)
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_triple)
    new_nu = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=is_triple)
    return new_params, AdamWState(step, new_mu, new_nu), norm
