"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak: float):
    return peak * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))


def cosine_schedule(step, *, peak: float, warmup_steps: int,
                    total_steps: int, floor: float = 0.0):
    warm = linear_warmup(step, warmup_steps, peak)
    t = jnp.clip((step - warmup_steps) /
                 max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)


def constant_schedule(step, peak: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), peak)
