"""repro.core — the paper's contribution: MXInt formats + datapaths."""
from repro.core.mx_types import (MXFormat, NonlinearConfig, QuantConfig,
                                 MXINT8_ACT, MXINT8_WEIGHT, MXINT6_WEIGHT,
                                 MXINT6_ACT, MXINT4_WEIGHT, MXINT8_OCP,
                                 NEG_INF, PEAK_FLOPS_BF16, PEAK_FLOPS_INT8,
                                 HBM_BW, ICI_BW)
from repro.core.quantize import (MXTensor, quantize, dequantize,
                                 quantize_dequantize, fake_quant,
                                 requantize_to_max_exponent, pack_weight,
                                 packed_bytes)
from repro.core.nonlinear import (mxint_layernorm, mxint_gelu, mxint_silu,
                                  mxint_softmax, exp_datapath,
                                  softmax_value, layernorm_value, gelu_value,
                                  silu_value, fixedpoint_layernorm,
                                  fixedpoint_gelu, relu6_gelu,
                                  fixedpoint_softmax)
from repro.core import luts, search, gradient_compression

__all__ = [
    "MXFormat", "NonlinearConfig", "QuantConfig", "MXTensor",
    "MXINT8_ACT", "MXINT8_WEIGHT", "MXINT6_WEIGHT", "MXINT6_ACT",
    "MXINT4_WEIGHT", "MXINT8_OCP", "NEG_INF", "PEAK_FLOPS_BF16",
    "PEAK_FLOPS_INT8", "HBM_BW", "ICI_BW",
    "quantize", "dequantize", "quantize_dequantize", "fake_quant",
    "requantize_to_max_exponent", "pack_weight", "packed_bytes",
    "mxint_layernorm", "mxint_gelu", "mxint_silu", "mxint_softmax",
    "luts", "search", "gradient_compression",
]
