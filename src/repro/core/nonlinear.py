"""Bit-accurate MXInt datapaths for LayerNorm, GELU and Softmax (§III-B).

These are the *correctness oracles* for the Pallas kernels and the engines
behind the paper's accuracy tables (Tables II-IV, VI) and DSE figures
(Figs 4, 7, 8, 9).  Every step mirrors a hardware stage:

  LayerNorm (Fig 3):  requantize-to-max-exponent -> integer mean/var ->
                      variance rescale to (v_m, v_e) -> LUT_{1/sqrt}(v_m)
                      with the even/odd exponent split of Eq. 9.
  GELU (Fig 6):       ReLU tails + LUT over [-a, a) (Eq. 12), exponent
                      forwarded from input to output.
  Softmax (Eq 14-20): max-subtract in the shared-exponent domain,
                      e^x = 2^n * LUT_pow2(r), division in (mantissa,
                      exponent) form.

Also provided: fixed-point emulations of the related-work datapaths the paper
compares against (8-bit integer LayerNorm/GELU/Softmax, SDA's ReLU6-GELU) so
the comparison tables can be reproduced.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import luts
from repro.core.mx_types import MXFormat, NonlinearConfig
from repro.core.quantize import (MXTensor, dequantize, quantize,
                                 requantize_to_max_exponent)

_LOG2E = 1.4426950408889634


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _quantize_with_exponent(y: jnp.ndarray, exponent: jnp.ndarray,
                            block: int, axis: int, mant_bits: int) -> MXTensor:
    """Quantize ``y`` onto a *given* per-block exponent (paper: GELU forwards
    the input exponent to the output)."""
    axis = axis % y.ndim
    scale = jnp.exp2(-exponent.astype(jnp.float32))
    scale = jnp.repeat(scale, block, axis=axis)
    m = jnp.clip(jnp.round(y * scale),
                 -(2 ** (mant_bits - 1)), 2 ** (mant_bits - 1) - 1)
    fmt = MXFormat(mant_bits=mant_bits, block_size=block)
    return MXTensor(m.astype(fmt.mant_dtype), exponent, axis - y.ndim,
                    mant_bits, block)


def _rsqrt_datapath(var: jnp.ndarray, lut_bits: int) -> jnp.ndarray:
    """Paper Eq. 8-9: 1/sqrt(var) via mantissa LUT + exponent shift.

    var is a positive fixed-point value (float-emulated).  Returns the
    approximated 1/sqrt(var).
    """
    # Guard the Var -> 0 corner the paper ignores (DESIGN.md §8): clamp to
    # one LSB of the accumulator.
    var = jnp.maximum(var, 2.0 ** -24)
    v_m, v_e = jnp.frexp(var)          # var = v_m * 2^v_e, v_m in [0.5, 1)
    v_m = v_m * 2.0                    # normalize to [1, 2)
    v_e = v_e - 1
    odd = (v_e % 2) != 0
    u = jnp.where(odd, v_m * 0.5, v_m)             # [0.5, 2)
    e_half = jnp.where(odd, (v_e + 1) // 2, v_e // 2)
    lut = luts.rsqrt_lut(lut_bits)
    r = jnp.take(lut, luts.rsqrt_index(u, lut_bits))
    return r * jnp.exp2(-e_half.astype(jnp.float32))


# ---------------------------------------------------------------------------
# LayerNorm (paper §III-B-1)
# ---------------------------------------------------------------------------
def mxint_layernorm(x: MXTensor,
                    gamma: Optional[jnp.ndarray],
                    beta: Optional[jnp.ndarray],
                    cfg: NonlinearConfig,
                    out_fmt: MXFormat,
                    rms_only: bool = False) -> MXTensor:
    """MXInt LayerNorm over the last axis (Fig 3 datapath).

    The shared exponent lambda cancels exactly between the centered value and
    sqrt(Var) (Eq. 5-7 with eps ~= 0), so the whole datapath runs on integer
    mantissas; the only non-integer stage is the tiny 1/sqrt LUT.

    ``rms_only=True`` gives the RMSNorm variant (no mean subtraction) used by
    the LM architectures — same datapath minus the centering adder.
    """
    m, _lam = requantize_to_max_exponent(x, axis=-1)   # int32; lambda cancels
    mf = m.astype(jnp.float32)                          # fixed-point emulation
    if rms_only:
        centered = mf
    else:
        mean = jnp.mean(mf, axis=-1, keepdims=True)
        centered = mf - mean
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = _rsqrt_datapath(var, cfg.ln_lut_bits)
    y = centered * inv
    if gamma is not None:
        y = y * gamma
    if beta is not None and not rms_only:
        y = y + beta
    return quantize(y, out_fmt, axis=-1)


# ---------------------------------------------------------------------------
# GELU (paper §III-B-2)
# ---------------------------------------------------------------------------
def mxint_gelu(x: MXTensor, cfg: NonlinearConfig,
               out_mant_bits: Optional[int] = None) -> MXTensor:
    """MXInt GELU (Eq. 12 / Fig 6).

    ReLU tails outside [-a, a]; LUT inside.  The input block exponent is
    forwarded unchanged to the output (paper: "the exponent value does not
    change and is directly forwarded").
    """
    a = float(cfg.gelu_domain)
    bits = cfg.gelu_index_bits                           # Fig 6: k index bits
    xf = dequantize(x)                                   # (m, e) -> fixed point
    lut = luts.gelu_lut(bits, a)
    y_small = jnp.take(lut, luts.gelu_index(xf, bits, a))
    y = jnp.where(xf >= a, xf, jnp.where(xf <= -a, 0.0, y_small))
    out_bits = out_mant_bits or x.mant_bits
    return _quantize_with_exponent(y, x.exponent, x.block_size,
                                   x.scale_axis, out_bits)


def mxint_silu(x: MXTensor, cfg: NonlinearConfig,
               out_mant_bits: Optional[int] = None) -> MXTensor:
    """SiLU via the same 3-piece LUT datapath (LM archs use SiLU/SwiGLU).

    silu(x) = x * sigmoid(x) has the same asymptotics as GELU (x for large x,
    0 for very negative x) so the paper's Eq. 12 structure applies verbatim;
    only the table contents differ.  SiLU's negative tail decays slower
    (silu(-3) = -0.142 vs gelu(-3) = -0.004), so the LUT domain is doubled —
    exactly the "different results of bitwidth [for] other ML models" the
    paper anticipates (§III-B).  Beyond-paper extension, DESIGN.md §6.
    """
    a = 2.0 * float(cfg.gelu_domain)
    bits = cfg.gelu_index_bits + 1                       # keep resolution
    xf = dequantize(x)
    n = 2 ** bits
    import numpy as np
    centers = -a + (2.0 * a / n) * (np.arange(n) + 0.5)
    lut = jnp.asarray(centers / (1.0 + np.exp(-centers)), dtype=jnp.float32)
    y_small = jnp.take(lut, luts.gelu_index(xf, bits, a))
    y = jnp.where(xf >= a, xf, jnp.where(xf <= -a, 0.0, y_small))
    out_bits = out_mant_bits or x.mant_bits
    return _quantize_with_exponent(y, x.exponent, x.block_size,
                                   x.scale_axis, out_bits)


# ---------------------------------------------------------------------------
# Softmax (paper §III-B-3)
# ---------------------------------------------------------------------------
def exp_datapath(z: jnp.ndarray, r_bits: int) -> jnp.ndarray:
    """e^x ~= 2^n * LUT_pow2(r) for z = x*log2(e) <= 0 (Eq. 14-19).

    Returns (p_m, n): mantissa in [1,2) and integer exponent, as the hardware
    would hand them to the divider, packed here as p_m * 2^n in float.
    """
    n = jnp.floor(z)
    r = z - n                                           # [0, 1)
    lut = luts.pow2_lut(r_bits)
    p_m = jnp.take(lut, luts.pow2_index(r, r_bits))      # [1, 2)
    n = jnp.maximum(n, -126.0)                           # flush denormals
    return p_m * jnp.exp2(n)


def mxint_softmax(x: MXTensor, cfg: NonlinearConfig, out_fmt: MXFormat,
                  axis: int = -1) -> MXTensor:
    """MXInt softmax along ``axis`` (must be the block axis).

    Datapath: requantize row to max exponent -> integer max-subtract ->
    z = t*log2(e) (constant fixed-point multiply) -> 2^n * LUT_pow2(r) ->
    accumulate -> divide in (mantissa, exponent) form (Eq. 20).
    """
    m, lam = requantize_to_max_exponent(x, axis=axis)
    m_max = jnp.max(m, axis=axis, keepdims=True)
    t = (m - m_max).astype(jnp.float32)                  # <= 0, mantissa units
    z = t * jnp.exp2(lam.astype(jnp.float32)) * _LOG2E   # x*log2(e) <= 0
    p = exp_datapath(z, cfg.softmax_r_bits)
    s = jnp.sum(p, axis=axis, keepdims=True)
    # Division in (mantissa, exponent) form: y = (p_m/s_m) * 2^(p_e - s_e).
    # Emulated by normalizing the accumulator through frexp, exactly what the
    # hardware's leading-zero-count + shift does.
    s_m, s_e = jnp.frexp(s)
    y = (p / s_m) * jnp.exp2(-s_e.astype(jnp.float32))
    return quantize(y, out_fmt, axis=axis)


def softmax_value(x: jnp.ndarray, cfg: NonlinearConfig,
                  act_fmt: MXFormat, out_fmt: Optional[MXFormat] = None,
                  axis: int = -1) -> jnp.ndarray:
    """Convenience: float in -> MXInt softmax datapath -> float out."""
    xq = quantize(x, act_fmt, axis=axis)
    return dequantize(mxint_softmax(xq, cfg, out_fmt or act_fmt, axis=axis))


def layernorm_value(x: jnp.ndarray, gamma, beta, cfg: NonlinearConfig,
                    act_fmt: MXFormat, rms_only: bool = False) -> jnp.ndarray:
    xq = quantize(x, act_fmt, axis=-1)
    return dequantize(mxint_layernorm(xq, gamma, beta, cfg, act_fmt,
                                      rms_only=rms_only))


def gelu_value(x: jnp.ndarray, cfg: NonlinearConfig,
               act_fmt: MXFormat) -> jnp.ndarray:
    xq = quantize(x, act_fmt, axis=-1)
    return dequantize(mxint_gelu(xq, cfg))


def silu_value(x: jnp.ndarray, cfg: NonlinearConfig,
               act_fmt: MXFormat) -> jnp.ndarray:
    xq = quantize(x, act_fmt, axis=-1)
    return dequantize(mxint_silu(xq, cfg))


# ---------------------------------------------------------------------------
# Related-work datapaths (for Tables II-IV): 8-bit fixed point emulations.
# ---------------------------------------------------------------------------
def _fixed_point_qdq(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric per-tensor fixed-point quantize-dequantize."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / (2 ** (bits - 1) - 1)
    return jnp.clip(jnp.round(x / scale),
                    -(2 ** (bits - 1)), 2 ** (bits - 1) - 1) * scale


def fixedpoint_layernorm(x: jnp.ndarray, gamma, beta, bits: int = 8,
                         eps: float = 1e-6) -> jnp.ndarray:
    """Integer-datapath LayerNorm a la Huang et al. [9] / SDA [5]."""
    xq = _fixed_point_qdq(x, bits)
    mean = jnp.mean(xq, axis=-1, keepdims=True)
    var = jnp.var(xq, axis=-1, keepdims=True)
    y = (xq - mean) / jnp.sqrt(var + eps)
    if gamma is not None:
        y = y * gamma
    if beta is not None:
        y = y + beta
    return _fixed_point_qdq(y, bits)


def fixedpoint_gelu(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Polynomial-erf integer GELU a la HeatViT [2] / [9] (Eq. 11)."""
    xq = _fixed_point_qdq(x, bits)
    # I-BERT style 2nd-order polynomial erf approximation.
    a, b, c = -0.2888, -1.769, 1.0
    s = jnp.sign(xq)
    xa = jnp.minimum(jnp.abs(xq / jnp.sqrt(2.0)), -b)
    l_erf = s * (a * (xa + b) ** 2 + c)
    y = xq * 0.5 * (1.0 + l_erf)
    return _fixed_point_qdq(y, bits)


def relu6_gelu(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """SDA [5]: GELU approximated as ReLU6 — loses accuracy on ViTs."""
    xq = _fixed_point_qdq(x, bits)
    return _fixed_point_qdq(jnp.clip(xq, 0.0, 6.0), bits)


def fixedpoint_softmax(x: jnp.ndarray, bits: int = 8,
                       axis: int = -1) -> jnp.ndarray:
    """Max-subtract integer softmax a la I-ViT [23] / HeatViT [2]."""
    xq = _fixed_point_qdq(x, bits)
    z = (xq - jnp.max(xq, axis=axis, keepdims=True)) * _LOG2E   # <= 0
    # I-ViT ShiftExp: z = n + r with r in (-1, 0]; 2^r ~= 1 + r/2 (exact at
    # both endpoints, shift-friendly).
    n = jnp.ceil(z)
    r = z - n
    p = (1.0 + 0.5 * r) * jnp.exp2(jnp.maximum(n, -126.0))
    y = p / jnp.sum(p, axis=axis, keepdims=True)
    return _fixed_point_qdq(y, bits)
