"""Greedy mantissa-bitwidth search (paper §III-A / Table V).

The paper determines "the minimal bitwidth of the mantissa to preserve high
accuracy within a 1% loss" by greedy search in software quantization.  We
reproduce that loop generically: given a model's apply function, a
calibration batch and a per-group quantization hook, greedily lower each
group's mantissa width while a fidelity metric stays within budget.

Without ImageNet in the container, the default metric is top-1 *agreement*
with the float model on the calibration batch (argmax match rate), which is
exactly the accuracy-delta proxy — a 1% budget on agreement upper-bounds the
accuracy drop on the same distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SearchResult:
    bits: Dict[str, int]
    metric: float
    trace: List[tuple]          # (group, bits_tried, metric, accepted)

    @property
    def mean_bits(self) -> float:
        return sum(self.bits.values()) / max(len(self.bits), 1)


def argmax_agreement(logits_a: jnp.ndarray, logits_b: jnp.ndarray) -> float:
    return float(jnp.mean(
        (jnp.argmax(logits_a, -1) == jnp.argmax(logits_b, -1)).astype(jnp.float32)))


def cosine_fidelity(a: jnp.ndarray, b: jnp.ndarray) -> float:
    af, bf = a.reshape(-1), b.reshape(-1)
    num = jnp.vdot(af, bf)
    den = jnp.linalg.norm(af) * jnp.linalg.norm(bf) + 1e-12
    return float(num / den)


def greedy_bitwidth_search(
    apply_fn: Callable[[Dict[str, int]], jnp.ndarray],
    groups: Sequence[str],
    *,
    max_bits: int = 10,
    min_bits: int = 3,
    budget: float = 0.01,
    metric: str = "agreement",
    reference: jnp.ndarray | None = None,
) -> SearchResult:
    """Greedily minimize per-group mantissa bits.

    apply_fn(bits_per_group) must run the quantized model and return logits
    (or any comparable output).  Groups are visited in the given order
    (sort large-memory tensors first to harvest the big wins first, as the
    paper does); for each group we lower bits one step at a time while the
    metric stays within ``budget`` of the reference.
    """
    bits = {g: max_bits for g in groups}
    ref = reference if reference is not None else apply_fn(bits)
    if metric == "agreement":
        score = lambda out: 1.0 - argmax_agreement(out, ref)
    elif metric == "cosine":
        score = lambda out: 1.0 - cosine_fidelity(out, ref)
    else:
        raise ValueError(f"unknown metric {metric!r}")

    trace: List[tuple] = []
    current = score(apply_fn(bits))
    for g in groups:
        while bits[g] > min_bits:
            trial = dict(bits)
            trial[g] = bits[g] - 1
            s = score(apply_fn(trial))
            ok = s <= budget
            trace.append((g, trial[g], s, ok))
            if not ok:
                break
            bits = trial
            current = s
    return SearchResult(bits=bits, metric=current, trace=trace)
