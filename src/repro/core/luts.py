"""LUT builders for the paper's three non-linear datapaths (§III-B).

All tables are tiny by construction — that is the paper's point.  On TPU a
"LUT" is a small VMEM-resident vector consumed by a vectorized gather
(`jnp.take` in the oracle; in-kernel index select in Pallas).

Conventions
-----------
* ``rsqrt`` table: domain u ∈ [0.5, 2).  Even shared exponents index with the
  normalized variance mantissa v_m ∈ [1,2); odd exponents index with v_m/2 ∈
  [0.5, 1) (paper Eq. 9) — one table serves both halves.
* ``pow2`` table: r ∈ [0, 1), entries 2^(i / 2^bits) (truncation indexing so
  r = 0 → exactly 1.0, keeping the max element of a softmax row exact).
* ``gelu`` table: domain x ∈ [-a, a), entries gelu(center of bin).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

_SQRT_2_OVER_PI = float(np.sqrt(2.0 / np.pi))


# ---------------------------------------------------------------------------
# Exact scalar references (float64 on host, used only to fill tables).
# ---------------------------------------------------------------------------
def gelu_exact(x: np.ndarray) -> np.ndarray:
    """Exact erf-based GELU (paper Eq. 10/11)."""
    from math import erf
    xs = np.asarray(x, dtype=np.float64)
    return xs * 0.5 * (1.0 + np.vectorize(erf)(xs / np.sqrt(2.0)))


# ---------------------------------------------------------------------------
# Table builders (cached; tables are host numpy, converted lazily).
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def rsqrt_table(bits: int) -> tuple:
    """2^bits entries of 1/sqrt(u) over u in [0.5, 2), bucket midpoints."""
    n = 2 ** bits
    edges = 0.5 + 1.5 * np.arange(n, dtype=np.float64) / n
    centers = edges + 0.75 / n
    return tuple((1.0 / np.sqrt(centers)).astype(np.float32).tolist())


@functools.lru_cache(maxsize=None)
def pow2_table(bits: int) -> tuple:
    """2^bits entries of 2^r over r in [0, 1), truncation indexing."""
    n = 2 ** bits
    r = np.arange(n, dtype=np.float64) / n
    return tuple(np.exp2(r).astype(np.float32).tolist())


@functools.lru_cache(maxsize=None)
def gelu_table(bits: int, domain: float) -> tuple:
    """2^bits entries of gelu(x) over x in [-domain, domain), midpoints."""
    n = 2 ** bits
    step = 2.0 * domain / n
    centers = -domain + step * (np.arange(n, dtype=np.float64) + 0.5)
    return tuple(gelu_exact(centers).astype(np.float32).tolist())


# JAX-array views ------------------------------------------------------------
def rsqrt_lut(bits: int) -> jnp.ndarray:
    return jnp.asarray(rsqrt_table(bits), dtype=jnp.float32)


def pow2_lut(bits: int) -> jnp.ndarray:
    return jnp.asarray(pow2_table(bits), dtype=jnp.float32)


def gelu_lut(bits: int, domain: float) -> jnp.ndarray:
    return jnp.asarray(gelu_table(bits, float(domain)), dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Indexing helpers shared by oracle + kernels (keep numerics identical).
# ---------------------------------------------------------------------------
def rsqrt_index(u: jnp.ndarray, bits: int) -> jnp.ndarray:
    """u in [0.5, 2) -> bucket index (truncation, hardware-style)."""
    n = 2 ** bits
    idx = jnp.floor((u - 0.5) * (n / 1.5)).astype(jnp.int32)
    return jnp.clip(idx, 0, n - 1)


def pow2_index(r: jnp.ndarray, bits: int) -> jnp.ndarray:
    n = 2 ** bits
    idx = jnp.floor(r * n).astype(jnp.int32)
    return jnp.clip(idx, 0, n - 1)


def gelu_index(x: jnp.ndarray, bits: int, domain: float) -> jnp.ndarray:
    n = 2 ** bits
    idx = jnp.floor((x + domain) * (n / (2.0 * domain))).astype(jnp.int32)
    return jnp.clip(idx, 0, n - 1)


def table_bytes(entries: int, value_bits: int = 16) -> int:
    """Area proxy for DSE tables (paper counts LUT entries; we count bytes)."""
    return entries * value_bits // 8
