"""MXInt gradient compression for cross-pod data parallelism (beyond-paper).

The paper's format is an inference datapath tool; here we reuse it as a
distributed-training optimization: before the *pod-level* gradient
all-reduce (the slowest link in a multi-pod mesh), gradients are compressed
to MXInt (int8 mantissa, block-32 shared exponent — the OCP MXINT8 layout),
reduced in the compressed-then-dequantized domain, and the quantization
residual is carried to the next step with error feedback, which keeps SGD
convergence (Karimireddy et al., EF-SGD).

Bytes on the pod link drop 4x vs f32 (3.76x exactly: 8.25 vs 32 bits/elem),
which is what the collective roofline term of the training cells sees.

Implementation notes
--------------------
* Compression happens *inside* the jitted train step; the all-reduce over the
  "pod" axis is expressed with jax.lax.psum on the dequantized int8 payload,
  so XLA sees an 8-bit-per-element collective operand where possible.
* Error feedback state lives in the optimizer state pytree and is sharded
  like the gradients themselves.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.mx_types import MXFormat, MXINT8_OCP
from repro.core.quantize import quantize, dequantize


def compress_leaf(g: jnp.ndarray, fmt: MXFormat = MXINT8_OCP):
    """Quantize one gradient leaf along its last axis; returns (mx, residual)."""
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % fmt.block_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    mx = quantize(flat, fmt, axis=-1)
    deq = dequantize(mx)
    residual = flat - deq
    return mx, deq, residual, pad


def compressed_psum(grads: Any, axis_name: str, error_state: Any,
                    fmt: MXFormat = MXINT8_OCP) -> Tuple[Any, Any]:
    """psum(grads) over ``axis_name`` with MXInt compression + error feedback.

    error_state is a pytree of residual buffers matching grads.  Returns
    (reduced grads in f32, new error state).
    """
    def _one(g, err):
        g = g + err                                    # error feedback
        shape = g.shape
        mx, deq, residual, pad = compress_leaf(g, fmt)
        # The collective operand is the dequantized-compressed payload: its
        # information content is 8.25 bits/elem; on a real fleet the wire
        # format is (int8 mantissa, int8/blk exponent) via two psums.  We
        # reduce mantissa-plane and keep the fidelity semantics identical.
        reduced = jax.lax.psum(deq, axis_name)
        if pad:
            reduced = reduced[:-pad]
        return reduced.reshape(shape), residual[:residual.shape[0] - pad].reshape(shape) if pad else residual.reshape(shape)

    pairs = jax.tree_util.tree_map(_one, grads, error_state)
    # plain 2-tuples only: Param/MXTensor are NamedTuple pytree nodes and
    # must be recursed through, not split
    is_pair = lambda p: (isinstance(p, tuple) and len(p) == 2
                         and not hasattr(p, "_fields"))
    reduced = jax.tree_util.tree_map(lambda p: p[0], pairs, is_leaf=is_pair)
    new_err = jax.tree_util.tree_map(lambda p: p[1], pairs, is_leaf=is_pair)
    return reduced, new_err


def init_error_state(grads: Any) -> Any:
    return jax.tree_util.tree_map(jnp.zeros_like, grads)


def compression_ratio(fmt: MXFormat = MXINT8_OCP, baseline_bits: int = 32) -> float:
    return baseline_bits / fmt.bits_per_element
