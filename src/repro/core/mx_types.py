"""Core type definitions for the MXInt (Microscaling Integer) format.

The paper ("Refining Datapath for Microscaling ViTs") uses MXInt tensors in
which a *block* of values shares one 8-bit exponent while each value keeps a
small signed-integer mantissa.  A value is reconstructed as

    x = 2**e_block * m                                           (paper Eq. 2)

Bit cost per element is therefore ``mant_bits + exp_bits / block_size`` —
e.g. the paper's W6.03 (6-bit mantissa, block 256) and A8.5 (8-bit mantissa,
block 16) configurations in Fig. 1b.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e — the roofline target for this reproduction).
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip, bf16 MXU
PEAK_FLOPS_INT8 = 394e12      # FLOP/s per chip, int8 MXU (2x bf16)
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per ICI link

# FPGA constants from the paper (Alveo U250), kept for the Table VII analogue.
U250_KLUTS = 1728
U250_BRAM36 = 2688
U250_URAM = 1280

# ---------------------------------------------------------------------------
# Masking sentinel — THE single definition (kernels, wrappers and models all
# import it; tools/repro_lint.py rejects any other -2.0e38 literal).  The
# Eq. 2-3 score quantization runs on the MASKED tile, so every layer of the
# stack must fill masked lanes with the exact same value or the shared block
# exponents (and hence the whole-row bit-exactness guarantee) diverge.
# Finite rather than -inf: the requantize shift-clamp arithmetic needs
# ordinary float algebra (inf - inf would NaN the online-softmax rescale).
# ---------------------------------------------------------------------------
NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class MXFormat:
    """An MXInt element format.

    Attributes:
      mant_bits: signed mantissa width in bits (including sign).  The paper
        sweeps 4..14; MXInt8 means ``mant_bits=8``.
      block_size: number of elements sharing one exponent.  Paper: 16 for
        activations, 256 for weights (block == hardware tile).
      exp_bits: stored width of the shared exponent.  Always 8 in the paper.
    """

    mant_bits: int = 8
    block_size: int = 32
    exp_bits: int = 8

    def __post_init__(self):
        # bool is an int subclass; reject it explicitly so MXFormat(True)
        # cannot masquerade as a 1-bit width
        if isinstance(self.mant_bits, bool) or \
                not isinstance(self.mant_bits, int):
            raise TypeError(f"mant_bits must be an int, "
                            f"got {type(self.mant_bits).__name__}")
        if not (2 <= self.mant_bits <= 24):
            # < 2 leaves no magnitude bit beside the sign; > 24 exceeds the
            # f32 significand the quantizer round-trips through, so the
            # extra codes could not be represented exactly
            raise ValueError(f"mant_bits must be in [2, 24], got {self.mant_bits}")
        if isinstance(self.block_size, bool) or \
                not isinstance(self.block_size, int):
            raise TypeError(f"block_size must be an int, "
                            f"got {type(self.block_size).__name__}")
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")
        if self.exp_bits != 8:
            # The paper fixes the exponent at 8 bits; other widths would need
            # saturation logic we have not validated.
            raise ValueError("MXInt exponent is always 8 bits in this work")

    # -- storage helpers ----------------------------------------------------
    @property
    def bits_per_element(self) -> float:
        """Amortized bits per element (paper's W6.03 / A8.5 notation)."""
        return self.mant_bits + self.exp_bits / self.block_size

    @property
    def mant_dtype(self) -> jnp.dtype:
        if self.mant_bits <= 8:
            return jnp.int8
        if self.mant_bits <= 16:
            return jnp.int16
        return jnp.int32

    @property
    def mant_max(self) -> int:
        return 2 ** (self.mant_bits - 1) - 1

    @property
    def mant_min(self) -> int:
        # Symmetric clip: excluding -2^(m-1) keeps quantization idempotent
        # (Q(Q(x)) == Q(x)) and exactly sign-symmetric; costs one code point.
        return -(2 ** (self.mant_bits - 1) - 1)

    def density_vs(self, baseline_bits: float = 32.0) -> float:
        """Memory density multiplier vs. a scalar format (Fig. 1b)."""
        return baseline_bits / self.bits_per_element


# The paper's published configurations.
MXINT8_ACT = MXFormat(mant_bits=8, block_size=16)      # A8.5 in Fig 1b
MXINT8_WEIGHT = MXFormat(mant_bits=8, block_size=256)
MXINT6_WEIGHT = MXFormat(mant_bits=6, block_size=256)  # W6.03 in Fig 1b
MXINT6_ACT = MXFormat(mant_bits=6, block_size=16)
MXINT4_WEIGHT = MXFormat(mant_bits=4, block_size=256)

# OCP MX spec default (MXINT8: block 32) — used by gradient compression.
MXINT8_OCP = MXFormat(mant_bits=8, block_size=32)


@dataclasses.dataclass(frozen=True)
class NonlinearConfig:
    """Datapath knobs for the paper's three non-linear operators (§III-B).

    Defaults are the paper's final design points:
      * LayerNorm rsqrt LUT index bits = 5 (Table II; >=4 per Fig 4)
      * GELU domain a = 3, LUT bits = 5  (Table III; >=4 per Figs 7-8)
      * Softmax r bits = 2               (Table IV; Fig 9)
    """

    ln_lut_bits: int = 5          # index bits of LUT_{1/sqrt}
    gelu_domain: float = 3.0      # 'a' in Eq. 12
    gelu_lut_bits: int = 5        # index bits of LUT_GELU
    softmax_r_bits: int = 2       # fractional bits of r in Eq. 16
    softmax_out_bits: int = 8     # mantissa bits of 2^r LUT output
    acc_frac_bits: int = 12       # paper: 12-bit lossless accumulator mantissa

    @property
    def ln_lut_entries(self) -> int:
        return 2 ** self.ln_lut_bits

    @property
    def gelu_index_bits(self) -> int:
        """Fig 6: k = LUT bitwidth + log2(LUT domain) - 1 (ceil), the total
        fixed-point index width of LUT_GELU."""
        import math
        return self.gelu_lut_bits + max(math.ceil(math.log2(self.gelu_domain)), 0) - 1

    @property
    def gelu_lut_entries(self) -> int:
        return 2 ** self.gelu_index_bits

    @property
    def softmax_lut_entries(self) -> int:
        return 2 ** self.softmax_r_bits


@dataclasses.dataclass(frozen=True)
class QuantOverride:
    """A per-layer-group patch on a :class:`QuantConfig` (DESIGN.md §16).

    Every field is optional; ``None`` means "inherit from the base
    config".  Overrides attach to a config as ``(pattern, override)``
    pairs, where ``pattern`` is an ``fnmatch`` glob matched against the
    scope tag a model passes at its call sites (``"block/3/ffn"``,
    ``"head"``, ...).  This is the search-space lever of the paper's
    design-space exploration: per-layer-group mantissa widths, block
    sizes, backend (mode) choice and LUT widths, without forking the
    model code.
    """

    mode: Optional[str] = None
    weight_fmt: Optional[MXFormat] = None
    act_fmt: Optional[MXFormat] = None
    nonlinear: Optional["NonlinearConfig"] = None
    quantize_nonlinear: Optional[bool] = None

    _FIELDS = ("mode", "weight_fmt", "act_fmt", "nonlinear",
               "quantize_nonlinear")

    def patch(self) -> dict:
        """The non-None fields, as dataclasses.replace kwargs."""
        return {f: getattr(self, f) for f in self._FIELDS
                if getattr(self, f) is not None}


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Framework-level quantization policy for a model.

    mode:
      'off'    — full-precision reference path.
      'fake'   — quantize-dequantize in float (straight-through grads); used
                 for QAT-style experiments and fast accuracy sweeps.
      'sim'    — bit-accurate integer emulation of the paper's datapaths
                 (the correctness oracle).
      'packed' — weights stored as int8 mantissa planes + int8 exponents;
                 dequant fused into the consuming XLA op (serving path).
      'kernel' — packed planes fed straight into the Pallas kernels
                 (repro.kernels.ops): mxint_linear consumes the int8
                 mantissa/exponent planes with no host-side dequantize, and
                 LayerNorm/GELU/Softmax/attention run the in-kernel MXInt
                 datapaths.  Numerically identical to 'sim' (same LUTs and
                 integer stages) for per-op primitives and whole-row
                 attention — the ViT production shapes; long sequences
                 (score matrices past 512x512) and KV-ring decode beyond
                 one 128-key block use the BLOCKED Eq. 14-20 flash
                 datapath, which matches 'sim' within LUT granularity but
                 not bitwise (DESIGN.md §11).  Inference-only.  MXInt
                 formats only: ``emulate`` / ``nl_emulate`` baselines are
                 XLA emulations with no kernel counterpart.
    """

    mode: str = "off"
    weight_fmt: MXFormat = MXINT6_WEIGHT
    act_fmt: MXFormat = MXINT8_ACT
    nonlinear: Optional[NonlinearConfig] = None
    quantize_nonlinear: bool = False   # route LN/softmax/GELU through MXInt
    nl_ops: tuple = ("layernorm", "gelu", "softmax")  # per-op selectivity
    emulate: Optional[str] = None      # None=MXInt | 'int' per-tensor |
                                       # 'fp8' e4m3 — Table V baselines
    nl_emulate: Optional[str] = None   # None=MXInt datapath | 'fixedpoint'
                                       # ([9]/HeatViT/I-ViT) | 'relu6' (SDA)
                                       # — Tables II-IV baselines
    overrides: tuple = ()              # ((glob_pattern, QuantOverride), ...)
                                       # per-layer-group patches, resolved
                                       # by scoped() (DESIGN.md §16)

    def __post_init__(self):
        if self.mode not in ("off", "fake", "sim", "packed", "kernel"):
            raise ValueError(f"unknown quant mode {self.mode!r}")
        if self.emulate not in (None, "int", "fp8"):
            raise ValueError(f"unknown emulate {self.emulate!r}")
        if self.mode == "kernel" and (self.emulate is not None or
                                      self.nl_emulate is not None):
            raise ValueError("mode='kernel' runs the MXInt Pallas datapaths; "
                             "emulate/nl_emulate baselines are XLA-only")
        if self.quantize_nonlinear and self.nonlinear is None:
            object.__setattr__(self, "nonlinear", NonlinearConfig())
        if self.overrides:
            norm = []
            for entry in self.overrides:
                try:
                    pattern, ov = entry
                except (TypeError, ValueError):
                    raise ValueError(
                        f"overrides entries must be (pattern, QuantOverride) "
                        f"pairs, got {entry!r}") from None
                if not isinstance(pattern, str) or not pattern:
                    raise ValueError(f"override pattern must be a non-empty "
                                     f"glob string, got {pattern!r}")
                if not isinstance(ov, QuantOverride):
                    raise TypeError(f"override for {pattern!r} must be a "
                                    f"QuantOverride, got "
                                    f"{type(ov).__name__}")
                norm.append((pattern, ov))
            object.__setattr__(self, "overrides", tuple(norm))

    @property
    def enabled(self) -> bool:
        return self.mode != "off"

    @property
    def has_overrides(self) -> bool:
        """True when per-layer-group patches are attached — models use
        this to switch from the stacked lax.scan over blocks (one traced
        body) to an unrolled per-layer loop that can carry different
        static configs (DESIGN.md §16)."""
        return bool(self.overrides)

    def scoped(self, scope: Optional[str]) -> "QuantConfig":
        """The effective config for layer-group ``scope`` (DESIGN.md §16).

        Matching ``overrides`` patterns apply in declaration order, later
        entries winning field-by-field; the merged patch is applied to
        the base fields and the result (with ``overrides`` stripped, so
        scoping is idempotent) is cached per scope on this instance.
        With no overrides — or ``scope=None``, the untagged call sites —
        this returns ``self``, keeping the global-config path literally
        identical.
        """
        if scope is None or not self.overrides:
            return self
        cache = self.__dict__.setdefault("_scoped_cache", {})
        got = cache.get(scope)
        if got is None:
            got = cache[scope] = self._resolve_scope(scope)
        return got

    def _resolve_scope(self, scope: str) -> "QuantConfig":
        import fnmatch
        patch: dict = {}
        for pattern, ov in self.overrides:
            if fnmatch.fnmatchcase(scope, pattern):
                patch.update(ov.patch())
        return dataclasses.replace(self, overrides=(), **patch)

    def describe(self) -> dict:
        """JSON-serializable summary (the dse report's config block)."""
        nl = self.nonlinear
        return {
            "mode": self.mode,
            "weight_fmt": {"mant_bits": self.weight_fmt.mant_bits,
                           "block_size": self.weight_fmt.block_size},
            "act_fmt": {"mant_bits": self.act_fmt.mant_bits,
                        "block_size": self.act_fmt.block_size},
            "quantize_nonlinear": self.quantize_nonlinear,
            "nonlinear": None if nl is None else {
                "ln_lut_bits": nl.ln_lut_bits,
                "gelu_lut_bits": nl.gelu_lut_bits,
                "softmax_r_bits": nl.softmax_r_bits},
        }

    @functools.cached_property
    def datapath(self):
        """The execution backend this config resolves to (DESIGN.md §12).

        Resolved ONCE per config from the ``repro.datapath`` registry and
        cached on the instance (``cached_property`` writes the instance
        ``__dict__`` directly, which a frozen dataclass permits; field
        equality/hash are untouched).  Every layer primitive dispatches
        through this object — mode-string branching lives only in
        ``repro/datapath/`` and this module's validation.
        """
        from repro.datapath import resolve
        return resolve(self)
