"""MXInt quantization: block-shared exponent + integer mantissa.

Quantization of a block b with elements x_i:

    amax    = max_i |x_i|
    e_b     = floor(log2(amax)) - (mant_bits - 2)        # so amax maps into
                                                         # [2^(m-2), 2^(m-1))
    m_i     = clip(round(x_i * 2^-e_b), -2^(m-1), 2^(m-1)-1)
    x_i_hat = m_i * 2^e_b                                 # paper Eq. 2

The shared exponent is stored as a signed int8 (equivalent to the paper's
8-bit biased exponent).  Blocks are taken along one axis; the block axis is
always the *contraction/feature* axis so that shared exponents never straddle
a sharded dimension (DESIGN.md §8).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.mx_types import MXFormat

_EXP_MIN, _EXP_MAX = -127, 127


class MXTensor(NamedTuple):
    """A packed MXInt tensor.

    mantissa: integer array, same shape as the source tensor.
    exponent: int8 array; shape equals the source shape with the block axis
      divided by ``block_size`` (ceil).
    scale_axis: the axis along which blocks were formed, stored NEGATIVE
      (from the end) so that slicing a leading stacked-layers dim (lax.scan
      over units) leaves the static axis valid.
    mant_bits: element mantissa width (static).
    block_size: static block size actually used (may be clamped to the dim).
    tp_axis: mesh axis name this tensor's planes are sharded over inside a
      ``shard_map``, or None (the default: unsharded / replicated).  Static
      aux data, so it survives scan slicing and jit tracing; consumed by
      ``repro.kernels.ops.mxint_linear`` to insert the matching collective
      (all_gather for output-sharded planes, psum for contraction-sharded
      planes — see ``tp_mode``).  Set by
      ``repro.parallel.sharding.tp_shard_packed_params`` (DESIGN.md §10).
    tp_mode: 'gather' when the OUTPUT (last) axis is sharded — each shard
      computes a column slice over the full contraction and the results are
      concatenated, which is bit-exact by construction; 'psum' when the
      CONTRACTION axis is sharded — each shard computes a partial sum and
      the f32 psum re-orders the accumulation (NOT bit-exact vs the
      single-device oracle; see DESIGN.md §10).
    """

    mantissa: jnp.ndarray
    exponent: jnp.ndarray
    scale_axis: int
    mant_bits: int
    block_size: int
    tp_axis: "str | None" = None
    tp_mode: "str | None" = None

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.mantissa.shape

    @property
    def bits_per_element(self) -> float:
        return self.mant_bits + 8.0 / self.block_size

    def nbytes_packed(self) -> int:
        """Bytes this tensor occupies in packed storage (sub-byte mantissas
        counted at their true bit cost, as dense bit-packing would give)."""
        n = self.mantissa.size
        return int((n * self.mant_bits + self.exponent.size * 8 + 7) // 8)


jax.tree_util.register_pytree_node(
    MXTensor,
    lambda t: ((t.mantissa, t.exponent),
               (t.scale_axis, t.mant_bits, t.block_size, t.tp_axis,
                t.tp_mode)),
    lambda aux, leaves: MXTensor(leaves[0], leaves[1], *aux),
)


def _resolve_block(dim: int, block_size: int) -> int:
    """Clamp block size to the dimension (granite d_ff=512 w/ block 256 is
    fine; d=10 w/ block 16 clamps to 10)."""
    if dim >= block_size and dim % block_size == 0:
        return block_size
    if dim < block_size:
        return dim
    # find the largest divisor of dim that is <= block_size
    for b in range(block_size, 0, -1):
        if dim % b == 0:
            return b
    return 1


def _blockwise(x: jnp.ndarray, axis: int, block: int) -> jnp.ndarray:
    """Reshape so the block axis splits into (nblocks, block) at ``axis``."""
    axis = axis % x.ndim
    d = x.shape[axis]
    new_shape = x.shape[:axis] + (d // block, block) + x.shape[axis + 1:]
    return x.reshape(new_shape)


def _shared_exponent(amax: jnp.ndarray, mant_bits: int) -> jnp.ndarray:
    """e = floor(log2(amax)) - (mant_bits - 2), saturated to int8 range."""
    # frexp: amax = f * 2^k with f in [0.5, 1) => floor(log2(amax)) = k - 1.
    _, k = jnp.frexp(jnp.maximum(amax, jnp.finfo(jnp.float32).tiny))
    e = k - 1 - (mant_bits - 2)
    e = jnp.where(amax > 0, e, 0)
    return jnp.clip(e, _EXP_MIN, _EXP_MAX).astype(jnp.int8)


def quantize(x: jnp.ndarray, fmt: MXFormat, axis: int = -1) -> MXTensor:
    """Quantize ``x`` to MXInt along ``axis``."""
    x = x.astype(jnp.float32)
    axis = axis % x.ndim
    block = _resolve_block(x.shape[axis], fmt.block_size)
    xb = _blockwise(x, axis, block)                      # (..., nb, block, ...)
    amax = jnp.max(jnp.abs(xb), axis=axis + 1)           # (..., nb, ...)
    e = _shared_exponent(amax, fmt.mant_bits)
    scale = jnp.exp2(-e.astype(jnp.float32))
    m = jnp.round(xb * jnp.expand_dims(scale, axis + 1))
    m = jnp.clip(m, fmt.mant_min, fmt.mant_max)
    m = m.reshape(x.shape).astype(fmt.mant_dtype)
    return MXTensor(m, e, axis - x.ndim, fmt.mant_bits, block)


def dequantize(t: MXTensor, dtype=jnp.float32) -> jnp.ndarray:
    """Reconstruct x = m * 2^e."""
    scale = jnp.exp2(t.exponent.astype(jnp.float32))
    scale = jnp.repeat(scale, t.block_size, axis=t.scale_axis)
    return (t.mantissa.astype(jnp.float32) * scale).astype(dtype)


def quantize_dequantize(x: jnp.ndarray, fmt: MXFormat, axis: int = -1) -> jnp.ndarray:
    return dequantize(quantize(x, fmt, axis), dtype=x.dtype)


# ---------------------------------------------------------------------------
# Fake quantization with straight-through gradients (QAT / fast sweeps).
# ---------------------------------------------------------------------------
import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def fake_quant(x: jnp.ndarray, mant_bits: int, block_size: int, axis: int):
    fmt = MXFormat(mant_bits=mant_bits, block_size=block_size)
    return quantize_dequantize(x, fmt, axis)


def _fq_fwd(x, mant_bits, block_size, axis):
    return fake_quant(x, mant_bits, block_size, axis), None


def _fq_bwd(mant_bits, block_size, axis, _, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


# ---------------------------------------------------------------------------
# Baseline-format emulations (Table V comparisons).
# ---------------------------------------------------------------------------
def per_tensor_int_qdq(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric per-tensor integer quantization (the paper's IntN rows)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    s = amax / (2 ** (bits - 1) - 1)
    return (jnp.clip(jnp.round(x / s), -(2 ** (bits - 1)),
                     2 ** (bits - 1) - 1) * s).astype(x.dtype)


def fp8_e4m3_qdq(x: jnp.ndarray) -> jnp.ndarray:
    """e4m3 emulation: 3 explicit mantissa bits, saturate at +-448."""
    xf = jnp.asarray(x, jnp.float32)
    m, e = jnp.frexp(xf)
    e = jnp.clip(e, -6, 9)
    scale = jnp.exp2(3.0 - e.astype(jnp.float32))
    q = jnp.round(xf * scale) / scale
    return jnp.clip(q, -448.0, 448.0).astype(x.dtype)


# ---------------------------------------------------------------------------
# Re-quantization to a common exponent (paper Eq. 3, Fig. 3 first stage).
# ---------------------------------------------------------------------------
def requantize_to_max_exponent(t: MXTensor, axis: int = -1):
    """Force every block along ``axis`` onto the row-max exponent by
    arithmetic right-shift of the mantissas (paper Eq. 3).

    Returns (shifted mantissas as int32, lambda exponent with the reduced
    axis kept at size 1).  This is the lossy alignment step the non-linear
    datapaths start from; the shift truncates low bits exactly as the
    hardware barrel shifter would.
    """
    axis = axis % t.mantissa.ndim
    if axis != t.scale_axis % t.mantissa.ndim:
        raise ValueError("requantize must reduce along the block axis")
    e_max = jnp.max(t.exponent, axis=axis, keepdims=True)
    shift = (e_max - t.exponent).astype(jnp.int32)       # >= 0
    shift = jnp.repeat(shift, t.block_size, axis=axis)
    m = jnp.right_shift(t.mantissa.astype(jnp.int32), jnp.minimum(shift, 31))
    return m, e_max.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Packed-plane helpers (serving path): a weight is stored as two leaves.
# ---------------------------------------------------------------------------
def pack_weight(w: jnp.ndarray, fmt: MXFormat, axis: int = 0) -> MXTensor:
    """Quantize a parameter for packed serving storage.

    ``axis`` is the contraction dimension (first dim of a (d_in, d_out)
    kernel) so each output feature's blocks run along the reduction — the
    layout `mxint_matmul` consumes.
    """
    return quantize(w, fmt, axis=axis)


def packed_bytes(tree) -> int:
    """Total packed bytes of a pytree that may mix MXTensor and arrays."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda l: isinstance(l, MXTensor)):
        if isinstance(leaf, MXTensor):
            total += leaf.nbytes_packed()
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
