"""Search drivers over a SearchSpace (DESIGN.md §16).

Four strategies, all returning the full list of evaluated candidates
(the evaluator memoizes, so revisits are free and the report's Pareto
extraction sees everything each driver touched):

* ``exhaustive_search`` — every point; guarded by an explicit limit so a
  fat-fingered space cannot enumerate forever.
* ``greedy_search``     — the paper's §III-A / Table V loop re-hosted
  from ``core/search.py``: start every group at its widest candidate,
  then lower one group at a time while the accuracy drop vs the widest
  point stays within budget.  Same accept rule, same visit order, same
  trace tuples as ``core.search.greedy_bitwidth_search``.
* ``random_search``     — uniform samples, seeded.
* ``evolutionary_search`` — (mu + lambda) with dominance-based
  selection: parents are drawn from the current Pareto archive and
  mutated one knob at a time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.dse.evaluate import EvalResult, Evaluator
from repro.dse.report import DEFAULT_OBJECTIVES, pareto_front
from repro.dse.space import Point, SearchSpace

EXHAUSTIVE_LIMIT = 4096


def exhaustive_search(space: SearchSpace, evaluate: Evaluator, *,
                      limit: int = EXHAUSTIVE_LIMIT) -> List[EvalResult]:
    n = space.size()
    if n > limit:
        raise ValueError(f"space has {n} points > exhaustive limit "
                         f"{limit}; use greedy/random/evolutionary")
    return [evaluate(p) for p in space.points()]


def random_search(space: SearchSpace, evaluate: Evaluator, *,
                  n: int = 32, seed: int = 0) -> List[EvalResult]:
    import numpy as np
    rng = np.random.default_rng(seed)
    return [evaluate(space.random_point(rng)) for _ in range(n)]


@dataclasses.dataclass
class GreedyResult:
    """Mirror of ``core.search.SearchResult`` keyed by scope."""

    point: Point
    bits: Dict[str, int]        # scope -> chosen width
    metric: float               # final score (1 - agreement vs widest)
    trace: List[tuple]          # (scope, bits_tried, score, accepted)
    results: List[EvalResult]   # every candidate evaluated

    @property
    def mean_bits(self) -> float:
        return sum(self.bits.values()) / max(len(self.bits), 1)


def greedy_search(space: SearchSpace, evaluate: Evaluator, *,
                  knob: str = "weight_mant_bits",
                  budget: float = 0.01,
                  order: Optional[Sequence[str]] = None) -> GreedyResult:
    """Greedily minimize per-group ``knob`` under an accuracy budget.

    Reference = the point with every swept group at its WIDEST
    candidate (everything else at baseline); a lowering step is accepted
    while ``1 - agreement(candidate_logits, reference_logits)`` stays
    ``<= budget`` — the EXACT accept rule of
    ``core.search.greedy_bitwidth_search`` (candidates compared against
    the widest point's own output, via the evaluator's logits memo).
    """
    from repro.core.search import argmax_agreement

    knobs = {k.scope: sorted(k.values, reverse=True)
             for k in space.knobs() if k.name == knob}
    if not knobs:
        raise ValueError(f"no group sweeps knob {knob!r}")
    scopes = list(order) if order is not None else list(knobs)

    point = space.baseline_point()
    for s, widths in knobs.items():
        point[(s, knob)] = widths[0]
    results = [evaluate(point)]
    ref_out = evaluate.logits_for(point)

    trace: List[tuple] = []
    current = 0.0
    for s in scopes:
        widths = knobs[s]
        while True:
            i = widths.index(point[(s, knob)])
            if i + 1 >= len(widths):
                break
            trial = dict(point)
            trial[(s, knob)] = widths[i + 1]
            r = evaluate(trial)
            results.append(r)
            score = 1.0 - argmax_agreement(evaluate.logits_for(trial),
                                           ref_out)
            ok = score <= budget
            trace.append((s, widths[i + 1], score, ok))
            if not ok:
                break
            point = trial
            current = score
    bits = {s: point[(s, knob)] for s in knobs}
    return GreedyResult(point=point, bits=bits, metric=current,
                        trace=trace, results=results)


def evolutionary_search(space: SearchSpace, evaluate: Evaluator, *,
                        generations: int = 4, population: int = 8,
                        seed: int = 0,
                        objectives=DEFAULT_OBJECTIVES) -> List[EvalResult]:
    """(mu + lambda) evolution with dominance-based parent selection."""
    import numpy as np
    rng = np.random.default_rng(seed)

    seen: List[EvalResult] = [evaluate(space.baseline_point())]
    seen += [evaluate(space.random_point(rng))
             for _ in range(max(population - 1, 0))]
    for _ in range(generations):
        front = pareto_front(seen, objectives=objectives)
        parents = [seen[i] for i in front] or seen
        children = []
        for _ in range(population):
            parent = parents[int(rng.integers(len(parents)))]
            children.append(evaluate(space.mutate(parent.point, rng)))
        seen += children
    # dedupe on the canonical key, keeping first occurrence
    out, keys = [], set()
    for r in seen:
        if r.key not in keys:
            keys.add(r.key)
            out.append(r)
    return out
