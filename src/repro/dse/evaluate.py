"""Cached candidate evaluator: accuracy proxy + static hardware cost
(DESIGN.md §16).

Accuracy side — the ``core/search.py`` proxies, unchanged: top-1 argmax
*agreement* with the float (mode='off') model on a calibration batch
(the paper's 1%-budget stand-in without ImageNet in the container) and
cosine fidelity of the logits.

Cost side — static only, no execution:

* ``weight_bits`` / ``act_bits`` — element-count-weighted mean
  ``MXFormat.bits_per_element`` over the model's weight groups, each
  group priced under its SCOPED config (``q.scoped(scope)``), so a
  per-layer override shows up exactly in proportion to the parameters
  it covers (the paper's Fig. 1b x-axis).  Groups whose scoped mode is
  'off' are priced at float32.
* kernel FLOPs / HBM-traffic / VMEM — the ``analysis.cost_model`` rows
  for the deployment kernels (default: the DeiT pair ``matmul-deit`` +
  ``flash-deit``), with each int8 mantissa-plane operand's bytes scaled
  by ``weight_bits/8`` — the static table is built at 1 byte/element.
* ``lut_entries`` — total LUT provisioning: the per-table MAX across
  scopes (shared hardware must fit the widest requested table), summed
  over the three §III-B tables.

Optionally, measured wall-clock: ``measure_kernels`` runs the
``telemetry.probes`` twins of the same labels and the report carries
``{label: mean_ms}`` next to the predictions.

Every evaluation is cached on the canonical point key and counted in
telemetry (``dse/evaluations``, ``dse/cache_hits``, ``span/dse/eval``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.mx_types import QuantConfig
from repro.core.search import argmax_agreement, cosine_fidelity
from repro.dse.space import Point, SearchSpace, point_key
from repro.telemetry import metrics
from repro.telemetry.tracing import span

# the paper's DeiT deployment kernels (same labels as telemetry.probes)
DEFAULT_KERNEL_ROWS: Tuple[str, ...] = ("matmul-deit", "flash-deit")

FLOAT_BITS = 32.0


@dataclasses.dataclass(frozen=True)
class CandidateCost:
    """Static hardware-cost vector of one candidate."""

    weight_bits: float          # weighted mean bits/element, weights
    act_bits: float             # weighted mean bits/element, activations
    weight_bytes: int           # total packed weight footprint
    kernel_flops: int
    kernel_hbm_bytes: int       # traffic, mantissa planes scaled to width
    kernel_vmem_bytes: int
    lut_entries: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class EvalResult:
    key: tuple                  # canonical point key (space.point_key)
    point: Dict[Tuple[str, str], object]
    accuracy: float             # argmax agreement vs float model
    fidelity: float             # cosine fidelity of logits
    cost: CandidateCost

    def as_dict(self) -> dict:
        return {
            "point": [{"scope": s, "knob": n, "value": v}
                      for (s, n), v in sorted(self.point.items())],
            "accuracy": self.accuracy,
            "fidelity": self.fidelity,
            "cost": self.cost.as_dict(),
        }


# ---------------------------------------------------------------------------
# weight groups: (scope tag, element count) per quantizable weight
# ---------------------------------------------------------------------------
def weight_groups(cfg, params) -> List[Tuple[str, int]]:
    """(scope, n_elements) for every quantized weight tensor, under the
    same scope tags the model's forward passes to ``q.scoped``."""
    if cfg.family == "vit":
        return _vit_weight_groups(cfg, params)
    # generic fallback: every large matrix under the un-scoped tag
    total = sum(int(_leaf_size(p)) for p in _matmul_leaves(params))
    return [("*", total)]


def _leaf_size(p) -> int:
    v = getattr(p, "value", p)
    mant = getattr(v, "mantissa", None)
    return int(mant.size if mant is not None else v.size)


def _matmul_leaves(tree):
    import jax

    from repro.models.model_api import is_param
    for p in jax.tree_util.tree_leaves(tree, is_leaf=is_param):
        v = getattr(p, "value", p)
        mant = getattr(v, "mantissa", None)
        nd = mant.ndim if mant is not None else getattr(v, "ndim", 0)
        if nd >= 2 and _leaf_size(p) > 256:
            yield p

def _vit_weight_groups(cfg, params) -> List[Tuple[str, int]]:
    n = cfg.n_layers
    attn = sum(_leaf_size(params["blocks"]["attn"][k])
               for k in ("wq", "wk", "wv", "wo")) // n
    ffn = sum(_leaf_size(params["blocks"]["ffn"][k])
              for k in ("wi", "wo")) // n
    out = [("patch", _leaf_size(params["patch_proj"]))]
    for i in range(n):
        out.append((f"block/{i}/attn", attn))
        out.append((f"block/{i}/ffn", ffn))
    out.append(("head", _leaf_size(params["head"])))
    return out


# ---------------------------------------------------------------------------
# static cost
# ---------------------------------------------------------------------------
def _fmt_bits(q: QuantConfig, which: str) -> float:
    if not q.enabled:
        return FLOAT_BITS
    return getattr(q, which).bits_per_element


def _scaled_kernel_rows(rows: Dict[str, dict],
                        weight_scale: float) -> Tuple[int, int, int]:
    """(flops, hbm_bytes, vmem_bytes) summed over rows, with each row's
    largest int8 operand — the weight mantissa plane the table prices at
    8 bits — rescaled to the candidate's mean weight width."""
    flops = hbm = vmem = 0
    for row in rows.values():
        flops += int(row["flops"])
        vmem += int(row["vmem_bytes"])
        int8_ops = [o for o in row["operands"]
                    if o["dtype"] == "int8"]
        mant = max(int8_ops, key=lambda o: o["bytes_traffic"],
                   default=None)
        for o in row["operands"]:
            b = int(o["bytes_traffic"])
            if o is mant:
                b = int(round(b * weight_scale))
            hbm += b
    return flops, hbm, vmem


def static_cost(space: SearchSpace, point: Point, groups: Sequence[tuple],
                kernel_rows: Optional[Dict[str, dict]] = None
                ) -> CandidateCost:
    q = space.to_config(point)
    scopes = [s for s, _ in groups]
    total = sum(n for _, n in groups) or 1
    w_bits = sum(n * _fmt_bits(q.scoped(s), "weight_fmt")
                 for s, n in groups) / total
    a_bits = sum(n * _fmt_bits(q.scoped(s), "act_fmt")
                 for s, n in groups) / total

    lut = 0
    for entries in ("ln_lut_entries", "gelu_lut_entries",
                    "softmax_lut_entries"):
        per_scope = []
        for s in scopes:
            qs = q.scoped(s)
            if qs.quantize_nonlinear and qs.nonlinear is not None:
                per_scope.append(getattr(qs.nonlinear, entries))
        lut += max(per_scope, default=0)

    flops = hbm = vmem = 0
    if kernel_rows:
        flops, hbm, vmem = _scaled_kernel_rows(kernel_rows, w_bits / 8.0)
    return CandidateCost(
        weight_bits=round(float(w_bits), 4),
        act_bits=round(float(a_bits), 4),
        weight_bytes=int(round(sum(n for _, n in groups) * w_bits / 8.0)),
        kernel_flops=flops,
        kernel_hbm_bytes=hbm,
        kernel_vmem_bytes=vmem,
        lut_entries=lut,
    )


def measure_kernels(labels: Sequence[str] = DEFAULT_KERNEL_ROWS,
                    repeats: int = 2) -> Dict[str, float]:
    """Optional measured wall-clock: run the telemetry probe twins of
    the cost-model labels (interpret-mode on CPU — plumbing, not perf)."""
    from repro.telemetry.probes import run_probes
    return run_probes(labels, repeats=repeats)


# ---------------------------------------------------------------------------
# the evaluator
# ---------------------------------------------------------------------------
class Evaluator:
    """Score SearchSpace points, memoized on the canonical point key.

    cfg/params: the model (any family with scope-tagged call sites; the
    ViT/DeiT family is the first-class citizen).  ``images`` is the
    calibration batch.  The float reference (mode='off') is computed
    once, lazily.
    """

    def __init__(self, space: SearchSpace, cfg, params, images, *,
                 kernel_rows: Sequence[str] = DEFAULT_KERNEL_ROWS,
                 registry: Optional[metrics.Registry] = None):
        self.space = space
        self.cfg = cfg
        self.params = params
        self.images = images
        self.groups = weight_groups(cfg, params)
        self.registry = registry or metrics.default_registry()
        self._cache: Dict[tuple, EvalResult] = {}
        self._logits_cache: Dict[tuple, object] = {}
        self._ref = None
        self._rows = (dict() if not kernel_rows else
                      self._load_rows(tuple(kernel_rows)))

    @staticmethod
    def _load_rows(labels: Tuple[str, ...]) -> Dict[str, dict]:
        from repro.analysis.cost_model import query
        return query(labels)

    def _logits(self, q: QuantConfig):
        import dataclasses as dc

        from repro.models import build_model
        model = build_model(dc.replace(self.cfg, quant=q))
        return model.logits(self.params, self.images)

    @property
    def reference(self):
        if self._ref is None:
            self._ref = self._logits(QuantConfig(mode="off"))
        return self._ref

    def logits_for(self, point: Point):
        """Candidate logits on the calibration batch, memoized — the
        greedy driver compares candidates AGAINST EACH OTHER with these
        (the ``core.search`` accept rule), not just against float."""
        key = point_key(point)
        got = self._logits_cache.get(key)
        if got is None:
            self.registry.counter("dse/evaluations").inc()
            with span("dse/eval", registry=self.registry):
                got = self._logits(self.space.to_config(point))
            self._logits_cache[key] = got
        return got

    def __call__(self, point: Point) -> EvalResult:
        key = point_key(point)
        hit = self._cache.get(key)
        if hit is not None:
            self.registry.counter("dse/cache_hits").inc()
            return hit
        out = self.logits_for(point)
        result = EvalResult(
            key=key,
            point=dict(point),
            accuracy=argmax_agreement(out, self.reference),
            fidelity=cosine_fidelity(out, self.reference),
            cost=static_cost(self.space, point, self.groups, self._rows),
        )
        self._cache[key] = result
        return result

    @property
    def n_evaluated(self) -> int:
        return len(self._cache)
