"""Declarative per-layer-group search spaces (DESIGN.md §16).

A :class:`SearchSpace` is a base :class:`QuantConfig` plus a tuple of
:class:`GroupSpace` entries — one per layer group, each naming a scope
glob (matched against the tags models pass at their call sites:
``"block/3/ffn"``, ``"head"``, ``"block/*"``...) and the candidate
values for each knob it sweeps.  A *point* assigns one value to every
knob; ``to_config(point)`` turns it into a plain ``QuantConfig`` whose
``overrides`` carry only the assignments that DIFFER from the base —
so the uniform point (every knob at its base value) resolves to the
base config itself, keeping the scanned single-trace model path and
bit-identical logits (the §16 regression contract).

Knobs cover the paper's Fig. 1b / Table V axes: weight/act mantissa
widths and block sizes (``MXFormat``), the execution backend per group
(``mode``, from the ``repro.datapath`` registry — e.g. kernel attention
with sim FFN), and the ``NonlinearConfig`` LUT index widths.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, NamedTuple, Optional, Tuple

from repro.core.mx_types import (MXFormat, NonlinearConfig, QuantConfig,
                                 QuantOverride)

# knob name -> (override field it patches, MXFormat/NonlinearConfig
# sub-field or None for a direct QuantOverride field)
KNOBS: Dict[str, Tuple[str, Optional[str]]] = {
    "weight_mant_bits": ("weight_fmt", "mant_bits"),
    "weight_block_size": ("weight_fmt", "block_size"),
    "act_mant_bits": ("act_fmt", "mant_bits"),
    "act_block_size": ("act_fmt", "block_size"),
    "mode": ("mode", None),
    "ln_lut_bits": ("nonlinear", "ln_lut_bits"),
    "gelu_lut_bits": ("nonlinear", "gelu_lut_bits"),
    "softmax_r_bits": ("nonlinear", "softmax_r_bits"),
}


class Knob(NamedTuple):
    scope: str          # the group's scope glob
    name: str           # a KNOBS key
    values: Tuple       # candidate values, in sweep order


# a point assigns one value per knob, keyed by (scope, knob name)
Point = Dict[Tuple[str, str], object]


@dataclasses.dataclass(frozen=True)
class GroupSpace:
    """Candidate values for one layer group's knobs.

    Empty tuples mean "not swept — inherit the base config".  ``scope``
    is an fnmatch glob over the model's scope tags; groups apply in
    declaration order with later groups winning per field, mirroring
    the override resolution of ``QuantConfig.scoped``.
    """

    scope: str
    weight_mant_bits: Tuple[int, ...] = ()
    weight_block_size: Tuple[int, ...] = ()
    act_mant_bits: Tuple[int, ...] = ()
    act_block_size: Tuple[int, ...] = ()
    mode: Tuple[str, ...] = ()
    ln_lut_bits: Tuple[int, ...] = ()
    gelu_lut_bits: Tuple[int, ...] = ()
    softmax_r_bits: Tuple[int, ...] = ()

    def __post_init__(self):
        if not isinstance(self.scope, str) or not self.scope:
            raise ValueError(f"scope must be a non-empty glob string, "
                             f"got {self.scope!r}")
        for name in KNOBS:
            vals = tuple(getattr(self, name))
            if len(set(vals)) != len(vals):
                raise ValueError(f"duplicate candidates for "
                                 f"{self.scope}/{name}: {vals}")
            object.__setattr__(self, name, vals)

    def knobs(self) -> Iterator[Knob]:
        for name in KNOBS:
            vals = getattr(self, name)
            if vals:
                yield Knob(self.scope, name, vals)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    base: QuantConfig
    groups: Tuple[GroupSpace, ...]

    def __post_init__(self):
        if self.base.has_overrides:
            raise ValueError("the base config of a SearchSpace must be "
                             "override-free; overrides are what the "
                             "space generates")
        object.__setattr__(self, "groups", tuple(self.groups))
        seen = set()
        for g in self.groups:
            for k in g.knobs():
                key = (k.scope, k.name)
                if key in seen:
                    raise ValueError(f"knob {key} declared twice")
                seen.add(key)

    # -- enumeration --------------------------------------------------------
    def knobs(self) -> List[Knob]:
        return [k for g in self.groups for k in g.knobs()]

    def size(self) -> int:
        n = 1
        for k in self.knobs():
            n *= len(k.values)
        return n

    def points(self) -> Iterator[Point]:
        knobs = self.knobs()
        for combo in itertools.product(*[k.values for k in knobs]):
            yield {(k.scope, k.name): v for k, v in zip(knobs, combo)}

    def baseline_point(self) -> Point:
        """The per-knob values matching the base config where available
        (else the first candidate) — the uniform no-override point."""
        out: Point = {}
        for k in self.knobs():
            bv = self._base_value(k.name)
            out[(k.scope, k.name)] = bv if bv in k.values else k.values[0]
        return out

    def random_point(self, rng) -> Point:
        return {(k.scope, k.name): k.values[int(rng.integers(len(k.values)))]
                for k in self.knobs()}

    def mutate(self, point: Point, rng) -> Point:
        """Resample one knob to a different value (identity on a space
        with no multi-valued knob)."""
        knobs = [k for k in self.knobs() if len(k.values) > 1]
        out = dict(point)
        if not knobs:
            return out
        k = knobs[int(rng.integers(len(knobs)))]
        others = [v for v in k.values if v != point[(k.scope, k.name)]]
        out[(k.scope, k.name)] = others[int(rng.integers(len(others)))]
        return out

    # -- materialization ----------------------------------------------------
    def _base_value(self, name: str):
        field, sub = KNOBS[name]
        if sub is None:
            return getattr(self.base, field)
        if field == "nonlinear":
            nl = self.base.nonlinear or NonlinearConfig()
            return getattr(nl, sub)
        return getattr(getattr(self.base, field), sub)

    def to_config(self, point: Point) -> QuantConfig:
        """Materialize a point as a QuantConfig.

        Assignments equal to the base value are dropped; a point with no
        effective assignment returns ``base`` itself (no overrides, same
        scanned trace — the §16 bit-identity contract).
        """
        overrides = []
        base = self.base
        for g in self.groups:
            fmt_patch: Dict[str, Dict[str, object]] = {}
            ov_patch: Dict[str, object] = {}
            for k in g.knobs():
                val = point[(k.scope, k.name)]
                if val not in k.values:
                    raise ValueError(f"value {val!r} not a candidate for "
                                     f"{(k.scope, k.name)}")
                if val == self._base_value(k.name):
                    continue
                field, sub = KNOBS[k.name]
                if sub is None:
                    ov_patch[field] = val
                else:
                    fmt_patch.setdefault(field, {})[sub] = val
            for field, kw in fmt_patch.items():
                if field == "nonlinear":
                    nl = base.nonlinear or NonlinearConfig()
                    ov_patch[field] = dataclasses.replace(nl, **kw)
                else:
                    ov_patch[field] = dataclasses.replace(
                        getattr(base, field), **kw)
            if ov_patch:
                overrides.append((g.scope, QuantOverride(**ov_patch)))
        if not overrides:
            return base
        return dataclasses.replace(base, overrides=tuple(overrides))

    # -- reporting ----------------------------------------------------------
    def describe(self) -> dict:
        """JSON summary for the report header (DESIGN.md §16)."""
        return {
            "base": self.base.describe(),
            "size": self.size(),
            "groups": [{"scope": g.scope,
                        "knobs": {k.name: list(k.values)
                                  for k in g.knobs()}}
                       for g in self.groups],
        }


def point_key(point: Point) -> tuple:
    """Canonical hashable form of a point (the evaluator cache key and
    the report's candidate id)."""
    return tuple(sorted(((s, n), v) for (s, n), v in point.items()))
