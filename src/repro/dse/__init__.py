"""repro.dse — per-layer design-space exploration (DESIGN.md §16).

The paper's method layer: search per-layer-group MXInt configurations
(mantissa widths, block sizes, backend choice, LUT widths) and emit
accuracy-proxy vs hardware-cost Pareto frontiers — the Fig. 1b curve
and the Table V greedy search as two drivers over one space.

    space    SearchSpace / GroupSpace — the declarative knob grammar
    evaluate Evaluator — cached accuracy proxy + static cost scoring
    drivers  exhaustive / greedy / random / evolutionary
    report   Pareto extraction + the archived JSON report

Runnable: ``python -m repro.dse`` (Fig. 1b-style DeiT-Tiny sweep).
"""
from repro.dse.drivers import (GreedyResult, evolutionary_search,
                               exhaustive_search, greedy_search,
                               random_search)
from repro.dse.evaluate import (CandidateCost, EvalResult, Evaluator,
                                measure_kernels, weight_groups)
from repro.dse.report import (DEFAULT_OBJECTIVES, build_report, dominates,
                              objective_vector, pareto_front, write_report)
from repro.dse.space import (GroupSpace, Knob, SearchSpace, point_key)

__all__ = [
    "SearchSpace", "GroupSpace", "Knob", "point_key",
    "Evaluator", "EvalResult", "CandidateCost", "measure_kernels",
    "weight_groups",
    "exhaustive_search", "greedy_search", "random_search",
    "evolutionary_search", "GreedyResult",
    "dominates", "pareto_front", "objective_vector", "DEFAULT_OBJECTIVES",
    "build_report", "write_report",
]
