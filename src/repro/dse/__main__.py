"""Runnable Fig. 1b-style sweep: ``python -m repro.dse`` (DESIGN.md §16).

Sweeps weight mantissa width (and optionally a per-group space) on a
randomly-initialized DeiT against a synthetic calibration batch and
writes the Pareto JSON report.  With random weights the accuracy proxy
is agreement against the float forward of the SAME weights — the
datapath-fidelity signal the paper's software emulation measures, not
ImageNet accuracy (not shipped in the container).  CI runs this as the
DSE smoke (one block, tiny space, exhaustive driver) and archives the
report in both lanes.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys


def _space(args):
    from repro.core.mx_types import MXFormat, QuantConfig
    from repro.dse.space import GroupSpace, SearchSpace

    base = QuantConfig(mode=args.base_mode, quantize_nonlinear=True,
                       weight_fmt=MXFormat(mant_bits=8, block_size=256),
                       act_fmt=MXFormat(mant_bits=8, block_size=16))
    widths = tuple(int(b) for b in args.weight_bits.split(","))
    if args.per_group:
        groups = (GroupSpace(scope="block/*/attn",
                             weight_mant_bits=widths),
                  GroupSpace(scope="block/*/ffn",
                             weight_mant_bits=widths))
    else:
        groups = (GroupSpace(scope="*", weight_mant_bits=widths),)
    return SearchSpace(base=base, groups=groups)


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(
        prog="python -m repro.dse",
        description="per-layer DSE sweep: accuracy proxy vs static "
                    "hardware cost (paper Fig. 1b / Table V)")
    p.add_argument("--arch", default="deit_tiny",
                   help="configs.deit.BY_NAME entry (default deit_tiny)")
    p.add_argument("--layers", type=int, default=0,
                   help="truncate to N encoder blocks (0 = full depth)")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--mode", dest="base_mode", default="sim",
                   help="base execution mode for candidates")
    p.add_argument("--weight-bits", default="3,4,6,8",
                   help="comma list of weight mantissa widths to sweep")
    p.add_argument("--per-group", action="store_true",
                   help="sweep attn and ffn groups independently")
    p.add_argument("--driver", default="exhaustive",
                   choices=("exhaustive", "greedy", "random", "evolve"))
    p.add_argument("--budget", type=float, default=0.01,
                   help="greedy accuracy-loss budget")
    p.add_argument("--samples", type=int, default=16,
                   help="random-driver sample count")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--probes", action="store_true",
                   help="also run the telemetry kernel probes "
                        "(measured wall-clock; interpret-mode on CPU)")
    p.add_argument("--out", default="dse_report.json")
    args = p.parse_args(argv)

    import jax

    from repro.configs.deit import BY_NAME
    from repro.data.pipeline import SyntheticImageData
    from repro.dse import (Evaluator, build_report, evolutionary_search,
                           exhaustive_search, greedy_search, measure_kernels,
                           random_search, write_report)
    from repro.models import build_model

    cfg = BY_NAME[args.arch]
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    data = SyntheticImageData(n_classes=cfg.n_classes, batch=args.batch,
                              image_size=cfg.image_size, seed=args.seed)
    images = data.next_batch()["images"]

    space = _space(args)
    ev = Evaluator(space, cfg, params, images)
    if args.driver == "exhaustive":
        results = exhaustive_search(space, ev)
    elif args.driver == "random":
        results = random_search(space, ev, n=args.samples, seed=args.seed)
    elif args.driver == "evolve":
        results = evolutionary_search(space, ev, seed=args.seed)
    else:
        results = greedy_search(space, ev, budget=args.budget).results

    measured = measure_kernels() if args.probes else None
    report = build_report(space, results, driver=args.driver,
                          n_evaluations=ev.n_evaluated,
                          measured_ms=measured)
    path = write_report(args.out, report)

    print(f"# {args.arch} layers={cfg.n_layers} batch={args.batch} "
          f"driver={args.driver} space={space.size()} "
          f"evaluated={ev.n_evaluated}")
    print(f"{'pareto':>6} {'w_bits':>7} {'acc':>6} {'fid':>6} "
          f"{'hbm_bytes':>10}")
    for row in report["candidates"]:
        c = row["cost"]
        print(f"{'*' if row['pareto'] else '':>6} "
              f"{c['weight_bits']:>7.2f} {row['accuracy']:>6.3f} "
              f"{row['fidelity']:>6.3f} {c['kernel_hbm_bytes']:>10}")
    print(f"report -> {path}")
    return report


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
