"""Pareto extraction and the JSON report both CI lanes archive
(DESIGN.md §16).

Objectives are (name, sense, extractor) triples; the comparison space
is the sign-adjusted vector where HIGHER IS BETTER on every axis.
``dominates(a, b)`` is strict Pareto dominance: at least as good
everywhere, strictly better somewhere; equal vectors never dominate
each other, so exact ties all stay on the front.

Report schema (§16)::

    {"schema": 1, "driver": ..., "space": SearchSpace.describe(),
     "objectives": [names...], "n_candidates": N, "n_evaluations": N,
     "candidates": [EvalResult.as_dict() + {"pareto": bool}],
     "pareto": [indices into candidates],
     "measured_ms": {label: mean_ms} | null}
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Callable, List, Optional, Sequence, Tuple

from repro.dse.evaluate import EvalResult

Objective = Tuple[str, int, Callable[[EvalResult], float]]

# the Fig. 1b axes: maximize agreement, minimize bits-per-element and
# the deployment kernels' HBM traffic
DEFAULT_OBJECTIVES: Tuple[Objective, ...] = (
    ("accuracy", +1, lambda r: r.accuracy),
    ("weight_bits", -1, lambda r: r.cost.weight_bits),
    ("kernel_hbm_bytes", -1, lambda r: float(r.cost.kernel_hbm_bytes)),
)


def objective_vector(result: EvalResult,
                     objectives: Sequence[Objective] = DEFAULT_OBJECTIVES
                     ) -> Tuple[float, ...]:
    """Sign-adjusted objective values (higher is better on every axis)."""
    return tuple(sense * fn(result) for _, sense, fn in objectives)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """Strict Pareto dominance on higher-is-better vectors."""
    if len(a) != len(b):
        raise ValueError(f"objective arity mismatch: {len(a)} vs {len(b)}")
    return all(x >= y for x, y in zip(a, b)) and \
        any(x > y for x, y in zip(a, b))


def pareto_front(results: Sequence[EvalResult],
                 objectives: Sequence[Objective] = DEFAULT_OBJECTIVES
                 ) -> List[int]:
    """Indices of the non-dominated results (stable order)."""
    vecs = [objective_vector(r, objectives) for r in results]
    return [i for i, v in enumerate(vecs)
            if not any(dominates(w, v) for j, w in enumerate(vecs)
                       if j != i)]


def build_report(space, results: Sequence[EvalResult], *, driver: str,
                 objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
                 n_evaluations: Optional[int] = None,
                 measured_ms: Optional[dict] = None) -> dict:
    # dedupe on the canonical point key (drivers may revisit; the
    # evaluator already served those from cache)
    uniq: List[EvalResult] = []
    seen = set()
    for r in results:
        if r.key not in seen:
            seen.add(r.key)
            uniq.append(r)
    front = set(pareto_front(uniq, objectives))
    candidates = []
    for i, r in enumerate(uniq):
        row = r.as_dict()
        row["pareto"] = i in front
        candidates.append(row)
    return {
        "schema": 1,
        "driver": driver,
        "space": space.describe(),
        "objectives": [name for name, _, _ in objectives],
        "n_candidates": len(uniq),
        "n_evaluations": (len(uniq) if n_evaluations is None
                          else n_evaluations),
        "candidates": candidates,
        "pareto": sorted(front),
        "measured_ms": measured_ms,
    }


def write_report(path, report: dict) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    return path
