from repro.parallel.sharding import (LOGICAL_RULES, logical_to_pspec,
                                     params_pspecs, maybe_constraint,
                                     named_sharding_tree, ShardingRules)
