"""Logical-axis sharding rules: map Param axes onto mesh axes.

The production mesh has axes ("pod", "data", "model") (multi-pod) or
("data", "model") (single pod).  Logical axis names used by the model zoo:

  batch      -> (pod, data)        activations / inputs
  seq        -> None by default; 'data' under sequence-parallel decode
  embed      -> None               d_model stays replicated across TP
  q_heads    -> model              attention heads (TP)
  kv_heads   -> model              KV heads (TP; replicated if fewer heads
                                   than shards — GSPMD handles the remainder)
  mlp        -> model              FFN hidden
  vocab      -> model              embedding / unembedding tables
  expert     -> model              MoE expert dim (EP)
  lru        -> model              recurrent channel dim
  layers     -> None               stacked-scan leading dim
  fsdp       -> data               optional ZeRO-style param shard (hillclimb)

Rules are a dataclass so perf iterations can swap assignments per run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    batch: Optional[Tuple[str, ...]] = ("pod", "data")
    seq: Optional[str] = None
    embed: Optional[str] = None
    q_heads: Optional[str] = "model"
    kv_heads: Optional[str] = "model"
    heads: Optional[str] = "model"
    mlp: Optional[str] = "model"
    vocab: Optional[str] = "model"
    expert: Optional[str] = "model"
    lru: Optional[str] = "model"
    layers: Optional[str] = None
    kv_seq: Optional[str] = None           # sequence-parallel KV (long ctx)
    patch: Optional[str] = None
    classes: Optional[str] = None
    conv: Optional[str] = None
    pods: Optional[str] = "pod"            # per-pod state (error feedback)
    cap: Optional[Tuple[str, ...]] = ("pod", "data")  # MoE dispatch capacity
    fsdp: Optional[str] = None             # set to "data" for ZeRO-style

    def get(self, name: Optional[str]):
        if name is None:
            return None
        return getattr(self, name)


LOGICAL_RULES = ShardingRules()


def _filter_axes(assignment, mesh_axis_names):
    """Drop mesh axes absent from the current mesh (single-pod drops 'pod')."""
    if assignment is None:
        return None
    if isinstance(assignment, str):
        return assignment if assignment in mesh_axis_names else None
    kept = tuple(a for a in assignment if a in mesh_axis_names)
    return kept if kept else None


def logical_to_pspec(axes: Tuple[Optional[str], ...],
                     rules: ShardingRules,
                     mesh_axis_names,
                     shape: Optional[Tuple[int, ...]] = None,
                     mesh_shape: Optional[dict] = None) -> P:
    """Logical axes tuple -> PartitionSpec.

    Drops mesh axes absent from the current mesh, de-duplicates (a mesh axis
    may appear once per spec), and — when ``shape`` is given — prunes mesh
    axes that do not divide the dimension (e.g. vocab=49155 over model=16,
    MQA kv_heads=1): the longest divisible prefix of the assignment is kept,
    so a (pod, data) batch assignment degrades gracefully to (pod,) or
    replication for small dims."""
    used = set()
    out = []
    for i, name in enumerate(axes):
        a = _filter_axes(rules.get(name), mesh_axis_names)
        if a is None:
            out.append(None)
            continue
        names = (a,) if isinstance(a, str) else a
        names = tuple(n for n in names if n not in used)
        if shape is not None and mesh_shape is not None and i < len(shape):
            while names:
                prod = 1
                for n in names:
                    prod *= mesh_shape[n]
                if prod > 0 and shape[i] % prod == 0:
                    break
                names = names[:-1]
        used.update(names)
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(names)
    return P(*out)


def params_pspecs(axes_pytree, rules: ShardingRules, mesh: Mesh):
    """Map an axes pytree (from model_api.axes_tree) to PartitionSpecs."""
    names = mesh.axis_names
    return jax.tree_util.tree_map(
        lambda axes: logical_to_pspec(axes, rules, names),
        axes_pytree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def named_sharding_tree(axes_pytree, rules: ShardingRules, mesh: Mesh):
    specs = params_pspecs(axes_pytree, rules, mesh)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def ambient_mesh():
    """The mesh the current trace runs under, or None.

    Modern jax exposes it as ``jax.sharding.get_abstract_mesh()``; older
    jax keeps the ``with mesh:`` context in the legacy thread-resources
    global — check both so shard_hint works across versions.
    """
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except AttributeError:
        pass
    except Exception:
        return None
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def maybe_constraint(x: jnp.ndarray, axes: Tuple[Optional[str], ...]):
    """with_sharding_constraint when tracing under a mesh, else identity."""
    env_mesh = ambient_mesh()
    if env_mesh is None:
        return x
    spec = logical_to_pspec(axes, LOGICAL_RULES, env_mesh.axis_names)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
