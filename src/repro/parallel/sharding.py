"""Logical-axis sharding rules: map Param axes onto mesh axes.

The production mesh has axes ("pod", "data", "model") (multi-pod) or
("data", "model") (single pod).  Logical axis names used by the model zoo:

  batch      -> (pod, data)        activations / inputs
  seq        -> None by default; 'data' under sequence-parallel decode
  embed      -> None               d_model stays replicated across TP
  q_heads    -> model              attention heads (TP)
  kv_heads   -> model              KV heads (TP; replicated if fewer heads
                                   than shards — GSPMD handles the remainder)
  mlp        -> model              FFN hidden
  vocab      -> model              embedding / unembedding tables
  expert     -> model              MoE expert dim (EP)
  lru        -> model              recurrent channel dim
  layers     -> None               stacked-scan leading dim
  fsdp       -> data               optional ZeRO-style param shard (hillclimb)

Rules are a dataclass so perf iterations can swap assignments per run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    batch: Optional[Tuple[str, ...]] = ("pod", "data")
    seq: Optional[str] = None
    embed: Optional[str] = None
    q_heads: Optional[str] = "model"
    kv_heads: Optional[str] = "model"
    heads: Optional[str] = "model"
    mlp: Optional[str] = "model"
    vocab: Optional[str] = "model"
    expert: Optional[str] = "model"
    lru: Optional[str] = "model"
    layers: Optional[str] = None
    kv_seq: Optional[str] = None           # sequence-parallel KV (long ctx)
    patch: Optional[str] = None
    classes: Optional[str] = None
    conv: Optional[str] = None
    pods: Optional[str] = "pod"            # per-pod state (error feedback)
    cap: Optional[Tuple[str, ...]] = ("pod", "data")  # MoE dispatch capacity
    fsdp: Optional[str] = None             # set to "data" for ZeRO-style

    def get(self, name: Optional[str]):
        if name is None:
            return None
        return getattr(self, name)


LOGICAL_RULES = ShardingRules()


def _filter_axes(assignment, mesh_axis_names):
    """Drop mesh axes absent from the current mesh (single-pod drops 'pod')."""
    if assignment is None:
        return None
    if isinstance(assignment, str):
        return assignment if assignment in mesh_axis_names else None
    kept = tuple(a for a in assignment if a in mesh_axis_names)
    return kept if kept else None


def logical_to_pspec(axes: Tuple[Optional[str], ...],
                     rules: ShardingRules,
                     mesh_axis_names,
                     shape: Optional[Tuple[int, ...]] = None,
                     mesh_shape: Optional[dict] = None) -> P:
    """Logical axes tuple -> PartitionSpec.

    Drops mesh axes absent from the current mesh, de-duplicates (a mesh axis
    may appear once per spec), and — when ``shape`` is given — prunes mesh
    axes that do not divide the dimension (e.g. vocab=49155 over model=16,
    MQA kv_heads=1): the longest divisible prefix of the assignment is kept,
    so a (pod, data) batch assignment degrades gracefully to (pod,) or
    replication for small dims."""
    used = set()
    out = []
    for i, name in enumerate(axes):
        a = _filter_axes(rules.get(name), mesh_axis_names)
        if a is None:
            out.append(None)
            continue
        names = (a,) if isinstance(a, str) else a
        names = tuple(n for n in names if n not in used)
        if shape is not None and mesh_shape is not None and i < len(shape):
            while names:
                prod = 1
                for n in names:
                    prod *= mesh_shape[n]
                if prod > 0 and shape[i] % prod == 0:
                    break
                names = names[:-1]
        used.update(names)
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(names)
    return P(*out)


def params_pspecs(axes_pytree, rules: ShardingRules, mesh: Mesh):
    """Map an axes pytree (from model_api.axes_tree) to PartitionSpecs."""
    names = mesh.axis_names
    return jax.tree_util.tree_map(
        lambda axes: logical_to_pspec(axes, rules, names),
        axes_pytree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def named_sharding_tree(axes_pytree, rules: ShardingRules, mesh: Mesh):
    specs = params_pspecs(axes_pytree, rules, mesh)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# tensor-parallel sharding of packed MXInt planes (serving; DESIGN.md §10)
# ---------------------------------------------------------------------------
def _tp_decision(value, n_shards: int, strategy: str):
    """Which axis of a packed weight shards under ``strategy``, or None.

    value: an MXTensor whose planes may still be ShapeDtypeStructs
    (abstract dry-run packing).  Returns (axis, tp_mode) with axis an
    index into the mantissa shape, or None when the leaf must stay
    replicated (not packed, not divisible, or the split would straddle a
    shared-exponent block).
    """
    from repro.core.quantize import MXTensor
    if not isinstance(value, MXTensor):
        return None
    shape = value.mantissa.shape
    if len(shape) < 2:
        return None
    scale_axis = value.scale_axis % len(shape)
    if strategy == "column":
        axis, mode = len(shape) - 1, "gather"
        if axis == scale_axis:
            return None          # output axis carries the shared-exponent
                                 # blocks (embedding tables): cannot
                                 # column-shard without splitting blocks
    elif strategy == "row":
        axis, mode = scale_axis, "psum"
        if axis != len(shape) - 2:
            # mxint_linear contracts the second-to-last plane axis; leaves
            # whose blocks run elsewhere (embedding/unembedding tables:
            # last axis) are consumed via dequantize, not the kernel —
            # sharding them here would silently mismatch.  Replicate.
            return None
        # the exponent plane must split evenly too: block boundaries may
        # not straddle shards (pack with tp_shards=n_shards to guarantee)
        if (shape[axis] // value.block_size) % n_shards:
            return None
    else:
        raise ValueError(f"unknown tp strategy {strategy!r}")
    if shape[axis] % n_shards:
        return None
    return axis, mode


def tp_shard_packed_params(packed_params, n_shards: int,
                           axis_name: str = "model",
                           strategy: str = "column"):
    """Mark packed Param leaves for tensor parallelism and build in_specs.

    packed_params: a Param tree from ``pack_params_mxint`` (MXTensor
    values on the large matmul weights, plain arrays elsewhere).
    n_shards: size of the ``axis_name`` mesh axis.
    strategy:
      'column' — shard every packed weight along its OUTPUT (last) axis;
        each shard contracts the full K and `mxint_linear` all_gathers
        the column slices.  Bit-exact vs single-device by construction
        (collectives only move data).  The serving default.
      'row'    — shard along the contraction/block axis (Megatron
        row-parallel); `mxint_linear` slices the replicated activations
        and psums partial products.  Halves the activation traffic but
        the f32 psum re-orders accumulation: close, NOT bit-exact.
        Pack with ``pack_params_mxint(..., tp_shards=n_shards)`` so block
        boundaries never straddle shards (DESIGN.md §8).

    Returns ``(marked_params, in_specs)``: the same tree with
    ``tp_axis``/``tp_mode`` stamped on the sharded MXTensor leaves, and a
    PartitionSpec tree (one spec per Param position — the exponent plane
    inherits the mantissa plane's spec, their ranks match) usable as
    shard_map in_specs or for ``NamedSharding`` device placement.
    Everything that is not a shardable packed weight (norm scales,
    biases, positional tables) is replicated: biases are added after the
    collective inside ``mxint_linear``, so they stay full-width.
    """
    from repro.models.model_api import Param, is_param

    def mark(p: Param) -> Param:
        d = _tp_decision(p.value, n_shards, strategy)
        if d is None:
            return p
        return Param(p.value._replace(tp_axis=axis_name, tp_mode=d[1]),
                     p.axes)

    def spec(p: Param) -> P:
        d = _tp_decision(p.value, n_shards, strategy)
        if d is None:
            return P()
        axis, _ = d
        ndim = len(p.value.mantissa.shape)
        return P(*(axis_name if i == axis else None for i in range(ndim)))

    marked = jax.tree_util.tree_map(mark, packed_params, is_leaf=is_param)
    specs = jax.tree_util.tree_map(spec, packed_params, is_leaf=is_param)
    return marked, specs


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (mirrors repro.train.step's shim).

    Modern jax exposes ``jax.shard_map`` (VMA-checked); the pinned
    jax 0.4.37 only has ``jax.experimental.shard_map``.  Both are called
    with replication checking off: the collectives inserted by
    ``mxint_linear`` make outputs replicated by construction.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as _legacy_sm
    return _legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def ambient_mesh():
    """The mesh the current trace runs under, or None.

    Modern jax exposes it as ``jax.sharding.get_abstract_mesh()``; older
    jax keeps the ``with mesh:`` context in the legacy thread-resources
    global — check both so shard_hint works across versions.
    """
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except AttributeError:
        pass
    except Exception:
        return None
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def maybe_constraint(x: jnp.ndarray, axes: Tuple[Optional[str], ...]):
    """with_sharding_constraint when tracing under a mesh, else identity."""
    env_mesh = ambient_mesh()
    if env_mesh is None:
        return x
    spec = logical_to_pspec(axes, LOGICAL_RULES, env_mesh.axis_names)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
