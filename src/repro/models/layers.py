"""Quantization-aware layer primitives shared by every architecture.

Each primitive dispatches on QuantConfig.mode:

  'off'    — plain float ops.
  'fake'   — MXInt quantize-dequantize (straight-through grads) on weights
             and (optionally) activations; float non-linear ops unless
             quantize_nonlinear is set.
  'sim'    — bit-accurate MXInt datapaths from repro.core.nonlinear for
             LayerNorm/softmax/GELU-family; linears run QDQ (exactly equal
             to the integer datapath: products of <=8-bit mantissas are
             exact in f32, and the TPU accumulator is lossless).
  'packed' — weights arrive as MXTensor leaves (int8 planes); dequant is
             fused into the consuming XLA op.  Serving path.
  'kernel' — the Pallas execution path (repro.kernels.ops): linears feed
             the packed int8 mantissa/exponent planes straight into
             `mxint_linear` (no host-side dequantize — HBM traffic is the
             quantized bytes), and, when ``quantize_nonlinear`` is set,
             LayerNorm / RMSNorm / GELU / SiLU / softmax run the in-kernel
             MXInt datapaths (`mxint_layernorm_op` / `mxint_gelu_op` /
             `mxint_softmax_op`).  Numerically identical to 'sim' — same
             LUTs, same integer stages, same output quantization — so the
             oracle doubles as the parity check.  Inference-only (the
             Pallas calls carry no VJP); weights that are not already
             MXTensor leaves are packed on the fly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.mx_types import QuantConfig, NonlinearConfig
from repro.core.quantize import MXTensor, dequantize, fake_quant, pack_weight
from repro.core import nonlinear as nl
from repro.models.model_api import Param


# ---------------------------------------------------------------------------
# sharding hint (no-op off-mesh; constraint under pjit)
# ---------------------------------------------------------------------------
def shard_hint(x: jnp.ndarray, spec) -> jnp.ndarray:
    """Apply a with_sharding_constraint if a mesh is active."""
    from repro.parallel.sharding import maybe_constraint
    return maybe_constraint(x, spec)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------
def _maybe_qdq_weight(w: jnp.ndarray, q: QuantConfig) -> jnp.ndarray:
    if q.mode in ("fake", "sim"):
        if q.emulate == "int":
            from repro.core.quantize import per_tensor_int_qdq
            return per_tensor_int_qdq(w, q.weight_fmt.mant_bits)
        if q.emulate == "fp8":
            from repro.core.quantize import fp8_e4m3_qdq
            return fp8_e4m3_qdq(w)
        return fake_quant(w, q.weight_fmt.mant_bits,
                          q.weight_fmt.block_size, 0)
    return w


def _maybe_qdq_act(x: jnp.ndarray, q: QuantConfig) -> jnp.ndarray:
    if q.mode in ("fake", "sim"):
        if q.emulate == "int":
            from repro.core.quantize import per_tensor_int_qdq
            return per_tensor_int_qdq(x, q.act_fmt.mant_bits)
        if q.emulate == "fp8":
            from repro.core.quantize import fp8_e4m3_qdq
            return fp8_e4m3_qdq(x)
        return fake_quant(x, q.act_fmt.mant_bits, q.act_fmt.block_size, -1)
    return x


def linear(x: jnp.ndarray, w: Param, b: Optional[Param] = None, *,
           q: QuantConfig) -> jnp.ndarray:
    """y = x @ w (+ b); w may be a packed MXTensor in serving mode."""
    wv = w.value
    if q.mode == "kernel":
        from repro.kernels import ops
        if not isinstance(wv, MXTensor):
            wv = pack_weight(jnp.asarray(wv, jnp.float32), q.weight_fmt,
                             axis=0)
        # tp_axis/tp_mode are static MXTensor metadata stamped by
        # tp_shard_packed_params: inside a shard_map the kernel runs on the
        # local planes and mxint_linear inserts the matching collective
        # (all_gather / psum) before the bias add (DESIGN.md §10).
        return ops.mxint_linear(
            x, wv.mantissa, wv.exponent,
            None if b is None else b.value.astype(jnp.float32),
            w_block=wv.block_size, quantize_act=True,
            act_block=q.act_fmt.block_size,
            act_mant_bits=q.act_fmt.mant_bits,
            tp_axis=wv.tp_axis, tp_mode=wv.tp_mode)
    if isinstance(wv, MXTensor):
        wf = dequantize(wv, dtype=x.dtype)          # fused by XLA into the dot
    else:
        wf = _maybe_qdq_weight(wv, q).astype(x.dtype)
    xf = _maybe_qdq_act(x, q)
    y = jnp.einsum("...k,kn->...n", xf, wf)
    if b is not None:
        y = y + b.value.astype(y.dtype)
    return y


def embed_lookup(tokens: jnp.ndarray, table: Param, q: QuantConfig,
                 dtype) -> jnp.ndarray:
    tv = table.value
    if isinstance(tv, MXTensor):
        tf = dequantize(tv, dtype=dtype)
    else:
        tf = _maybe_qdq_weight(tv, q).astype(dtype)
    return jnp.take(tf, tokens, axis=0)


def unembed(x: jnp.ndarray, table: Param, q: QuantConfig) -> jnp.ndarray:
    tv = table.value
    if isinstance(tv, MXTensor):
        tf = dequantize(tv, dtype=x.dtype)
    else:
        tf = _maybe_qdq_weight(tv, q).astype(x.dtype)
    return jnp.einsum("...d,vd->...v", x, tf)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def _nl_on(q: QuantConfig, op: str) -> bool:
    return (q.enabled and q.quantize_nonlinear and
            q.mode in ("sim", "packed", "kernel") and op in q.nl_ops)


def _nl_kernel(q: QuantConfig, op: str) -> bool:
    return q.mode == "kernel" and _nl_on(q, op)


def _nl_emulate(q: QuantConfig, op: str):
    return q.nl_emulate if _nl_on(q, op) else None


def rmsnorm(x: jnp.ndarray, gamma: Param, *, q: QuantConfig,
            eps: float = 1e-6) -> jnp.ndarray:
    if _nl_kernel(q, "layernorm"):
        from repro.kernels import ops
        y = ops.mxint_layernorm_op(
            x.astype(jnp.float32), gamma.value, None,
            act_block=q.act_fmt.block_size, mant_bits=q.act_fmt.mant_bits,
            lut_bits=q.nonlinear.ln_lut_bits, rms_only=True,
            quantize_out=True)
        return y.astype(x.dtype)
    if _nl_emulate(q, "layernorm") == "fixedpoint":
        # 8-bit fixed-point RMS variant of the [9]/SDA integer datapath
        from repro.core.nonlinear import _fixed_point_qdq
        xf = _fixed_point_qdq(x.astype(jnp.float32), 8)
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (_fixed_point_qdq(y, 8) * gamma.value).astype(x.dtype)
    if _nl_on(q, "layernorm"):
        y = nl.layernorm_value(x.astype(jnp.float32), gamma.value, None,
                               q.nonlinear, q.act_fmt, rms_only=True)
        return y.astype(x.dtype)
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * gamma.value).astype(x.dtype)


def layernorm(x: jnp.ndarray, gamma: Param, beta: Param, *, q: QuantConfig,
              eps: float = 1e-6) -> jnp.ndarray:
    if _nl_kernel(q, "layernorm"):
        from repro.kernels import ops
        y = ops.mxint_layernorm_op(
            x.astype(jnp.float32), gamma.value, beta.value,
            act_block=q.act_fmt.block_size, mant_bits=q.act_fmt.mant_bits,
            lut_bits=q.nonlinear.ln_lut_bits, quantize_out=True)
        return y.astype(x.dtype)
    if _nl_emulate(q, "layernorm") == "fixedpoint":
        y = nl.fixedpoint_layernorm(x.astype(jnp.float32), gamma.value,
                                    beta.value, bits=8, eps=eps)
        return y.astype(x.dtype)
    if _nl_on(q, "layernorm"):
        y = nl.layernorm_value(x.astype(jnp.float32), gamma.value, beta.value,
                               q.nonlinear, q.act_fmt)
        return y.astype(x.dtype)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.value + beta.value).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / softmax
# ---------------------------------------------------------------------------
def act_fn(x: jnp.ndarray, kind: str, q: QuantConfig) -> jnp.ndarray:
    if _nl_kernel(q, "gelu"):
        from repro.kernels import ops
        cfg: NonlinearConfig = q.nonlinear
        y = ops.mxint_gelu_op(
            x.astype(jnp.float32), fn=kind,
            act_block=q.act_fmt.block_size, mant_bits=q.act_fmt.mant_bits,
            lut_bits=cfg.gelu_lut_bits, domain=cfg.gelu_domain)
        return y.astype(x.dtype)
    em = _nl_emulate(q, "gelu")
    if em == "fixedpoint":
        return nl.fixedpoint_gelu(x.astype(jnp.float32)).astype(x.dtype)
    if em == "relu6":
        return nl.relu6_gelu(x.astype(jnp.float32)).astype(x.dtype)
    if _nl_on(q, "gelu"):
        cfg: NonlinearConfig = q.nonlinear
        f = {"gelu": nl.gelu_value, "silu": nl.silu_value}[kind]
        return f(x.astype(jnp.float32), cfg, q.act_fmt).astype(x.dtype)
    return {"gelu": lambda v: jax.nn.gelu(v, approximate=False),
            "silu": jax.nn.silu}[kind](x)


def softmax(x: jnp.ndarray, q: QuantConfig, axis: int = -1) -> jnp.ndarray:
    if _nl_kernel(q, "softmax") and axis in (-1, x.ndim - 1):
        from repro.kernels import ops
        y = ops.mxint_softmax_op(
            x.astype(jnp.float32), act_block=q.act_fmt.block_size,
            mant_bits=q.act_fmt.mant_bits,
            r_bits=q.nonlinear.softmax_r_bits, quantize_out=True)
        return y.astype(x.dtype)
    if _nl_emulate(q, "softmax") in ("fixedpoint", "relu6"):
        return nl.fixedpoint_softmax(x.astype(jnp.float32),
                                     axis=axis).astype(x.dtype)
    if _nl_on(q, "softmax"):
        y = nl.softmax_value(x.astype(jnp.float32), q.nonlinear, q.act_fmt,
                             axis=axis)
        return y.astype(x.dtype)
    return jax.nn.softmax(x, axis=axis)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, hd); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) *
                    (jnp.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., s, half)
    cos = jnp.cos(ang)[..., None, :]                             # (...,s,1,half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------
def ffn(x: jnp.ndarray, p, kind: str, q: QuantConfig) -> jnp.ndarray:
    """p: dict with wi/wg/wo (gated) or wi/wo (plain)."""
    if kind in ("swiglu", "geglu"):
        act = "silu" if kind == "swiglu" else "gelu"
        up = linear(x, p["wi"], q=q)
        gate = act_fn(linear(x, p["wg"], q=q), act, q)
        return linear(up * gate, p["wo"], q=q)
    elif kind == "gelu":
        h = act_fn(linear(x, p["wi"], p.get("bi"), q=q), "gelu", q)
        return linear(h, p["wo"], p.get("bo"), q=q)
    elif kind == "none":
        return jnp.zeros_like(x)
    raise ValueError(kind)
