"""Quantization-aware layer primitives shared by every architecture.

Thin forwarding wrappers: each primitive dispatches through the pluggable
execution backend resolved once from the config (``q.datapath`` — the
``repro.datapath`` registry, DESIGN.md §12).  The five ``QuantConfig``
modes map onto three backends:

  'off' / 'fake'   -> ``xla_float``     plain XLA float ops; 'fake' adds
                      MXInt quantize-dequantize (straight-through grads)
                      on linear weights/activations.
  'sim' / 'packed' -> ``mxint_sim``     bit-accurate MXInt datapaths from
                      repro.core.nonlinear plus the Table II–V
                      ``emulate``/``nl_emulate`` baselines; 'packed'
                      consumes MXTensor weight leaves with the dequant
                      fused into the consuming XLA op (serving path).
  'kernel'         -> ``pallas_kernel`` the Pallas execution path
                      (repro.kernels.ops): packed int8 planes straight
                      into `mxint_linear`, in-kernel LN/GELU/softmax, and
                      the fused `layernorm_linear` composite.  Bit-exact
                      vs 'sim'.  Inference-only.

The public call signatures below are STABLE — external scripts
(examples/serve_deit_mxint.py, serve_llm_mxint.py) call them directly —
and no mode-string branching is allowed here (tools/check_dispatch.py
enforces that the dispatch seam stays inside repro/datapath/).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.mx_types import QuantConfig
# re-exported for external callers of the pre-refactor surface
from repro.core.quantize import MXTensor, dequantize  # noqa: F401
from repro.models.model_api import Param


# ---------------------------------------------------------------------------
# sharding hint (no-op off-mesh; constraint under pjit)
# ---------------------------------------------------------------------------
def shard_hint(x: jnp.ndarray, spec) -> jnp.ndarray:
    """Apply a with_sharding_constraint if a mesh is active."""
    from repro.parallel.sharding import maybe_constraint
    return maybe_constraint(x, spec)


# ---------------------------------------------------------------------------
# linears
# ---------------------------------------------------------------------------
def linear(x: jnp.ndarray, w: Param, b: Optional[Param] = None, *,
           q: QuantConfig, scope: Optional[str] = None) -> jnp.ndarray:
    """y = x @ w (+ b); w may be a packed MXTensor in serving mode.

    ``scope``: optional per-layer-group tag — the config's overrides are
    resolved here (``q.scoped``), so a scoped call site may run a
    different format or backend than the global config (DESIGN.md §16).
    """
    q = q.scoped(scope)
    return q.datapath.linear(x, w, b, q=q)


def _maybe_qdq_weight(w: jnp.ndarray, q: QuantConfig) -> jnp.ndarray:
    """Deprecated alias for ``q.datapath.qdq_weight`` (kept for external
    callers; forwards with no warning)."""
    return q.datapath.qdq_weight(w, q=q)


def embed_lookup(tokens: jnp.ndarray, table: Param, q: QuantConfig,
                 dtype) -> jnp.ndarray:
    tf = q.datapath.weight_value(table.value, q=q, dtype=dtype)
    return jnp.take(tf, tokens, axis=0)


def unembed(x: jnp.ndarray, table: Param, q: QuantConfig) -> jnp.ndarray:
    tf = q.datapath.weight_value(table.value, q=q, dtype=x.dtype)
    return jnp.einsum("...d,vd->...v", x, tf)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, gamma: Param, *, q: QuantConfig,
            eps: float = 1e-6) -> jnp.ndarray:
    return q.datapath.rmsnorm(x, gamma, q=q, eps=eps)


def layernorm(x: jnp.ndarray, gamma: Param, beta: Param, *, q: QuantConfig,
              eps: float = 1e-6,
              scope: Optional[str] = None) -> jnp.ndarray:
    q = q.scoped(scope)
    return q.datapath.layernorm(x, gamma, beta, q=q, eps=eps)


# ---------------------------------------------------------------------------
# composite: norm fused into the consuming linear (DESIGN.md §12)
# ---------------------------------------------------------------------------
def layernorm_linear(x: jnp.ndarray, gamma: Param, beta: Optional[Param],
                     w: Param, b: Optional[Param] = None, *,
                     q: QuantConfig, eps: float = 1e-6,
                     rms_only: bool = False) -> jnp.ndarray:
    """LayerNorm/RMSNorm immediately followed by a quantized linear.

    Uses the backend's fused composite when provided (``pallas_kernel``
    keeps the normalized act-quantized tile in VMEM — one HBM round-trip
    removed) and falls back to the two-op sequence otherwise.  Both paths
    are bit-identical under any one config (the composite-hook contract,
    asserted in tests/test_datapath.py), so blocks call this
    unconditionally.
    """
    dp = q.datapath
    if dp.layernorm_linear is not None:
        return dp.layernorm_linear(x, gamma, beta, w, b, q=q, eps=eps,
                                   rms_only=rms_only)
    h = (dp.rmsnorm(x, gamma, q=q, eps=eps) if rms_only
         else dp.layernorm(x, gamma, beta, q=q, eps=eps))
    return dp.linear(h, w, b, q=q)


def rmsnorm_linear(x: jnp.ndarray, gamma: Param, w: Param,
                   b: Optional[Param] = None, *, q: QuantConfig,
                   eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm -> linear through the same composite seam."""
    return layernorm_linear(x, gamma, None, w, b, q=q, eps=eps,
                            rms_only=True)


# ---------------------------------------------------------------------------
# activations / softmax
# ---------------------------------------------------------------------------
def act_fn(x: jnp.ndarray, kind: str, q: QuantConfig) -> jnp.ndarray:
    return q.datapath.act(x, kind, q=q)


def softmax(x: jnp.ndarray, q: QuantConfig, axis: int = -1) -> jnp.ndarray:
    return q.datapath.softmax(x, q=q, axis=axis)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, hd); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    # rank-1 frequency ladder on concrete constants, not a datapath op:
    # repro-lint: allow[models-float-nonlinear] positional constants
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) *
                    (jnp.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., s, half)
    cos = jnp.cos(ang)[..., None, :]                             # (...,s,1,half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------
def ffn(x: jnp.ndarray, p, kind: str, q: QuantConfig, prenorm=None,
        eps: float = 1e-6, scope: Optional[str] = None) -> jnp.ndarray:
    """p: dict with wi/wg/wo (gated) or wi/wo (plain).

    ``prenorm``: optional ('ln'|'rms', gamma, beta) — the block's pre-FFN
    norm, folded into the input linears via the ``layernorm_linear``
    composite when the backend provides it (beta is None for 'rms').
    Without a composite the norm runs once up front — the classic two-op
    block, bit-identical by the composite contract.

    ``scope``: optional layer-group tag; the whole FFN resolves one
    scoped config up front (DESIGN.md §16).
    """
    q = q.scoped(scope)
    _in_ws = [p["wi"], p["wg"]] if kind in ("swiglu", "geglu") else \
        ([p["wi"]] if kind == "gelu" else [])
    if prenorm is not None and not all(
            q.datapath.fuses_norm_linear(q, x, w) for w in _in_ws):
        # no fusion for EVERY input linear this norm feeds (config,
        # sharding or compiled-TPU tiling): normalize ONCE — a partial
        # answer would replay the norm inside the declining linears'
        # fallbacks
        nk, g, b_ = prenorm
        x = (rmsnorm(x, g, q=q, eps=eps) if nk == "rms"
             else layernorm(x, g, b_, q=q, eps=eps))
        prenorm = None

    def in_linear(w, b=None):
        if prenorm is None:
            return linear(x, w, b, q=q)
        nk, g, b_ = prenorm
        return layernorm_linear(x, g, b_, w, b, q=q, eps=eps,
                                rms_only=(nk == "rms"))

    if kind in ("swiglu", "geglu"):
        act = "silu" if kind == "swiglu" else "gelu"
        up = in_linear(p["wi"])
        gate = act_fn(in_linear(p["wg"]), act, q)
        return linear(up * gate, p["wo"], q=q)
    elif kind == "gelu":
        h = act_fn(in_linear(p["wi"], p.get("bi")), "gelu", q)
        return linear(h, p["wo"], p.get("bo"), q=q)
    elif kind == "none":
        return jnp.zeros_like(x)
    raise ValueError(kind)
