"""Recurrent mixers: RG-LRU (RecurrentGemma) and mLSTM/sLSTM (xLSTM).

All three are sub-quadratic — they carry O(1)-per-token state, which is why
the long_500k shape runs for these families (DESIGN.md §6).

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_i x_t)
    a_t = exp(c * softplus(Lambda) * (-r_t))        # 'a' in (0,1), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
  Training uses jax.lax.associative_scan on the linear recurrence (parallel,
  O(log T) depth); decode is the one-step update.  The block wraps the LRU
  with linear_x -> temporal conv(4) -> LRU, gated by GELU(linear_y), then
  linear_out — the RecurrentGemma recurrent block.

mLSTM (arXiv:2405.04517), chunkwise-parallel form:
    C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    h_t = o_t * (C_t q_t) / max(|n_t . q_t|, 1)
  with scalar-per-head gates.  Implemented as gated linear attention over
  chunks: the carry (C, n) crosses chunk boundaries, intra-chunk terms are
  a masked quadratic within the chunk only -> O(T * chunk) work.  The
  exponential input gate runs through the paper's pow2-LUT datapath when
  quantize_nonlinear is on (the MXInt exp — DESIGN.md §6 'xlstm' row).

sLSTM: scalar memory, inherently sequential -> lax.scan over time.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mx_types import QuantConfig
from repro.models import layers as L
from repro.models.model_api import ModelConfig, Param, dense_init, zeros_init

_C_RGLRU = 8.0


# ===========================================================================
# RG-LRU
# ===========================================================================
def init_rglru_params(key, cfg: ModelConfig, dtype) -> Dict[str, Param]:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "linear_y": dense_init(ks[0], (d, w), ("embed", "lru"), dtype=dtype),
        "linear_x": dense_init(ks[1], (d, w), ("embed", "lru"), dtype=dtype),
        "linear_out": dense_init(ks[2], (w, d), ("lru", "embed"), dtype=dtype),
        "conv_w": dense_init(ks[3], (cfg.conv_width, w), ("conv", "lru"),
                             scale=0.5, dtype=dtype),
        "conv_b": zeros_init((w,), ("lru",), dtype=dtype),
        "w_a": dense_init(ks[4], (w, w), ("lru", None), dtype=dtype),
        "w_i": dense_init(ks[5], (w, w), ("lru", None), dtype=dtype),
        "lam": Param(jnp.linspace(0.3, 1.7, w).astype(dtype), ("lru",)),
    }


def _rglru_gates(p, x, quant):
    r = jax.nn.sigmoid(L.linear(x, p["w_a"], q=quant).astype(jnp.float32))
    i = jax.nn.sigmoid(L.linear(x, p["w_i"], q=quant).astype(jnp.float32))
    log_a = -_C_RGLRU * jax.nn.softplus(
        p["lam"].value.astype(jnp.float32)) * r     # log a_t <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * (i * x.astype(jnp.float32))


def rglru_scan(p, x: jnp.ndarray, quant: QuantConfig,
               h0: Optional[jnp.ndarray] = None):
    """x: (b, s, w). Parallel associative scan over the linear recurrence."""
    a, b_in = _rglru_gates(p, x, quant)               # (b, s, w) each

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        b_in = b_in.at[:, 0].add(a[:, 0] * h0)
    aa, hh = jax.lax.associative_scan(combine, (a, b_in), axis=1)
    return hh.astype(x.dtype), hh[:, -1]              # outputs, final state


def rglru_step(p, x: jnp.ndarray, h: jnp.ndarray, quant: QuantConfig):
    """x: (b, 1, w); h: (b, w)."""
    a, b_in = _rglru_gates(p, x, quant)
    h_new = a[:, 0] * h + b_in[:, 0]
    return h_new.astype(x.dtype)[:, None], h_new


def _temporal_conv(p, x: jnp.ndarray, state: Optional[jnp.ndarray]):
    """Depthwise causal conv width K.  state: (b, K-1, w) history."""
    K = p["conv_w"].value.shape[0]
    w = p["conv_w"].value.astype(jnp.float32)
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1).astype(jnp.float32)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):].astype(x.dtype) if K > 1 else None
    return (out + p["conv_b"].value.astype(jnp.float32)).astype(x.dtype), \
        new_state


def rglru_block(p, x: jnp.ndarray, cfg: ModelConfig, *, quant: QuantConfig,
                state: Optional[Dict[str, jnp.ndarray]] = None,
                decode: bool = False):
    """RecurrentGemma recurrent block.  state: {'conv': (b,K-1,w),
    'h': (b,w)} or None."""
    y = L.act_fn(L.linear(x, p["linear_y"], q=quant), "gelu", quant)
    u = L.linear(x, p["linear_x"], q=quant)
    conv_state = state["conv"] if state is not None else None
    u, new_conv = _temporal_conv(p, u, conv_state)
    if decode:
        out, h_new = rglru_step(p, u, state["h"], quant)
    else:
        h0 = state["h"] if state is not None else None
        out, h_new = rglru_scan(p, u, quant, h0)
    o = L.linear(out * y, p["linear_out"], q=quant)
    new_state = {"conv": new_conv, "h": h_new.astype(x.dtype)}
    return o, new_state


def rglru_state_init(cfg: ModelConfig, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
            "h": jnp.zeros((batch, w), dtype)}


def rglru_state_specs(cfg: ModelConfig, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    return {"conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w),
                                         dtype),
            "h": jax.ShapeDtypeStruct((batch, w), dtype)}


RGLRU_STATE_AXES = {"conv": ("batch", None, "lru"), "h": ("batch", "lru")}


# ===========================================================================
# mLSTM (chunkwise gated linear attention form)
# ===========================================================================
def init_mlstm_params(key, cfg: ModelConfig, dtype) -> Dict[str, Param]:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    proj = H * hd
    ks = jax.random.split(key, 8)
    return {
        "wq": dense_init(ks[0], (d, proj), ("embed", "q_heads"), dtype=dtype),
        "wk": dense_init(ks[1], (d, proj), ("embed", "q_heads"), dtype=dtype),
        "wv": dense_init(ks[2], (d, proj), ("embed", "q_heads"), dtype=dtype),
        "wo": dense_init(ks[3], (proj, d), ("q_heads", "embed"), dtype=dtype),
        "w_f": dense_init(ks[4], (d, H), ("embed", "heads"), dtype=dtype),
        "b_f": Param(jnp.full((H,), 3.0, dtype), ("heads",)),
        "w_i": dense_init(ks[5], (d, H), ("embed", "heads"), dtype=dtype),
        "up": dense_init(ks[6], (d, 2 * d), ("embed", "mlp"), dtype=dtype),
        "down": dense_init(ks[7], (d, d), ("mlp", "embed"), dtype=dtype),
    }


def _mlstm_gates(p, x, quant):
    """Scalar-per-head gates; exp input gate through the MXInt pow2 datapath
    when the quant config routes non-linearities through the paper's LUTs."""
    f_logit = L.linear(x, p["w_f"], q=quant).astype(jnp.float32) + \
        p["b_f"].value.astype(jnp.float32)
    log_f = -jax.nn.softplus(-f_logit)               # log sigmoid(f) <= 0
    i_logit = L.linear(x, p["w_i"], q=quant).astype(jnp.float32)
    log_i = jnp.minimum(i_logit, 0.0)                # stabilized exp gate
    # backend exp: the mxint_sim datapath runs the Eq. 14-19 pow2 LUT when
    # softmax non-linearities are quantized; float e^x everywhere else
    i_gate = quant.datapath.exp(log_i, q=quant)
    return log_f, i_gate


def mlstm_scan(p, x: jnp.ndarray, cfg: ModelConfig, quant: QuantConfig,
               state: Optional[Tuple] = None, chunk: int = 256):
    """Chunkwise-parallel mLSTM.  x: (b, s, d) -> (y, (C, n) final)."""
    b, s, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = L.linear(x, p["wq"], q=quant).reshape(b, s, H, hd) * (hd ** -0.5)
    k = L.linear(x, p["wk"], q=quant).reshape(b, s, H, hd) * (hd ** -0.5)
    v = L.linear(x, p["wv"], q=quant).reshape(b, s, H, hd)
    log_f, i_gate = _mlstm_gates(p, x, quant)        # (b, s, H)

    chunk = min(chunk, s)
    assert s % chunk == 0
    n_chunks = s // chunk

    def to_chunks(t):
        return jnp.swapaxes(
            t.reshape(b, n_chunks, chunk, *t.shape[2:]), 0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lfc, igc = to_chunks(log_f), to_chunks(i_gate)

    if state is None:
        C0 = jnp.zeros((b, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, H, hd), jnp.float32)
    else:
        C0, n0 = state

    def step(carry, inp):
        C, n = carry
        qb, kb, vb, lf, ig = inp                     # (b,c,H,*) each
        qf, kf, vf = (t.astype(jnp.float32) for t in (qb, kb, vb))
        lf_cum = jnp.cumsum(lf, axis=1)              # (b, c, H)
        # inter-chunk: h_inter_t = (prod f up to t) * C_in q_t
        decay_q = jnp.exp(lf_cum)                    # (b, c, H)
        h_inter = jnp.einsum("bchd,bhde->bche", qf * decay_q[..., None], C)
        n_inter = jnp.einsum("bchd,bhd->bch", qf * decay_q[..., None], n)
        # intra-chunk: masked quadratic with relative decay
        rel = lf_cum[:, :, None, :] - lf_cum[:, None, :, :]   # (b,c,c,H) t>=s
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0)
        w = w * ig[:, None, :, :]                    # input gate at source s
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf)   # q_t . k_s
        sw = scores * w
        h_intra = jnp.einsum("btsh,bshe->bthe", sw, vf)
        n_intra = jnp.sum(sw, axis=2)                    # (n_t . q_t) intra
        h = h_inter + h_intra
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), 1.0)[..., None]
        out = h / denom                               # (b, c, H, hd)
        # carry update
        total_decay = jnp.exp(lf_cum[:, -1])          # (b, H)
        src_decay = jnp.exp(lf_cum[:, -1:, :] - lf_cum)   # decay to chunk end
        kw = kf * (src_decay * ig)[..., None]
        C_new = C * total_decay[:, :, None, None] + \
            jnp.einsum("bchd,bche->bhde", kw, vf)
        n_new = n * total_decay[:, :, None] + jnp.einsum("bchd->bhd", kw)
        return (C_new, n_new), out

    (C, n), outs = jax.lax.scan(step, (C0, n0), (qc, kc, vc, lfc, igc))
    y = jnp.swapaxes(outs, 0, 1).reshape(b, s, H * hd).astype(x.dtype)
    return y, (C, n)


def mlstm_step(p, x: jnp.ndarray, cfg: ModelConfig, quant: QuantConfig,
               state: Tuple):
    """Single-token decode.  x: (b, 1, d); state: (C (b,H,hd,hd), n)."""
    b = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    q = L.linear(x, p["wq"], q=quant).reshape(b, H, hd).astype(jnp.float32) \
        * (hd ** -0.5)
    k = L.linear(x, p["wk"], q=quant).reshape(b, H, hd).astype(jnp.float32) \
        * (hd ** -0.5)
    v = L.linear(x, p["wv"], q=quant).reshape(b, H, hd).astype(jnp.float32)
    log_f, i_gate = _mlstm_gates(p, x, quant)
    f = jnp.exp(log_f[:, 0])                          # (b, H)
    ig = i_gate[:, 0]
    C, n = state
    C = C * f[:, :, None, None] + jnp.einsum(
        "bhd,bhe->bhde", k * ig[..., None], v)
    n = n * f[:, :, None] + k * ig[..., None]
    h = jnp.einsum("bhde,bhd->bhe", C, q)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0)
    out = (h / denom[..., None]).reshape(b, 1, H * hd).astype(x.dtype)
    return out, (C, n)


def mlstm_block(p, x, cfg, *, quant, state=None, decode=False):
    """mLSTM mixer + its internal up/down projection (xLSTM block style)."""
    if decode:
        inner, new_state = mlstm_step(p, x, cfg, quant, state)
    else:
        inner, new_state = mlstm_scan(p, x, cfg, quant, state)
    o = L.linear(inner, p["wo"], q=quant)
    # position-wise gated up/down (xLSTM projects around the mixer)
    u = L.linear(x + o, p["up"], q=quant)
    u1, u2 = jnp.split(u, 2, axis=-1)
    return L.linear(u1 * jax.nn.sigmoid(u2.astype(jnp.float32)).astype(
        x.dtype), p["down"], q=quant), new_state


def mlstm_state_init(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.hd
    return (jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32))


def mlstm_state_specs(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.hd
    return (jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
            jax.ShapeDtypeStruct((batch, H, hd), jnp.float32))


MLSTM_STATE_AXES = (("batch", "heads", None, None), ("batch", "heads", None))


# ===========================================================================
# sLSTM (sequential scalar memory)
# ===========================================================================
def init_slstm_params(key, cfg: ModelConfig, dtype) -> Dict[str, Param]:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), ("embed", "mlp"), dtype=dtype),
        "r_in": dense_init(ks[1], (d, 4 * d), ("embed", "mlp"),
                           scale=0.1, dtype=dtype),
        "b_in": zeros_init((4 * d,), ("mlp",), dtype=dtype),
        "wo": dense_init(ks[2], (d, d), ("embed", "embed"), dtype=dtype),
    }


def _slstm_cell(p, xt, state, quant):
    """xt: (b, d); state: (h, c, n, m) each (b, d)."""
    h, c, n, m = state
    z = L.linear(xt, p["w_in"], q=quant).astype(jnp.float32) + \
        L.linear(h, p["r_in"], q=quant).astype(jnp.float32) + \
        p["b_in"].value.astype(jnp.float32)
    zi, zf, zz, zo = jnp.split(z, 4, axis=-1)
    # exponential gating with stabilizer state m (xLSTM Eq. 15-17)
    log_i = jnp.minimum(zi, 0.0)
    log_f = -jax.nn.softplus(-zf)
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(zz)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1.0)
    return h_new, c_new, n_new, m_new


def slstm_scan(p, x, cfg, quant, state=None):
    b, s, d = x.shape
    if state is None:
        state = slstm_state_init(cfg, b)

    def step(carry, xt):
        new = _slstm_cell(p, xt, carry, quant)
        return new, new[0]

    state_f = tuple(t.astype(jnp.float32) for t in state)
    final, hs = jax.lax.scan(step, state_f, jnp.swapaxes(x, 0, 1))
    y = jnp.swapaxes(hs, 0, 1).astype(x.dtype)
    return L.linear(y, p["wo"], q=quant), final


def slstm_step(p, x, cfg, quant, state):
    new = _slstm_cell(p, x[:, 0], state, quant)
    return L.linear(new[0][:, None].astype(x.dtype), p["wo"], q=quant), new


def slstm_state_init(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, z)


def slstm_state_specs(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    s = jax.ShapeDtypeStruct((batch, d), jnp.float32)
    return (s, s, s, s)


SLSTM_STATE_AXES = tuple(("batch", None) for _ in range(4))
