from repro.models.model_api import (ModelConfig, MoEConfig, ShapeConfig,
                                    Param, unwrap, axes_tree, is_param,
                                    TRAIN_4K, PREFILL_32K, DECODE_32K,
                                    LONG_500K, ALL_SHAPES, shape_by_name)
from repro.models.transformer import DecoderLM, EncDecLM
from repro.models.vit import ViT


def build_model(cfg: ModelConfig):
    if cfg.family == "vit":
        return ViT(cfg)
    if cfg.is_encoder_decoder:
        return EncDecLM(cfg)
    return DecoderLM(cfg)
