"""ViT / DeiT — the paper's own model family (§IV: DeiT Tiny/Small/Base).

Patchify is an exact reshape + linear (equivalent to the stride-16 conv),
class token + learned position embeddings, pre-LayerNorm encoder blocks with
GELU MLPs, classification head on the CLS token — the standard DeiT
architecture the paper quantizes.

Every operator routes through the quantization-aware layer primitives, so a
`QuantConfig(mode='sim', quantize_nonlinear=True)` config runs the FULL
bit-accurate MXInt datapath end-to-end: MXInt linears, Fig-3 LayerNorm,
Eq-12 GELU and Eq-14..20 Softmax — the configuration of the paper's final
accelerator.  `mode='kernel'` runs the same datapath through the Pallas
kernels: packed int8 weight planes into `mxint_linear`, the non-linear ops
and the attention softmax in-kernel — bit-identical to 'sim' (enforced by
tests/test_kernel_mode.py) and the deployment path of
`serving.ViTServingEngine`.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.mx_types import QuantConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models.model_api import (ModelConfig, Param, dense_init,
                                    ones_init, zeros_init, is_param)
from repro.models.transformer import _stacked_init


class ViT:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.image_size % cfg.patch_size == 0
        self.n_patches = (cfg.image_size // cfg.patch_size) ** 2
        self.seq = self.n_patches + 1                     # + CLS

    # -- params -------------------------------------------------------------
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        dtype = cfg.dtype
        patch_dim = cfg.patch_size * cfg.patch_size * 3
        ks = jax.random.split(rng, 6)
        params = {
            "patch_proj": dense_init(ks[0], (patch_dim, d),
                                     ("patch", "embed"), dtype=dtype),
            "patch_bias": zeros_init((d,), ("embed",), dtype=dtype),
            "cls_token": dense_init(ks[1], (1, 1, d), (None, None, "embed"),
                                    scale=0.02, dtype=dtype),
            "pos_embed": dense_init(ks[2], (self.seq, d), (None, "embed"),
                                    scale=0.02, dtype=dtype),
            "blocks": _stacked_init(lambda k: self._init_block(k, dtype),
                                    ks[3], cfg.n_layers),
            "final_ln_g": ones_init((d,), ("embed",), dtype=dtype),
            "final_ln_b": zeros_init((d,), ("embed",), dtype=dtype),
            "head": dense_init(ks[4], (d, cfg.n_classes),
                               ("embed", "classes"), dtype=dtype),
            "head_b": zeros_init((cfg.n_classes,), ("classes",), dtype=dtype),
        }
        return params

    def _init_block(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        return {
            "ln1_g": ones_init((cfg.d_model,), ("embed",), dtype=dtype),
            "ln1_b": zeros_init((cfg.d_model,), ("embed",), dtype=dtype),
            "attn": A.init_attn_params(ks[0], cfg, dtype),
            "ln2_g": ones_init((cfg.d_model,), ("embed",), dtype=dtype),
            "ln2_b": zeros_init((cfg.d_model,), ("embed",), dtype=dtype),
            "ffn": {
                "wi": dense_init(ks[1], (cfg.d_model, cfg.d_ff),
                                 ("embed", "mlp"), dtype=dtype),
                "bi": zeros_init((cfg.d_ff,), ("mlp",), dtype=dtype),
                "wo": dense_init(ks[2], (cfg.d_ff, cfg.d_model),
                                 ("mlp", "embed"), dtype=dtype),
                "bo": zeros_init((cfg.d_model,), ("embed",), dtype=dtype),
            },
        }

    # -- forward --------------------------------------------------------------
    def patchify(self, images: jnp.ndarray) -> jnp.ndarray:
        """(b, H, W, 3) -> (b, n_patches, patch_dim); exact stride-P conv.

        Channel-major feature layout (c slowest) so per-block shared
        exponents align with channels — microscaling then isolates
        outlier channels into their own blocks (paper Fig. 1a rationale).
        """
        cfg = self.cfg
        b, h, w, c = images.shape
        p = cfg.patch_size
        x = images.reshape(b, h // p, p, w // p, p, c)
        x = x.transpose(0, 1, 3, 5, 2, 4)
        return x.reshape(b, (h // p) * (w // p), c * p * p)

    def features(self, params, images):
        cfg = self.cfg
        quant = cfg.quant
        x = self.patchify(images.astype(cfg.dtype))
        x = L.linear(x, params["patch_proj"], params["patch_bias"], q=quant,
                     scope="patch")
        cls = jnp.broadcast_to(params["cls_token"].value.astype(x.dtype),
                               (x.shape[0], 1, x.shape[-1]))
        x = jnp.concatenate([cls, x], axis=1)
        x = x + params["pos_embed"].value.astype(x.dtype)[None]

        def block(x, bp, attn_scope=None, ffn_scope=None):
            # pre-norms ride into the consuming linears through the
            # layernorm_linear composite seam: fused LN->qkv / LN->wi in
            # kernel mode, norm-then-linear otherwise (DESIGN.md §12)
            o, _ = A.attention(bp["attn"], x, cfg, quant=quant,
                               positions=jnp.arange(x.shape[1])[None, :],
                               causal=False, use_rope=False,
                               prenorm=("ln", bp["ln1_g"], bp["ln1_b"]),
                               scope=attn_scope)
            x = x + o
            return x + L.ffn(x, bp["ffn"], "gelu", quant,
                             prenorm=("ln", bp["ln2_g"], bp["ln2_b"]),
                             eps=cfg.norm_eps, scope=ffn_scope)

        remat = cfg.remat in ("block", "full")
        if quant.has_overrides:
            # per-layer-group overrides are STATIC per block (different
            # formats/backends per layer), which one scanned trace cannot
            # carry — unroll over blocks, slicing each layer's params out
            # of the stacked tree (DESIGN.md §16)
            for i in range(cfg.n_layers):
                bp = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                            params["blocks"])
                step = (lambda x, bp=bp, i=i:
                        block(x, bp, f"block/{i}/attn", f"block/{i}/ffn"))
                x = jax.checkpoint(step)(x) if remat else step(x)
        else:
            def scan_block(x, bp):
                return block(x, bp), None
            if remat:
                scan_block = jax.checkpoint(scan_block)
            x, _ = jax.lax.scan(scan_block, x, params["blocks"])
        return L.layernorm(x, params["final_ln_g"], params["final_ln_b"],
                           q=quant, eps=cfg.norm_eps, scope="final_ln")

    def logits(self, params, images):
        x = self.features(params, images)
        pooled = x[:, 0] if self.cfg.pool == "cls" else x.mean(1)
        return L.linear(pooled, params["head"], params["head_b"],
                        q=self.cfg.quant, scope="head")

    def loss(self, params, batch):
        """batch: {'images': (b,H,W,3), 'labels': (b,) int32}."""
        logits = self.logits(params, batch["images"]).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, batch["labels"][:, None], -1)[:, 0]
        return jnp.mean(nll)

    def accuracy(self, params, batch):
        logits = self.logits(params, batch["images"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                        .astype(jnp.float32))
