"""Attention for every arch: GQA/MQA, RoPE, qk-norm, sliding window, caches.

This module owns the attention ORCHESTRATION — projections, RoPE, cache
ring arithmetic, mask semantics — while the execution of the attention
math itself dispatches through the pluggable backend resolved from the
config (``quant.datapath`` — DESIGN.md §12):

  * xla_float / mxint_sim — masked softmax on the full score matrix
               (direct; the paper's whole-row ViT path, also the MXInt
               'sim' datapath) or the lax.scan online-softmax over query
               blocks for score matrices that would not fit (32k prefill,
               4k training).  The direct/chunked helpers below are shared
               by both backends.
  * pallas_kernel — repro.kernels.ops.attention_op: the whole-row Pallas
               MXInt softmax ('paper' variant, bit-identical to the sim
               direct path) when quantize_nonlinear is set and the score
               matrix is small, the blocked mxint flash kernel (Eq. 14-20
               without the O(S^2) scores, DESIGN.md §11) for long
               sequences, the float flash kernel otherwise.  Decode
               (s == 1 with a cache) goes through
               ops.attention_decode_op — scoring, softmax and p @ V fused
               in one Pallas kernel over the cache ring.

``prenorm``: blocks may hand their pre-attention norm parameters to
``attention`` instead of normalizing first; the q/k/v projections then
ride the backend's fused ``layernorm_linear`` composite when it exists
(kernel mode: normalized tile stays in VMEM) and fall back to the
norm-then-linear sequence otherwise — bit-identical either way.

KV caches:
  full ring: (b, kv_heads, S_max, hd) with dynamic_update_slice writes.
  sliding window: ring buffer of size W; slot i at step t holds absolute
  position t - ((t - i) mod W) — no position side-array needed.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mx_types import NEG_INF as _NEG_INF
from repro.core.mx_types import QuantConfig
from repro.models import layers as L
from repro.models.model_api import ModelConfig, Param, dense_init, ones_init


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_attn_params(key, cfg: ModelConfig, dtype, cross: bool = False):
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads * hd),
                         ("embed", "q_heads"), dtype=dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd),
                         ("embed", "kv_heads"), dtype=dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd),
                         ("embed", "kv_heads"), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, cfg.d_model),
                         ("q_heads", "embed"), dtype=dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = ones_init((hd,), (None,), dtype=dtype)
        p["k_norm"] = ones_init((hd,), (None,), dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# score/softmax cores
# ---------------------------------------------------------------------------
def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _gqa_scores(q, k, scale):
    """q: (b, s, kv, g, hd); k: (b, S, kv, hd) -> (b, kv, g, s, S)."""
    return jnp.einsum("bskgd,bSkd->bkgsS", q, k) * scale


def positions_mask(positions, s: int, kv_len: int, causal: bool,
                   window: int) -> jnp.ndarray:
    """(1|b, s, kv_len) bool mask from per-row positions.

    per-ROW masks: positions may be (b, s) with ragged per-batch offsets
    (left-padded prompts) — collapsing to the last batch row's positions
    masked every other row wrongly (ISSUE 3).  Self-attention keys are
    the same tokens, so they carry the same position VALUES: comparing q
    values against key INDICES would let offset rows attend their own
    future (position relabeling must be a no-op when rope is off).
    """
    pos2 = positions if positions.ndim == 2 else positions.reshape(1, -1)
    q_pos = pos2[:, -s:]                             # (1|b, s)
    if kv_len == s:
        k_pos = q_pos[:, None, :]                    # self-attn: values
    else:
        k_pos = jnp.arange(kv_len)[None, None, :]    # cross: indices
    mask = jnp.ones((q_pos.shape[0], s, kv_len), dtype=bool)
    if causal:
        mask &= q_pos[:, :, None] >= k_pos
    if window > 0:
        mask &= (q_pos[:, :, None] - k_pos) < window
    return mask


def _direct_attention(q, k, v, mask, quant: QuantConfig, scale):
    s = _gqa_scores(q, k, scale)
    s = jnp.where(mask, s.astype(jnp.float32), _NEG_INF)
    p = L.softmax(s, quant, axis=-1).astype(q.dtype)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bkgsS,bSkd->bskgd", p, v)


def _q_chunked_attention(q, k, v, *, q_offset, causal, window, chunk, scale,
                         positions=None):
    """Attention chunked over QUERY blocks (lax.scan, no carry).

    For long prefill the kv-chunked online-softmax form drags a
    (b, heads, s, hd) f32 accumulator through every scan iteration — at 32k
    that carry alone is GBs of HBM round-trips per chunk (§Perf iteration
    log, llama3 prefill).  Query blocks are independent: each block does one
    full-width softmax, there is no carry, and the score tensor crosses
    fusion boundaries in bf16 (the f32 accumulation lives inside the dot).
    On real TPU the Pallas flash kernel keeps scores in VMEM entirely; this
    is the XLA-path equivalent structure.

    positions: optional (1|b, >=s) per-row positions with the exact mask
    semantics of ``positions_mask`` — ragged/left-padded batches mask each
    row from its own position VALUES (self-attn keys carry the same values;
    cross keys stay contiguous indices).  ``None`` keeps the contiguous
    ``q_offset + row-index`` arithmetic.
    """
    b, s, kv, g, hd = q.shape
    S = k.shape[1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nq = s // chunk
    # fold the softmax scale into q (one fused pass instead of a full-score
    # rescale) and pre-transpose K/V ONCE to the dot layouts — leaving them
    # (b, S, kv, hd) made XLA re-copy them inside every q-block iteration
    # (§Perf: llama3 prefill, copy_bitcast_fusion ~1TB).
    qs = (q * scale).astype(q.dtype)
    qc = jnp.swapaxes(qs.reshape(b, nq, chunk, kv, g, hd), 0, 1)
    kt = jnp.einsum("bSkd->bkdS", k)
    vt = jnp.einsum("bSkd->bkSd", v)
    k_pos = jnp.arange(S, dtype=jnp.int32)[None, :]            # (1, S)
    if positions is None:
        pc = (q_offset + jnp.arange(s, dtype=jnp.int32)).reshape(nq, 1, chunk)
    else:
        pos2 = positions if positions.ndim == 2 else positions.reshape(1, -1)
        q_pos = pos2[:, -s:].astype(jnp.int32)                 # (1|b, s)
        if S == s:
            k_pos = q_pos                                      # self: values
        rows = q_pos.shape[0]
        pc = jnp.swapaxes(q_pos.reshape(rows, nq, chunk), 0, 1)
    neg = jnp.asarray(jnp.finfo(q.dtype).min, q.dtype)

    def block(_, inp):
        qb, qp = inp                                           # qp: (1|b, c)
        # f32 accumulation inside the dot; scores cross the fusion boundary
        # in the model dtype (halves every downstream score pass)
        s_blk = jnp.einsum("bckgd,bkdS->bkgcS", qb, kt,
                           preferred_element_type=jnp.float32
                           ).astype(q.dtype)
        mask = jnp.ones((qp.shape[0], chunk, S), dtype=bool)
        if causal:
            mask &= qp[:, :, None] >= k_pos[:, None, :]
        if window > 0:
            mask &= (qp[:, :, None] - k_pos[:, None, :]) < window
        s_blk = jnp.where(mask[:, None, None], s_blk, neg)
        m = jnp.max(s_blk, axis=-1, keepdims=True)
        # exp(neg - m) == 0 and every query row sees at least itself (its
        # own position value), so no second masking pass is needed
        p = jnp.exp((s_blk - m).astype(jnp.float32))
        l = jnp.sum(p, axis=-1, keepdims=True)
        pb = (p / jnp.maximum(l, 1e-30)).astype(q.dtype)
        o = jnp.einsum("bkgcS,bkSd->bckgd", pb, vt,
                       preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    _, outs = jax.lax.scan(block, None, (qc, pc))
    return jnp.swapaxes(outs, 0, 1).reshape(b, s, kv, g, hd)


def _chunked_attention(q, k, v, *, q_offset, causal, window, chunk, scale):
    """Online-softmax over KV chunks via lax.scan; O(s*chunk) live memory.

    RETAINED FOR COMPARISON ONLY: superseded by _q_chunked_attention after
    the §Perf llama3-prefill iteration showed the (m, l, acc) scan carry
    costs GBs of HBM round-trips per chunk (EXPERIMENTS.md §4, cell C).
    Still the right shape when queries are few and keys huge AND a carry is
    acceptable (e.g. speculative scoring); kept tested via kernels/ref.
    """
    b, s, kv, g, hd = q.shape
    S = k.shape[1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    kc = jnp.swapaxes(k.reshape(b, n_chunks, chunk, kv, hd), 0, 1)
    vc = jnp.swapaxes(v.reshape(b, n_chunks, chunk, kv, hd), 0, 1)

    q_pos = q_offset + jnp.arange(s)
    qf = q.astype(jnp.float32)

    def step(carry, inputs):
        m, l, acc = carry
        ci, kb, vb = inputs
        s_blk = _gqa_scores(qf, kb.astype(jnp.float32), scale)  # (b,kv,g,s,c)
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((s, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s_blk = jnp.where(mask[None, None, None], s_blk, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s_blk, -1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s_blk - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        l_new = l * alpha + jnp.sum(p, -1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgsc,bckd->bkgsd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, s), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, s), jnp.float32)
    a0 = jnp.zeros((b, kv, g, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.einsum("bkgsd->bskgd", out).astype(q.dtype)


# ---------------------------------------------------------------------------
# cache helpers
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window: int,
                  dtype) -> Dict[str, jnp.ndarray]:
    W = min(max_len, window) if window > 0 else max_len
    shape = (batch, W, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(cfg: ModelConfig, batch: int, max_len: int, window: int,
                   dtype):
    W = min(max_len, window) if window > 0 else max_len
    shape = (batch, W, cfg.n_kv_heads, cfg.hd)
    sds = jax.ShapeDtypeStruct(shape, dtype)
    return {"k": sds, "v": sds}


CACHE_AXES = ("batch", "kv_seq", "kv_heads", None)
CACHE_AXES_TREE = {"k": CACHE_AXES, "v": CACHE_AXES}


# ---------------------------------------------------------------------------
# main entry
# ---------------------------------------------------------------------------
def attention(p, x: jnp.ndarray, cfg: ModelConfig, *,
              quant: QuantConfig,
              positions: Optional[jnp.ndarray] = None,
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              cache_index: Optional[jnp.ndarray] = None,
              window: int = 0,
              causal: bool = True,
              kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              use_rope: bool = True,
              chunk: int = 1024,
              prenorm: Optional[Tuple] = None,
              scope: Optional[str] = None):
    """Returns (output (b, s, d), updated cache or None).

    Modes:
      cache=None                      -> training / encoder (no state)
      cache given, s > 1              -> prefill (writes 0..s)
      cache given, s == 1             -> decode at cache_index
      kv_override                     -> cross attention (encoder K/V)

    prenorm: optional ('ln'|'rms', gamma, beta) — the block's
    pre-attention norm.  When given, x arrives UN-normalized and the
    q/k/v projections run through the ``layernorm_linear`` composite
    (fused on backends that provide it; norm-then-linear otherwise —
    bit-identical, DESIGN.md §12).  beta is None for 'rms'.

    scope: optional layer-group tag ("block/3/attn"); per-layer
    overrides on the config resolve ONCE here (``quant.scoped``), and
    the whole attention op — projections, scores, softmax — runs the
    scoped config (DESIGN.md §16).
    """
    quant = quant.scoped(scope)
    b, s, _ = x.shape
    hd = cfg.hd
    kvh = cfg.n_kv_heads
    g = cfg.n_heads // kvh
    scale = hd ** -0.5

    _proj_ws = [p["wq"]] if kv_override is not None else \
        [p["wq"], p["wk"], p["wv"]]
    if prenorm is not None and not all(
            quant.datapath.fuses_norm_linear(quant, x, w)
            for w in _proj_ws):
        # no fusion for EVERY projection this call feeds (config,
        # sharding, or compiled-TPU tiling — GQA gives wk/wv a different
        # N than wq): normalize ONCE up front — the classic block; a
        # partial answer would replay the norm inside the declining
        # projections' fallbacks
        nk, ng, nb = prenorm
        x = (L.rmsnorm(x, ng, q=quant, eps=cfg.norm_eps) if nk == "rms"
             else L.layernorm(x, ng, nb, q=quant, eps=cfg.norm_eps))
        prenorm = None

    def in_proj(w):
        if prenorm is None:
            return L.linear(x, w, q=quant)
        nk, ng, nb = prenorm
        return L.layernorm_linear(x, ng, nb, w, q=quant, eps=cfg.norm_eps,
                                  rms_only=(nk == "rms"))

    q = _split_heads(in_proj(p["wq"]), cfg.n_heads, hd)
    if kv_override is None:
        k = _split_heads(in_proj(p["wk"]), kvh, hd)
        v = _split_heads(in_proj(p["wv"]), kvh, hd)
    else:
        k, v = kv_override

    if cfg.qk_norm and "q_norm" in p:
        q = L.rmsnorm(q, p["q_norm"], q=quant, eps=cfg.norm_eps)
        if kv_override is None:
            k = L.rmsnorm(k, p["k_norm"], q=quant, eps=cfg.norm_eps)

    if positions is None:
        if cache_index is not None:
            base = jnp.asarray(cache_index, jnp.int32)
            if base.ndim == 1:                           # per-row (b,) index
                base = base[:, None]
        else:
            base = 0
        positions = base + jnp.arange(s)[None, :]        # (1|b, s)
    if use_rope:
        q = L.rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = L.rope(k, positions, cfg.rope_theta)

    q = q.reshape(b, s, kvh, g, hd)
    new_cache = None

    if cache is not None and kv_override is None:
        W = cache["k"].shape[1]
        if s == 1:
            # decode at PER-ROW indices: cache_index may be () (legacy
            # scalar, e.g. the encoder-decoder stack) or (b,) — scalars
            # broadcast so every consumer below sees one (b,) contract.
            # Row i writes its own slot and masks its own ring validity,
            # which is what lets a freshly prefilled slot coexist with
            # rows deep into decode (slot-level batching, DESIGN.md §7).
            idx = jnp.asarray(cache_index, jnp.int32)
            if idx.ndim == 0:
                idx = jnp.broadcast_to(idx, (b,))
            slot = (idx % W) if window > 0 else idx          # (b,)
            rows = jnp.arange(b)
            ck = cache["k"].at[rows, slot].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, slot].set(
                v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            # absolute position of every slot, per row
            t = idx[:, None]                                 # (b, 1)
            pos = jnp.arange(W)[None, :]                     # (1, W)
            if window > 0:
                slot_pos = t - jnp.mod(t - pos, W)
            else:
                slot_pos = jnp.broadcast_to(pos, (b, W))
            valid = (slot_pos >= 0) & (slot_pos <= t)        # (b, W)
            if window > 0:
                valid &= (t - slot_pos) < window
            # backend decode: pallas_kernel runs one fused Pallas kernel
            # over the ring (scoring + online softmax + p @ V, no XLA
            # L.softmax in the trace — DESIGN.md §11); the XLA backends
            # score the ring directly through their own softmax
            o = quant.datapath.attention_decode(q, ck, cv, valid, q=quant,
                                                scale=scale)
        elif window > 0 and s >= W:
            # SWA prefill longer than the ring: only the last W positions
            # survive; they land on slots (pos % W) — a permutation scatter.
            pos = jnp.arange(s - W, s)
            slots = jnp.mod(pos, W)
            ck = cache["k"].at[:, slots].set(
                k[:, -W:].astype(cache["k"].dtype))
            cv = cache["v"].at[:, slots].set(
                v[:, -W:].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            o = _q_chunked_attention(q, k, v, q_offset=0, causal=causal,
                                     window=window, chunk=chunk, scale=scale,
                                     positions=positions)
        else:
            # prefill fits the cache: write slots [0, s)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv}
            o = _q_chunked_attention(q, k, v, q_offset=0, causal=causal,
                                     window=window, chunk=chunk, scale=scale,
                                     positions=positions)
    else:
        # cache-less execution: the backend picks its path — direct masked
        # softmax / query-chunked online softmax (XLA backends, with the
        # ragged-positions mask semantics of ``positions_mask``) or the
        # Pallas attention kernels (pallas_kernel)
        o = quant.datapath.attention(q, k, v, q=quant, positions=positions,
                                     causal=causal, window=window,
                                     scale=scale, chunk=chunk)

    o = o.reshape(b, s, cfg.n_heads * hd)
    out = L.linear(o, p["wo"], q=quant)
    return out, new_cache
