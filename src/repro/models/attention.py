"""Attention for every arch: GQA/MQA, RoPE, qk-norm, sliding window, caches.

Three execution paths, all numerically consistent:

  * direct   — masked softmax on the full score matrix; used for short
               sequences and for the MXInt softmax 'sim' datapath (the
               paper's ViT path computes whole rows, like the FPGA design).
  * chunked  — lax.scan online-softmax over KV chunks (flash-attention
               algebra in pure XLA); used whenever the score matrix would
               not fit (32k prefill, 4k training).  This is what the
               multi-pod dry-run lowers.
  * kernel   — QuantConfig(mode='kernel') routes through
               repro.kernels.ops.attention_op: the whole-row Pallas MXInt
               softmax ('paper' variant, bit-identical to the sim direct
               path) when quantize_nonlinear is set and the score matrix
               is small, the blocked mxint flash kernel (Eq. 14-20 without
               the O(S^2) scores, DESIGN.md §11) for long sequences, the
               float flash kernel otherwise.  Decode (s == 1 with a cache)
               goes through ops.attention_decode_op — scoring, softmax and
               p @ V fused in one Pallas kernel over the cache ring.

KV caches:
  full ring: (b, kv_heads, S_max, hd) with dynamic_update_slice writes.
  sliding window: ring buffer of size W; slot i at step t holds absolute
  position t - ((t - i) mod W) — no position side-array needed.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mx_types import QuantConfig
from repro.models import layers as L
from repro.models.model_api import ModelConfig, Param, dense_init, ones_init

_NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_attn_params(key, cfg: ModelConfig, dtype, cross: bool = False):
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads * hd),
                         ("embed", "q_heads"), dtype=dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd),
                         ("embed", "kv_heads"), dtype=dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd),
                         ("embed", "kv_heads"), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, cfg.d_model),
                         ("q_heads", "embed"), dtype=dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = ones_init((hd,), (None,), dtype=dtype)
        p["k_norm"] = ones_init((hd,), (None,), dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# score/softmax cores
# ---------------------------------------------------------------------------
def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _gqa_scores(q, k, scale):
    """q: (b, s, kv, g, hd); k: (b, S, kv, hd) -> (b, kv, g, s, S)."""
    return jnp.einsum("bskgd,bSkd->bkgsS", q, k) * scale


def _direct_attention(q, k, v, mask, quant: QuantConfig, scale):
    s = _gqa_scores(q, k, scale)
    s = jnp.where(mask, s.astype(jnp.float32), _NEG_INF)
    p = L.softmax(s, quant, axis=-1).astype(q.dtype)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bkgsS,bSkd->bskgd", p, v)


def _q_chunked_attention(q, k, v, *, q_offset, causal, window, chunk, scale):
    """Attention chunked over QUERY blocks (lax.scan, no carry).

    For long prefill the kv-chunked online-softmax form drags a
    (b, heads, s, hd) f32 accumulator through every scan iteration — at 32k
    that carry alone is GBs of HBM round-trips per chunk (§Perf iteration
    log, llama3 prefill).  Query blocks are independent: each block does one
    full-width softmax, there is no carry, and the score tensor crosses
    fusion boundaries in bf16 (the f32 accumulation lives inside the dot).
    On real TPU the Pallas flash kernel keeps scores in VMEM entirely; this
    is the XLA-path equivalent structure.
    """
    b, s, kv, g, hd = q.shape
    S = k.shape[1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nq = s // chunk
    # fold the softmax scale into q (one fused pass instead of a full-score
    # rescale) and pre-transpose K/V ONCE to the dot layouts — leaving them
    # (b, S, kv, hd) made XLA re-copy them inside every q-block iteration
    # (§Perf: llama3 prefill, copy_bitcast_fusion ~1TB).
    qs = (q * scale).astype(q.dtype)
    qc = jnp.swapaxes(qs.reshape(b, nq, chunk, kv, g, hd), 0, 1)
    kt = jnp.einsum("bSkd->bkdS", k)
    vt = jnp.einsum("bSkd->bkSd", v)
    k_pos = jnp.arange(S)
    neg = jnp.asarray(jnp.finfo(q.dtype).min, q.dtype)

    def block(_, inp):
        qi, qb = inp
        # f32 accumulation inside the dot; scores cross the fusion boundary
        # in the model dtype (halves every downstream score pass)
        s_blk = jnp.einsum("bckgd,bkdS->bkgcS", qb, kt,
                           preferred_element_type=jnp.float32
                           ).astype(q.dtype)
        q_pos = q_offset + qi * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, S), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s_blk = jnp.where(mask[None, None, None], s_blk, neg)
        m = jnp.max(s_blk, axis=-1, keepdims=True)
        # exp(neg - m) == 0 and every query row sees at least itself, so no
        # second masking pass is needed
        p = jnp.exp((s_blk - m).astype(jnp.float32))
        l = jnp.sum(p, axis=-1, keepdims=True)
        pb = (p / jnp.maximum(l, 1e-30)).astype(q.dtype)
        o = jnp.einsum("bkgcS,bkSd->bckgd", pb, vt,
                       preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    _, outs = jax.lax.scan(block, None, (jnp.arange(nq), qc))
    return jnp.swapaxes(outs, 0, 1).reshape(b, s, kv, g, hd)


def _chunked_attention(q, k, v, *, q_offset, causal, window, chunk, scale):
    """Online-softmax over KV chunks via lax.scan; O(s*chunk) live memory.

    RETAINED FOR COMPARISON ONLY: superseded by _q_chunked_attention after
    the §Perf llama3-prefill iteration showed the (m, l, acc) scan carry
    costs GBs of HBM round-trips per chunk (EXPERIMENTS.md §4, cell C).
    Still the right shape when queries are few and keys huge AND a carry is
    acceptable (e.g. speculative scoring); kept tested via kernels/ref.
    """
    b, s, kv, g, hd = q.shape
    S = k.shape[1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    kc = jnp.swapaxes(k.reshape(b, n_chunks, chunk, kv, hd), 0, 1)
    vc = jnp.swapaxes(v.reshape(b, n_chunks, chunk, kv, hd), 0, 1)

    q_pos = q_offset + jnp.arange(s)
    qf = q.astype(jnp.float32)

    def step(carry, inputs):
        m, l, acc = carry
        ci, kb, vb = inputs
        s_blk = _gqa_scores(qf, kb.astype(jnp.float32), scale)  # (b,kv,g,s,c)
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((s, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s_blk = jnp.where(mask[None, None, None], s_blk, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s_blk, -1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s_blk - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        l_new = l * alpha + jnp.sum(p, -1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgsc,bckd->bkgsd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, s), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, s), jnp.float32)
    a0 = jnp.zeros((b, kv, g, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.einsum("bkgsd->bskgd", out).astype(q.dtype)


# ---------------------------------------------------------------------------
# cache helpers
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window: int,
                  dtype) -> Dict[str, jnp.ndarray]:
    W = min(max_len, window) if window > 0 else max_len
    shape = (batch, W, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(cfg: ModelConfig, batch: int, max_len: int, window: int,
                   dtype):
    W = min(max_len, window) if window > 0 else max_len
    shape = (batch, W, cfg.n_kv_heads, cfg.hd)
    sds = jax.ShapeDtypeStruct(shape, dtype)
    return {"k": sds, "v": sds}


CACHE_AXES = ("batch", "kv_seq", "kv_heads", None)
CACHE_AXES_TREE = {"k": CACHE_AXES, "v": CACHE_AXES}


# ---------------------------------------------------------------------------
# main entry
# ---------------------------------------------------------------------------
def attention(p, x: jnp.ndarray, cfg: ModelConfig, *,
              quant: QuantConfig,
              positions: Optional[jnp.ndarray] = None,
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              cache_index: Optional[jnp.ndarray] = None,
              window: int = 0,
              causal: bool = True,
              kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              use_rope: bool = True,
              chunk: int = 1024):
    """Returns (output (b, s, d), updated cache or None).

    Modes:
      cache=None                      -> training / encoder (no state)
      cache given, s > 1              -> prefill (writes 0..s)
      cache given, s == 1             -> decode at cache_index
      kv_override                     -> cross attention (encoder K/V)
    """
    b, s, _ = x.shape
    hd = cfg.hd
    kvh = cfg.n_kv_heads
    g = cfg.n_heads // kvh
    scale = hd ** -0.5

    q = _split_heads(L.linear(x, p["wq"], q=quant), cfg.n_heads, hd)
    if kv_override is None:
        k = _split_heads(L.linear(x, p["wk"], q=quant), kvh, hd)
        v = _split_heads(L.linear(x, p["wv"], q=quant), kvh, hd)
    else:
        k, v = kv_override

    if cfg.qk_norm and "q_norm" in p:
        q = L.rmsnorm(q, p["q_norm"], q=quant, eps=cfg.norm_eps)
        if kv_override is None:
            k = L.rmsnorm(k, p["k_norm"], q=quant, eps=cfg.norm_eps)

    if positions is None:
        base = cache_index if cache_index is not None else 0
        positions = base + jnp.arange(s)[None, :]        # (1, s)
    if use_rope:
        q = L.rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = L.rope(k, positions, cfg.rope_theta)

    q = q.reshape(b, s, kvh, g, hd)
    new_cache = None

    if cache is not None and kv_override is None:
        W = cache["k"].shape[1]
        if s == 1:
            slot = (cache_index % W) if window > 0 else cache_index
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            new_cache = {"k": ck, "v": cv}
            # absolute position of every slot
            idx = jnp.arange(W)
            if window > 0:
                t = cache_index
                slot_pos = t - jnp.mod(t - idx, W)
            else:
                slot_pos = idx
            valid = (slot_pos >= 0) & (slot_pos <= cache_index)
            if window > 0:
                valid &= (cache_index - slot_pos) < window
            if quant.mode == "kernel":
                # Pallas decode: one fused kernel scores the ring, runs the
                # (optionally Eq. 14-20 quantized) online softmax and the
                # p @ V matmul — no XLA L.softmax on the decode path
                # (DESIGN.md §11).  GQA groups fold into the kernel's
                # sublane rows; ring validity streams in as `valid`; the
                # cache planes go in UNTRANSPOSED (the kernel grid walks
                # the native (b, W, kv, hd) layout — no per-step copy).
                from repro.kernels import ops as kops
                qd = q[:, 0]                             # (b, kv, g, hd)
                kd = ck.astype(q.dtype)
                vd = cv.astype(q.dtype)
                if quant.quantize_nonlinear and "softmax" in quant.nl_ops:
                    od = kops.attention_decode_op(
                        qd, kd, vd, valid, exp_mode="mxint",
                        r_bits=quant.nonlinear.softmax_r_bits,
                        quantize_scores=True,
                        act_block=quant.act_fmt.block_size,
                        mant_bits=quant.act_fmt.mant_bits)
                else:
                    od = kops.attention_decode_op(qd, kd, vd, valid)
                o = od[:, None]                          # (b,1,kv,g,hd)
            else:
                mask = valid[None, None, None, None, :]  # (1,1,1,1,W)
                sc = _gqa_scores(q, ck.astype(q.dtype), scale)
                sc = jnp.where(mask, sc.astype(jnp.float32), _NEG_INF)
                pr = L.softmax(sc, quant, axis=-1).astype(q.dtype)
                pr = jnp.where(mask, pr, 0.0)
                o = jnp.einsum("bkgsS,bSkd->bskgd", pr, cv.astype(q.dtype))
        elif window > 0 and s >= W:
            # SWA prefill longer than the ring: only the last W positions
            # survive; they land on slots (pos % W) — a permutation scatter.
            pos = jnp.arange(s - W, s)
            slots = jnp.mod(pos, W)
            ck = cache["k"].at[:, slots].set(
                k[:, -W:].astype(cache["k"].dtype))
            cv = cache["v"].at[:, slots].set(
                v[:, -W:].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            o = _q_chunked_attention(q, k, v, q_offset=0, causal=causal,
                                     window=window, chunk=chunk, scale=scale)
        else:
            # prefill fits the cache: write slots [0, s)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv}
            o = _q_chunked_attention(q, k, v, q_offset=0, causal=causal,
                                     window=window, chunk=chunk, scale=scale)
    elif quant.mode == "kernel":
        # Pallas route (kernel mode): heads-major layout into attention_op.
        # 'paper' variant = whole-row MXInt softmax in the Pallas kernel
        # (bit-identical to the 'sim' direct path); float flash otherwise.
        from repro.kernels import ops as kops
        S = k.shape[1]
        qh = jnp.einsum("bskgd->bkgsd", q).reshape(b, kvh * g, s, hd)
        kh = jnp.einsum("bSkd->bkSd", k)          # (b, kvh, S, hd), no copy
        vh = jnp.einsum("bSkd->bkSd", v)
        if quant.quantize_nonlinear and "softmax" in quant.nl_ops:
            if s * S <= 512 * 512:
                # whole-row 'paper' softmax: bit-identical to the sim
                # direct path (the ViT / encoder production path)
                o = kops.attention_op(
                    qh, kh, vh, causal=causal, window=window,
                    softmax_variant="paper",
                    act_block=quant.act_fmt.block_size,
                    mant_bits=quant.act_fmt.mant_bits,
                    r_bits=quant.nonlinear.softmax_r_bits)
            else:
                # long sequences: blocked mxint flash — the Eq. 14-20
                # datapath without the O(S^2) score matrix (DESIGN.md §11)
                o = kops.attention_op(
                    qh, kh, vh, causal=causal, window=window,
                    softmax_variant="online", exp_mode="mxint",
                    quantize_scores=True,
                    act_block=quant.act_fmt.block_size,
                    mant_bits=quant.act_fmt.mant_bits,
                    r_bits=quant.nonlinear.softmax_r_bits)
        else:
            o = kops.attention_op(qh, kh, vh, causal=causal, window=window,
                                  exp_mode="float")
        o = jnp.einsum("bkgsd->bskgd", o.reshape(b, kvh, g, s, hd))
    else:
        kv_len = k.shape[1]
        use_direct = (quant.enabled and quant.quantize_nonlinear and
                      quant.mode in ("sim", "packed")) or \
                     (s * kv_len <= 512 * 512)
        if use_direct:
            # per-ROW masks: positions may be (b, s) with ragged per-batch
            # offsets (left-padded prompts) — collapsing to the last batch
            # row's positions masked every other row wrongly (ISSUE 3).
            # Self-attention keys are the same tokens, so they carry the
            # same position VALUES: comparing q values against key INDICES
            # would let offset rows attend their own future (position
            # relabeling must be a no-op when rope is off).
            pos2 = positions if positions.ndim == 2 \
                else positions.reshape(1, -1)
            q_pos = pos2[:, -s:]                         # (1|b, s)
            if kv_len == s:
                k_pos = q_pos[:, None, :]                # self-attn: values
            else:
                k_pos = jnp.arange(kv_len)[None, None, :]  # cross: indices
            mask = jnp.ones((q_pos.shape[0], s, kv_len), dtype=bool)
            if causal:
                mask &= q_pos[:, :, None] >= k_pos
            if window > 0:
                mask &= (q_pos[:, :, None] - k_pos) < window
            o = _direct_attention(q, k, v, mask[:, None, None], quant,
                                  scale)
        else:
            o = _q_chunked_attention(q, k, v, q_offset=0, causal=causal,
                                     window=window, chunk=chunk, scale=scale)

    o = o.reshape(b, s, cfg.n_heads * hd)
    out = L.linear(o, p["wo"], q=quant)
    return out, new_cache
