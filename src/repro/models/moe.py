"""Mixture-of-Experts FFN with DP-local sort-based dispatch (EP-shardable).

Dispatch algorithm (Switch/Mixtral-style with token dropping), structured
for GSPMD locality: all routing / sorting / scatter / combine ops carry a
leading ``D`` axis = the number of data-parallel shards, and every op maps
elementwise over it (per-row sorts, batched scatters/gathers).  GSPMD keeps
axis-0-sharded batched ops shard-local, so:

  * token ranks and the (D, E, C_local, d) dispatch buffer never cross DP
    shards (GShard-style local capacity) — a global-rank scatter would
    force an all-reduce of the dense dispatch buffer across all DP shards;
  * the combine is an inverse-permutation *gather* per shard, not a
    scatter-add (scatter-add partials all-reduce the dense (T*k, d)
    tensor);
  * the only cross-device traffic left is the expert (EP/TP) resharding of
    the dispatch buffer against the 'model'-sharded expert weights.

See EXPERIMENTS.md §Perf (mixtral_8x7b x train_4k iterations) for the
measured effect of each of these choices.

Capacity C = ceil(T_local * k / E * capacity_factor) per DP shard;
overflow tokens are dropped (standard capacity-based MoE).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.mx_types import QuantConfig
from repro.models import layers as L
from repro.models.model_api import ModelConfig, Param, dense_init


def init_moe_params(key, cfg: ModelConfig, dtype) -> Dict[str, Param]:
    moe = cfg.moe
    ks = jax.random.split(key, 4)
    E, d, f = moe.num_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(ks[0], (d, E), ("embed", "expert"), dtype=dtype),
        "wi": dense_init(ks[1], (E, d, f), ("expert", "embed", "mlp"),
                         dtype=dtype),
        "wg": dense_init(ks[2], (E, d, f), ("expert", "embed", "mlp"),
                         dtype=dtype),
        "wo": dense_init(ks[3], (E, f, d), ("expert", "mlp", "embed"),
                         dtype=dtype),
    }


def _dp_shards(batch: int) -> int:
    """Number of DP shards from the ambient mesh (1 when off-mesh)."""
    try:
        from repro.parallel.sharding import ambient_mesh
        mesh = ambient_mesh()
        if mesh is None:
            return 1
        shape = dict(mesh.shape)
        D = shape.get("pod", 1) * shape.get("data", 1)
        return D if D > 1 and batch % D == 0 else 1
    except Exception:
        return 1


def moe_ffn(x: jnp.ndarray, p: Dict[str, Param], cfg: ModelConfig, *,
            quant: QuantConfig):
    """x: (b, s, d) -> (y, aux_loss)."""
    moe = cfg.moe
    E, k = moe.num_experts, moe.top_k
    b, s, d = x.shape
    T = b * s
    D = _dp_shards(b)
    xs = x.reshape(D, T // D, d)
    xs = L.shard_hint(xs, ("batch", None, None))

    Tl = T // D
    C = max(1, math.ceil(Tl * k / E * moe.capacity_factor))
    C = -(-C // 8) * 8                       # lane-friendly capacity

    # ---- routing (per shard row) -----------------------------------------
    logits = L.linear(xs, p["router"], q=quant).astype(jnp.float32)
    top_logits, top_idx = jax.lax.top_k(logits, k)        # (D, Tl, k)
    gates = L.softmax(top_logits, quant, axis=-1).astype(x.dtype)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e — a float
    # TRAINING statistic, deliberately outside the quantized datapath
    # (the routed gates above go through L.softmax)
    # repro-lint: allow[models-float-nonlinear] float-by-design aux loss
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux_loss = moe.router_aux_loss * E * jnp.sum(me * ce)

    # ---- per-shard sort-based dispatch -------------------------------------
    flat_e = top_idx.reshape(D, Tl * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)     # (D, Tl*k)
    inv = jnp.argsort(order, axis=-1)                     # inverse perm
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=-1) - counts         # (D, E)
    rank = jnp.arange(Tl * k)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)    # E*C = drop bin
    flat_tok = jnp.repeat(jnp.arange(Tl), k)[None, :]
    src_tok = jnp.take_along_axis(
        jnp.broadcast_to(flat_tok, (D, Tl * k)), order, axis=-1)

    def scatter_row(dst, xrow, st):
        return jnp.zeros((E * C + 1, d), x.dtype).at[dst].set(
            xrow[st], mode="drop")

    buf = jax.vmap(scatter_row)(dest, xs, src_tok)        # (D, E*C+1, d)
    buf = buf[:, :E * C].reshape(D, E, C, d)
    buf = L.shard_hint(buf, ("batch", "expert", None, None))

    # ---- expert computation (E/f sharded over 'model': EP/TP) -------------
    def expert_mm(h, w: Param, pattern: str):
        wv = quant.datapath.weight_value(w.value, q=quant, dtype=h.dtype)
        return jnp.einsum(pattern, h, wv)

    up = expert_mm(buf, p["wi"], "Xecd,edf->Xecf")
    gate = L.act_fn(expert_mm(buf, p["wg"], "Xecd,edf->Xecf"), "silu", quant)
    out = expert_mm(up * gate, p["wo"], "Xecf,efd->Xecd")
    out = out.reshape(D, E * C, d)

    # ---- combine: batched gather back to token order -----------------------
    gathered = jnp.take_along_axis(
        out, jnp.clip(dest, 0, E * C - 1)[..., None], axis=1)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    flat_gates = jnp.take_along_axis(gates.reshape(D, Tl * k), order,
                                     axis=-1)
    weighted = gathered * flat_gates[..., None].astype(gathered.dtype)
    tok_major = jnp.take_along_axis(weighted, inv[..., None], axis=1)
    y = tok_major.reshape(D, Tl, k, d).sum(axis=2).astype(x.dtype)
    y = L.shard_hint(y, ("batch", None, None))
    return y.reshape(b, s, d), aux_loss
