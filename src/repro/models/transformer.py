"""Transformer stacks: decoder-only LM (all LM archs) and encoder-decoder.

Layer stacks are expressed as a repeating *unit* of block kinds
(cfg.unit x cfg.n_units + cfg.tail) and executed with jax.lax.scan over
stacked per-unit parameters — HLO size stays O(unit) regardless of depth
(deepseek-67b's 95 layers compile as one scanned unit + tail).

Block kinds: 'attn' (GQA attention), 'rec' (RG-LRU), 'mlstm', 'slstm'.
Every block is pre-norm residual; 'attn'/'rec' blocks carry an FFN
sub-block (cfg.ffn_kind: swiglu/geglu/gelu/moe), xLSTM kinds are
self-contained.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mx_types import QuantConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models.model_api import (ModelConfig, Param, dense_init,
                                    ones_init, axes_tree, is_param)


# ===========================================================================
# block init / apply
# ===========================================================================
def _init_ffn_params(key, cfg: ModelConfig, dtype):
    kind = cfg.ffn_kind
    ks = jax.random.split(key, 3)
    if kind == "moe":
        return M.init_moe_params(key, cfg, dtype)
    if kind in ("swiglu", "geglu"):
        return {
            "wi": dense_init(ks[0], (cfg.d_model, cfg.d_ff),
                             ("embed", "mlp"), dtype=dtype),
            "wg": dense_init(ks[1], (cfg.d_model, cfg.d_ff),
                             ("embed", "mlp"), dtype=dtype),
            "wo": dense_init(ks[2], (cfg.d_ff, cfg.d_model),
                             ("mlp", "embed"), dtype=dtype),
        }
    if kind == "gelu":
        return {
            "wi": dense_init(ks[0], (cfg.d_model, cfg.d_ff),
                             ("embed", "mlp"), dtype=dtype),
            "wo": dense_init(ks[1], (cfg.d_ff, cfg.d_model),
                             ("mlp", "embed"), dtype=dtype),
        }
    if kind == "none":
        return {}
    raise ValueError(kind)


def init_block_params(key, kind: str, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"ln1": ones_init((cfg.d_model,), ("embed",),
                                          dtype=dtype)}
    if kind == "attn":
        p["mix"] = A.init_attn_params(k1, cfg, dtype)
    elif kind == "rec":
        p["mix"] = R.init_rglru_params(k1, cfg, dtype)
    elif kind == "mlstm":
        p["mix"] = R.init_mlstm_params(k1, cfg, dtype)
        return p                       # self-contained, no ffn
    elif kind == "slstm":
        p["mix"] = R.init_slstm_params(k1, cfg, dtype)
        return p
    else:
        raise ValueError(kind)
    if cfg.ffn_kind != "none":
        p["ln2"] = ones_init((cfg.d_model,), ("embed",), dtype=dtype)
        p["ffn"] = _init_ffn_params(k2, cfg, dtype)
    return p


def apply_block(p, kind: str, x: jnp.ndarray, cfg: ModelConfig, *,
                quant: QuantConfig, positions=None, cache=None,
                cache_index=None, decode: bool = False):
    """Pre-norm residual block.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        # the pre-attention norm rides into the q/k/v projections via the
        # layernorm_linear composite seam (fused when the backend provides
        # it, norm-then-linear otherwise — DESIGN.md §12)
        window = cfg.local_attn_window or cfg.window
        o, new_cache = A.attention(
            p["mix"], x, cfg, quant=quant, positions=positions,
            cache=cache, cache_index=cache_index, window=window,
            prenorm=("rms", p["ln1"], None))
    elif kind == "rec":
        h = L.rmsnorm(x, p["ln1"], q=quant, eps=cfg.norm_eps)
        o, new_cache = R.rglru_block(p["mix"], h, cfg, quant=quant,
                                     state=cache, decode=decode)
    elif kind == "mlstm":
        h = L.rmsnorm(x, p["ln1"], q=quant, eps=cfg.norm_eps)
        o, new_cache = R.mlstm_block(p["mix"], h, cfg, quant=quant,
                                     state=cache, decode=decode)
        return x + o, new_cache, aux
    elif kind == "slstm":
        h = L.rmsnorm(x, p["ln1"], q=quant, eps=cfg.norm_eps)
        if decode:
            o, new_cache = R.slstm_step(p["mix"], h, cfg, quant, cache)
        else:
            o, new_cache = R.slstm_scan(p["mix"], h, cfg, quant, cache)
        return x + o, new_cache, aux
    else:
        raise ValueError(kind)
    x = x + o
    if cfg.ffn_kind != "none" and "ffn" in p:
        if cfg.ffn_kind == "moe":
            h2 = L.rmsnorm(x, p["ln2"], q=quant, eps=cfg.norm_eps)
            f, aux = M.moe_ffn(h2, p["ffn"], cfg, quant=quant)
        else:
            f = L.ffn(x, p["ffn"], cfg.ffn_kind, quant,
                      prenorm=("rms", p["ln2"], None), eps=cfg.norm_eps)
        x = x + f
    return x, new_cache, aux


# ===========================================================================
# cache constructors per kind
# ===========================================================================
def block_cache_init(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype):
    if kind == "attn":
        window = cfg.local_attn_window or cfg.window
        return A.init_kv_cache(cfg, batch, max_len, window, dtype)
    if kind == "rec":
        return R.rglru_state_init(cfg, batch, dtype)
    if kind == "mlstm":
        return R.mlstm_state_init(cfg, batch)
    if kind == "slstm":
        return R.slstm_state_init(cfg, batch)
    raise ValueError(kind)


def block_cache_specs(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                      dtype):
    if kind == "attn":
        window = cfg.local_attn_window or cfg.window
        return A.kv_cache_specs(cfg, batch, max_len, window, dtype)
    if kind == "rec":
        return R.rglru_state_specs(cfg, batch, dtype)
    if kind == "mlstm":
        return R.mlstm_state_specs(cfg, batch)
    if kind == "slstm":
        return R.slstm_state_specs(cfg, batch)
    raise ValueError(kind)


def block_cache_axes(kind: str):
    if kind == "attn":
        return A.CACHE_AXES_TREE
    if kind == "rec":
        return R.RGLRU_STATE_AXES
    if kind == "mlstm":
        return R.MLSTM_STATE_AXES
    if kind == "slstm":
        return R.SLSTM_STATE_AXES
    raise ValueError(kind)


def _stack_tree(tree, n: int):
    """Add a leading n_units axis to ShapeDtypeStructs."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


def _stack_axes(tree):
    return jax.tree_util.tree_map(
        lambda axes: ("layers",) + tuple(axes), tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


# ===========================================================================
# stacked-unit init
# ===========================================================================
def _stacked_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    stacked = jax.vmap(init_fn)(keys)
    return jax.tree_util.tree_map(
        lambda pr: Param(pr.value, ("layers",) + tuple(pr.axes)),
        stacked, is_leaf=is_param)


# ===========================================================================
# DecoderLM
# ===========================================================================
class DecoderLM:
    """Every decoder-only LM arch (dense / moe / hybrid / ssm / vlm)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()

    # -- params -----------------------------------------------------------
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = cfg.dtype
        keys = jax.random.split(rng, 4 + len(cfg.unit) + len(cfg.tail))
        params: Dict[str, Any] = {
            "embed": dense_init(keys[0], (cfg.vocab, cfg.d_model),
                                ("vocab", "embed"), scale=0.02, dtype=dtype),
            "final_norm": ones_init((cfg.d_model,), ("embed",), dtype=dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(
                keys[1], (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                scale=0.02, dtype=dtype)
        if cfg.vision_tokens:
            params["vision_proj"] = dense_init(
                keys[2], (cfg.vision_dim, cfg.d_model), (None, "embed"),
                dtype=dtype)
        units = {}
        for j, kind in enumerate(cfg.unit):
            units[f"u{j}_{kind}"] = _stacked_init(
                lambda k, kind=kind: init_block_params(k, kind, cfg, dtype),
                keys[3 + j], cfg.resolved_n_units)
        params["units"] = units
        tail = {}
        for j, kind in enumerate(cfg.tail):
            tail[f"t{j}_{kind}"] = init_block_params(
                keys[3 + len(cfg.unit) + j], kind, cfg, dtype)
        params["tail"] = tail
        return params

    def abstract_params(self):
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    # -- cache --------------------------------------------------------------
    def cache_init(self, batch: int, max_len: int, abstract: bool = False):
        """The KV cache carries a PER-ROW ``index`` vector (batch,): row i's
        next write position / number of live tokens.  Rows advance
        independently, which is what lets ``BatchScheduler`` prefill a new
        request into one slot while the others keep decoding (slot-level
        continuous batching — DESIGN.md §7)."""
        cfg = self.cfg
        fn = block_cache_specs if abstract else block_cache_init
        n = cfg.resolved_n_units
        cache = {"units": {}, "tail": {}, "index": (
            jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
            else jnp.zeros((batch,), jnp.int32))}
        for j, kind in enumerate(cfg.unit):
            c = fn(kind, cfg, batch, max_len, cfg.dtype)
            cache["units"][f"u{j}_{kind}"] = (
                _stack_tree(c, n) if abstract else
                jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (n,) + a.shape), c))
        for j, kind in enumerate(cfg.tail):
            cache["tail"][f"t{j}_{kind}"] = fn(kind, cfg, batch, max_len,
                                               cfg.dtype)
        return cache

    def cache_axes(self):
        cfg = self.cfg
        axes = {"units": {}, "tail": {}, "index": ("batch",)}
        for j, kind in enumerate(cfg.unit):
            axes["units"][f"u{j}_{kind}"] = jax.tree_util.tree_map(
                lambda a: ("layers",) + tuple(a), block_cache_axes(kind),
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    y is None or isinstance(y, str) for y in x))
        for j, kind in enumerate(cfg.tail):
            axes["tail"][f"t{j}_{kind}"] = block_cache_axes(kind)
        return axes

    # -- forward ------------------------------------------------------------
    def _embed_inputs(self, params, tokens, vision_embeds):
        cfg = self.cfg
        x = L.embed_lookup(tokens, params["embed"], cfg.quant, cfg.dtype)
        if cfg.vision_tokens and vision_embeds is not None:
            v = L.linear(vision_embeds.astype(cfg.dtype),
                         params["vision_proj"], q=cfg.quant)
            x = jax.lax.dynamic_update_slice(x, v, (0, 0, 0))
        return x

    def _run_stack(self, params, x, *, positions, cache, cache_index,
                   decode):
        cfg = self.cfg
        quant = cfg.quant
        aux_total = jnp.zeros((), jnp.float32)

        unit_params = params["units"]
        unit_cache = cache["units"] if cache is not None else None

        def unit_step(carry, xs):
            x, aux = carry
            up, uc = xs
            new_uc = {}
            for j, kind in enumerate(cfg.unit):
                key = f"u{j}_{kind}"
                c_in = uc[key] if uc is not None else None
                x, c_out, a = apply_block(
                    up[key], kind, x, cfg, quant=quant, positions=positions,
                    cache=c_in, cache_index=cache_index, decode=decode)
                aux = aux + a
                if c_out is not None:
                    new_uc[key] = c_out
            return (x, aux), new_uc

        # remat is a gradient-memory tool: apply it only on the training
        # path.  Checkpointing inference (prefill/decode) forces the scan
        # carry through save/restore round-trips for no benefit.
        if cfg.remat in ("block", "full") and cache is None:
            unit_step = jax.checkpoint(unit_step)

        (x, aux_total), new_unit_cache = jax.lax.scan(
            unit_step, (x, aux_total),
            (unit_params, unit_cache) if unit_cache is not None
            else (unit_params, None))

        new_tail_cache = {}
        for j, kind in enumerate(cfg.tail):
            key = f"t{j}_{kind}"
            c_in = cache["tail"][key] if cache is not None else None
            x, c_out, a = apply_block(
                params["tail"][key], kind, x, cfg, quant=quant,
                positions=positions, cache=c_in, cache_index=cache_index,
                decode=decode)
            aux_total = aux_total + a
            if c_out is not None:
                new_tail_cache[key] = c_out

        x = L.rmsnorm(x, params["final_norm"], q=quant, eps=cfg.norm_eps)
        new_cache = None
        if cache is not None:
            new_cache = {"units": new_unit_cache, "tail": new_tail_cache,
                         "index": (cache_index + x.shape[1])}
        return x, new_cache, aux_total

    def logits(self, params, x):
        cfg = self.cfg
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        return L.unembed(x, table, cfg.quant)

    # -- public entry points --------------------------------------------------
    def loss(self, params, batch) -> jnp.ndarray:
        """batch: {'tokens': (b, s) int32, 'loss_mask': (b, s) f32 optional,
        'vision_embeds': optional}."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_inputs(params, tokens, batch.get("vision_embeds"))
        positions = jnp.arange(tokens.shape[1])[None, :]
        x, _, aux = self._run_stack(params, x, positions=positions,
                                    cache=None, cache_index=None,
                                    decode=False)
        logits = self.logits(params, x[:, :-1])
        targets = tokens[:, 1:]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        mask = mask[:, 1:] if mask is not None else jnp.ones_like(nll)
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + aux

    def prefill(self, params, tokens, cache, vision_embeds=None,
                lengths=None):
        """Writes the prompt into the cache; returns (last_logits, cache).

        ``lengths``: optional (b,) int32 per-row prompt lengths for
        RIGHT-padded ragged prompts.  Causal masking makes the pad keys
        (positions >= length) invisible to every real query, row i's
        logits are gathered at its own last real position (lengths[i]-1)
        and ``cache['index']`` is set to lengths — so the pad slots hold
        garbage K/V that the per-row decode validity then masks out.
        ``lengths=None`` keeps the dense contract: every row is exactly
        ``tokens.shape[1]`` long.
        """
        b, s = tokens.shape[0], tokens.shape[1]
        x = self._embed_inputs(params, tokens, vision_embeds)
        positions = jnp.arange(s)[None, :]
        x, cache, _ = self._run_stack(
            params, x, positions=positions, cache=cache,
            cache_index=jnp.zeros((b,), jnp.int32), decode=False)
        if lengths is None:
            return self.logits(params, x[:, -1:]), cache
        lengths = jnp.asarray(lengths, jnp.int32)
        cache = dict(cache, index=lengths)
        last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
        return self.logits(params, last), cache

    def decode_step(self, params, token, cache):
        """token: (b, 1).  One autoregressive step; row i reads/writes its
        cache at its own ``cache['index'][i]``."""
        x = self._embed_inputs(params, token, None)
        idx = cache["index"]
        x, cache, _ = self._run_stack(
            params, x, positions=None, cache=cache, cache_index=idx,
            decode=True)
        return self.logits(params, x), cache


# ===========================================================================
# Encoder-decoder (seamless-m4t style)
# ===========================================================================
class EncDecLM:
    """Encoder-decoder with a stubbed modality frontend: the encoder input
    is precomputed frame embeddings (b, s_enc, d_model)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _enc_cfg(self):
        import dataclasses as dc
        return dc.replace(self.cfg, unit=("attn",),
                          n_units=self.cfg.n_encoder_layers, tail=(),
                          ffn_kind="gelu")

    def init(self, rng):
        cfg = self.cfg
        dtype = cfg.dtype
        keys = jax.random.split(rng, 8)
        enc_cfg = self._enc_cfg()
        params = {
            "embed": dense_init(keys[0], (cfg.vocab, cfg.d_model),
                                ("vocab", "embed"), scale=0.02, dtype=dtype),
            "enc_blocks": _stacked_init(
                lambda k: init_block_params(k, "attn", enc_cfg, dtype),
                keys[1], cfg.n_encoder_layers),
            "enc_norm": ones_init((cfg.d_model,), ("embed",), dtype=dtype),
            "dec_blocks": _stacked_init(
                lambda k: self._init_dec_block(k, dtype),
                keys[2], cfg.n_layers),
            "final_norm": ones_init((cfg.d_model,), ("embed",), dtype=dtype),
            "unembed": dense_init(keys[3], (cfg.vocab, cfg.d_model),
                                  ("vocab", "embed"), scale=0.02,
                                  dtype=dtype),
        }
        return params

    def _init_dec_block(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        return {
            "ln1": ones_init((cfg.d_model,), ("embed",), dtype=dtype),
            "self_attn": A.init_attn_params(ks[0], cfg, dtype),
            "ln_x": ones_init((cfg.d_model,), ("embed",), dtype=dtype),
            "cross_attn": A.init_attn_params(ks[1], cfg, dtype, cross=True),
            "ln2": ones_init((cfg.d_model,), ("embed",), dtype=dtype),
            "ffn": _init_ffn_params(ks[2], self._enc_cfg(), dtype),
        }

    def encode(self, params, frames):
        cfg = self.cfg
        quant = cfg.quant
        x = frames.astype(cfg.dtype)
        positions = jnp.arange(x.shape[1])[None, :]

        def step(x, bp):
            h = L.rmsnorm(x, bp["ln1"], q=quant, eps=cfg.norm_eps)
            o, _ = A.attention(bp["mix"], h, cfg, quant=quant,
                               positions=positions, causal=False)
            x = x + o
            h2 = L.rmsnorm(x, bp["ln2"], q=quant, eps=cfg.norm_eps)
            return x + L.ffn(h2, bp["ffn"], "gelu", quant), None

        x, _ = jax.lax.scan(step, x, params["enc_blocks"])
        return L.rmsnorm(x, params["enc_norm"], q=quant, eps=cfg.norm_eps)

    def _dec_stack(self, params, x, enc_kv, *, cache, cache_index, decode):
        cfg = self.cfg
        quant = cfg.quant
        positions = None if decode else jnp.arange(x.shape[1])[None, :]

        def step(x, xs):
            bp, ekv, c = xs
            h = L.rmsnorm(x, bp["ln1"], q=quant, eps=cfg.norm_eps)
            o, new_c = A.attention(bp["self_attn"], h, cfg, quant=quant,
                                   positions=positions, cache=c,
                                   cache_index=cache_index)
            x = x + o
            hx = L.rmsnorm(x, bp["ln_x"], q=quant, eps=cfg.norm_eps)
            ox, _ = A.attention(bp["cross_attn"], hx, cfg, quant=quant,
                                kv_override=ekv, causal=False,
                                use_rope=False)
            x = x + ox
            h2 = L.rmsnorm(x, bp["ln2"], q=quant, eps=cfg.norm_eps)
            x = x + L.ffn(h2, bp["ffn"], "gelu", quant)
            return x, new_c

        x, new_cache = jax.lax.scan(
            step, x, (params["dec_blocks"], enc_kv, cache))
        x = L.rmsnorm(x, params["final_norm"], q=quant, eps=cfg.norm_eps)
        return x, new_cache

    def encode_kv(self, params, memory):
        """Precompute per-layer cross K/V from encoder output."""
        cfg = self.cfg
        kvh, hd = cfg.n_kv_heads, cfg.hd

        def one(bp):
            k = L.linear(memory, bp["cross_attn"]["wk"], q=cfg.quant)
            v = L.linear(memory, bp["cross_attn"]["wv"], q=cfg.quant)
            return (k.reshape(*memory.shape[:2], kvh, hd),
                    v.reshape(*memory.shape[:2], kvh, hd))

        return jax.vmap(one, in_axes=0, out_axes=0)(params["dec_blocks"])

    def loss(self, params, batch):
        cfg = self.cfg
        frames = batch["frames"]
        tokens = batch["tokens"]
        memory = self.encode(params, frames)
        enc_kv = self.encode_kv(params, memory)
        x = L.embed_lookup(tokens, params["embed"], cfg.quant, cfg.dtype)
        x, _ = self._dec_stack(params, x, enc_kv, cache=None,
                               cache_index=None, decode=False)
        logits = L.unembed(x[:, :-1], params["unembed"], cfg.quant)
        targets = tokens[:, 1:]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, targets[..., None], -1)[..., 0]
        return jnp.mean(nll)

    def cache_init(self, batch, max_len, abstract=False):
        cfg = self.cfg
        fn = block_cache_specs if abstract else block_cache_init
        c = fn("attn", cfg, batch, max_len, cfg.dtype)
        n = cfg.n_layers
        if abstract:
            self_c = _stack_tree(c, n)
        else:
            self_c = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n,) + a.shape), c)
        return {"self": self_c,
                "index": jax.ShapeDtypeStruct((), jnp.int32) if abstract
                else jnp.zeros((), jnp.int32)}

    def prefill(self, params, frames, tokens, cache):
        cfg = self.cfg
        memory = self.encode(params, frames)
        enc_kv = self.encode_kv(params, memory)
        x = L.embed_lookup(tokens, params["embed"], cfg.quant, cfg.dtype)
        x, new_self = self._dec_stack(params, x, enc_kv, cache=cache["self"],
                                      cache_index=jnp.zeros((), jnp.int32),
                                      decode=False)
        logits = L.unembed(x[:, -1:], params["unembed"], cfg.quant)
        return logits, {"self": new_self, "enc_kv": enc_kv,
                        "index": cache["index"] + tokens.shape[1]}

    def decode_step(self, params, token, cache):
        cfg = self.cfg
        x = L.embed_lookup(token, params["embed"], cfg.quant, cfg.dtype)
        x, new_self = self._dec_stack(
            params, x, cache["enc_kv"], cache=cache["self"],
            cache_index=cache["index"], decode=True)
        logits = L.unembed(x, params["unembed"], cfg.quant)
        return logits, {"self": new_self, "enc_kv": cache["enc_kv"],
                        "index": cache["index"] + 1}
