"""Model API: configs, parameter wrappers with logical sharding axes,
and the common protocol every architecture implements.

Parameters are created as ``Param(value, axes)`` where ``axes`` is a tuple of
*logical* axis names (e.g. ("embed", "q_heads")).  The parallel layer
(repro.parallel.sharding) maps logical names onto mesh axes.  Keeping the
axes on the leaf makes init the single source of truth — no drift between a
separate spec tree and the real params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.mx_types import QuantConfig


class Param(NamedTuple):
    """A parameter leaf plus its logical sharding axes (aux data)."""
    value: Any               # jnp.ndarray | ShapeDtypeStruct | MXTensor
    axes: Tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), (p.axes,)),
    lambda aux, leaves: Param(leaves[0], aux[0]),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def unwrap(tree):
    """Strip Param wrappers -> raw value pytree."""
    return jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)


def axes_tree(tree):
    """Param tree -> logical-axes pytree (same structure as unwrap)."""
    return jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_param)


def wrap_like(values, params_with_axes):
    """Re-attach axes from a Param tree onto a matching value tree."""
    return jax.tree_util.tree_map(
        lambda v, p: Param(v, p.axes), values, params_with_axes,
        is_leaf=lambda x: False)


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config drives every architecture family.

    unit / n_units / tail describe the layer stack as a repeating pattern so
    heterogeneous models (recurrentgemma's R-R-A, xlstm's 7xM+S) scan over
    *units* with stacked params — HLO stays O(1) in depth.
    Block kinds: 'attn', 'rec' (RG-LRU), 'mlstm', 'slstm'.
    """

    name: str = "model"
    family: str = "dense"          # dense|moe|hybrid|ssm|vlm|audio|vit
    n_layers: int = 4
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    d_ff: int = 2048
    vocab: int = 32000
    head_dim: Optional[int] = None
    # layer pattern
    unit: Tuple[str, ...] = ("attn",)
    n_units: Optional[int] = None          # default n_layers / len(unit)
    tail: Tuple[str, ...] = ()
    # mixer details
    rope_theta: float = 10000.0
    qk_norm: bool = False
    window: int = 0                        # sliding-window size; 0 = full
    local_attn_window: int = 0             # for hybrid local-attn blocks
    # ffn
    ffn_kind: str = "swiglu"               # swiglu|geglu|gelu|moe|none
    moe: Optional[MoEConfig] = None
    # recurrent details
    lru_width: Optional[int] = None        # RG-LRU width (default d_model)
    conv_width: int = 4
    # enc-dec
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # vlm / audio stubs
    vision_tokens: int = 0                 # prefix positions fed by projector
    vision_dim: int = 1024
    audio_frames: bool = False             # encoder input is frame embeddings
    # vit
    image_size: int = 224
    patch_size: int = 16
    n_classes: int = 1000
    pool: str = "cls"
    # numerics / runtime
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    remat: str = "none"                    # none|block|full
    max_cache_len: int = 4096

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_n_units(self) -> int:
        if self.n_units is not None:
            return self.n_units
        assert (self.n_layers - len(self.tail)) % len(self.unit) == 0, \
            (self.n_layers, self.unit, self.tail)
        return (self.n_layers - len(self.tail)) // len(self.unit)

    def validate(self):
        assert self.resolved_n_units * len(self.unit) + len(self.tail) == \
            self.n_layers, "unit pattern must tile n_layers"
        assert self.n_heads % self.n_kv_heads == 0
        if self.ffn_kind == "moe":
            assert self.moe is not None
        return self


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train|prefill|decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, axes, scale=None, dtype=jnp.float32) -> Param:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    v = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    return Param(v.astype(dtype), axes)


def zeros_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype=dtype), axes)


def ones_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype=dtype), axes)
