"""Deliberately violating fixtures — the analysis passes' self-tests.

Each fixture is a callable returning the violations its pass reports for
a KNOWN-BAD input; ``tests/test_analysis.py`` asserts every fixture
fires (non-empty, right rule name) and ``tools/repro_lint.py --fixture
NAME`` exits non-zero on each, which is the acceptance contract: a rule
that cannot flag its own counterexample is dead code, not a guarantee.

The kernel fixtures go through the REAL capture machinery (a fabricated
``pallas_call`` under abstract eval), not hand-built capture records, so
they also pin the recorder itself.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import (capture_pallas_calls,
                                             check_captures)
from repro.analysis.registry import ERROR, Violation
from repro.analysis.source_rules import check_source
from repro.analysis.trace_lint import (KERNEL_NL_DENY, TraceRules, lint_fn)


def _noop_kernel(*refs):
    pass


def _capture_2d(shape, block, *, out_block=None, grid=None,
                index_map=None, out_index_map=None, dtype=jnp.float32,
                kernel=_noop_kernel, scratch=(), compiler_params=None):
    """Fabricate one 2-D pallas_call capture with the given specs."""
    from jax.experimental import pallas as pl

    grid = grid or tuple(d // b for d, b in zip(shape, block))
    index_map = index_map or (lambda i, j: (i, j))
    out_index_map = out_index_map or index_map
    out_block = out_block or block

    def fn(x):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[pl.BlockSpec(block, index_map)],
            out_specs=pl.BlockSpec(out_block, out_index_map),
            out_shape=jax.ShapeDtypeStruct(shape, dtype),
            scratch_shapes=list(scratch),
            compiler_params=compiler_params,
            interpret=True)(x)

    return capture_pallas_calls(fn, jax.ShapeDtypeStruct(shape, dtype),
                                label="fixture")


def vmem_over_budget() -> List[Violation]:
    """A (2048, 2048) f32 block is 16 MiB; double-buffered in+out blows
    the whole per-core budget several times over."""
    return check_captures(_capture_2d((4096, 2048), (2048, 2048)))


def misaligned_tile() -> List[Violation]:
    """Minormost tiled block of 100 lanes (not a 128 multiple)."""
    return check_captures(_capture_2d((64, 400), (8, 100)))


def uncovered_output_block() -> List[Violation]:
    """A constant output index map over a tiled output: 3 of 4 row-blocks
    of the result are never written."""
    return check_captures(_capture_2d(
        (512, 128), (128, 128), grid=(4,),
        index_map=lambda i: (i, 0), out_index_map=lambda i: (0, 0)))


def wrong_scratch_dtype() -> List[Violation]:
    """A kernel posing as mxint_ln_matmul whose LN scratch is f32 while
    the model dtype is bf16 — the model-dtype scratch contract."""
    from jax.experimental.pallas import tpu as pltpu

    def _mxint_ln_matmul_kernel(*refs):
        pass

    return check_captures(_capture_2d(
        (128, 256), (128, 256), dtype=jnp.bfloat16,
        kernel=_mxint_ln_matmul_kernel,
        scratch=(pltpu.VMEM((128, 256), jnp.float32),)))


def float_softmax_in_kernel_trace() -> List[Violation]:
    """jax.nn.softmax traced under kernel-mode rules: denied rank-2 exp,
    a structural softmax chain, and a blown (>=1) pallas budget."""
    rules = TraceRules(deny_outside_pallas=KERNEL_NL_DENY,
                       forbid_softmax_chain=True, pallas_budget=(1, 1))
    return lint_fn(lambda x: jax.nn.softmax(x, axis=-1),
                   (jnp.zeros((8, 16), jnp.float32),), rules,
                   "fixture:float-softmax")


def f64_leak() -> List[Violation]:
    """An f64 upcast mid-trace (x64 enabled only inside the fixture —
    the default f32 canonicalisation would silently hide the leak)."""
    from jax.experimental import enable_x64

    with enable_x64():
        return lint_fn(
            lambda x: (x.astype(jnp.float64) * 2.0).astype(jnp.float32),
            (jnp.zeros((4, 4), jnp.float32),), TraceRules(),
            "fixture:f64-leak")


# ---------------------------------------------------------------------------
# grid-semantics fixtures (DESIGN.md §14) — file-defined accumulator
# kernels so the AST gate scan sees real source
# ---------------------------------------------------------------------------
def _acc_kernel(x_ref, o_ref, acc_ref):
    from jax.experimental import pallas as pl

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[...]

    @pl.when(pl.program_id(1) == 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _reversed_acc_kernel(x_ref, o_ref, acc_ref):
    from jax.experimental import pallas as pl

    @pl.when(pl.program_id(1) == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[...]

    @pl.when(pl.program_id(1) == 0)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _inplace_kernel(x_ref, o_ref):
    o_ref[...] = o_ref[...] + x_ref[...]


def _acc_capture(kernel, compiler_params):
    from jax.experimental.pallas import tpu as pltpu

    # grid (4, 2); the output map ignores axis 1, so each output block is
    # written on both of its steps — a revisiting axis by construction
    return _capture_2d(
        (512, 256), (128, 256), grid=(4, 2),
        index_map=lambda i, j: (i, 0),
        kernel=kernel, scratch=(pltpu.VMEM((128, 256), jnp.float32),),
        compiler_params=compiler_params)


def missing_dim_semantics() -> List[Violation]:
    """An accumulator grid with no dimension_semantics declaration."""
    from repro.analysis.grid_semantics import check_captures_semantics

    return check_captures_semantics(_acc_capture(_acc_kernel, None))


def race_parallel_accumulator() -> List[Violation]:
    """The revisiting/gated accumulator axis declared "parallel" — the
    data race the checker exists for."""
    from jax.experimental.pallas import tpu as pltpu

    from repro.analysis.grid_semantics import check_captures_semantics

    return check_captures_semantics(_acc_capture(
        _acc_kernel, pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel"))))


def reversed_init_flush() -> List[Violation]:
    """Init gated on the LAST step and flush on the FIRST: early steps
    accumulate into uninitialised scratch and a partial sum leaves."""
    from jax.experimental.pallas import tpu as pltpu

    from repro.analysis.grid_semantics import check_captures_semantics

    return check_captures_semantics(_acc_capture(
        _reversed_acc_kernel, pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"))))


def unaliased_inplace_output() -> List[Violation]:
    """A kernel reading its output ref with no input_output_aliases —
    the first visit of each block reads uninitialised VMEM."""
    from jax.experimental.pallas import tpu as pltpu

    from repro.analysis.grid_semantics import check_captures_semantics

    return check_captures_semantics(_capture_2d(
        (512, 256), (128, 256), kernel=_inplace_kernel,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel"))))


def cost_model_regression() -> List[Violation]:
    """The current tree diffed against a baseline whose byte counts are
    10% smaller — every row regresses past the 2% CI threshold."""
    from repro.analysis.cost_model import build_table, compare_to_baseline

    rows = build_table()
    deflated = {"rows": {
        r["label"]: {"hbm_bytes": int(r["hbm_bytes"] * 0.9)}
        for r in rows}}
    return compare_to_baseline(rows, deflated)


def raw_neg_inf_literal() -> List[Violation]:
    return check_source(
        "MASK_VALUE = -2.0e38\n",
        "src/repro/models/bad_sentinel.py")


def exp_in_models() -> List[Violation]:
    return check_source(
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    return jnp.exp(x)\n",
        "src/repro/models/bad_exp.py")


def interpret_literal_in_src() -> List[Violation]:
    return check_source(
        "def f(q, k, v, flash):\n"
        "    return flash(q, k, v, interpret=True)\n",
        "src/repro/serving/bad_interpret.py")


def override_branch_outside_seam() -> List[Violation]:
    """Per-layer override plumbing consulted outside the seam: a models/
    helper iterating the override pairs and branching on the mode string
    by hand — both of which must go through ``q.scoped`` /
    ``datapath.resolve`` (DESIGN.md §16).  Goes through the REAL
    ``tools/check_dispatch.check_text`` scanner so the fixture also pins
    the extended rule itself."""
    import importlib.util
    from pathlib import Path

    root = Path(__file__).resolve().parents[3]
    spec = importlib.util.spec_from_file_location(
        "_check_dispatch_for_fixture", root / "tools" / "check_dispatch.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    # the seam tokens are split so THIS file's source does not trip the
    # tree-wide scan the fixture exercises
    bad = ("def pick_backend(q, scope):\n"
           "    for pattern, ov in q.over" "rides:\n"
           "        if q.mo" "de == 'kernel':\n"
           "            return ov\n")
    return [Violation("dispatch-seam", "fixture", p)
            for p in mod.check_text(bad, "src/repro/models/bad_scoping.py")]


def adhoc_timing_in_src() -> List[Violation]:
    """Hand-rolled perf_counter deltas in library code — the timing that
    belongs in a ``telemetry.span`` (DESIGN.md §15)."""
    return check_source(
        "import time\n"
        "def f(fn):\n"
        "    t0 = time.perf_counter()\n"
        "    fn()\n"
        "    return time.perf_counter() - t0\n",
        "src/repro/serving/bad_timing.py")


FIXTURES: Dict[str, Callable[[], List[Violation]]] = {
    "vmem-over-budget": vmem_over_budget,
    "misaligned-tile": misaligned_tile,
    "uncovered-output-block": uncovered_output_block,
    "wrong-scratch-dtype": wrong_scratch_dtype,
    "float-softmax-kernel-trace": float_softmax_in_kernel_trace,
    "f64-leak": f64_leak,
    "raw-neg-inf-literal": raw_neg_inf_literal,
    "exp-in-models": exp_in_models,
    "interpret-literal-in-src": interpret_literal_in_src,
    "adhoc-timing-in-src": adhoc_timing_in_src,
    "override-branch-outside-seam": override_branch_outside_seam,
    "missing-dim-semantics": missing_dim_semantics,
    "race-parallel-accumulator": race_parallel_accumulator,
    "reversed-init-flush": reversed_init_flush,
    "unaliased-inplace-output": unaliased_inplace_output,
    "cost-model-regression": cost_model_regression,
}

# the rule each fixture must trip (self-test assertion)
FIXTURE_RULES: Dict[str, str] = {
    "vmem-over-budget": "kernel-contracts",
    "misaligned-tile": "kernel-contracts",
    "uncovered-output-block": "kernel-contracts",
    "wrong-scratch-dtype": "kernel-contracts",
    "float-softmax-kernel-trace": "trace-invariants",
    "f64-leak": "trace-invariants",
    "raw-neg-inf-literal": "neg-inf-literal",
    "exp-in-models": "models-float-nonlinear",
    "interpret-literal-in-src": "interpret-literal",
    "adhoc-timing-in-src": "no-adhoc-timing",
    "override-branch-outside-seam": "dispatch-seam",
    "missing-dim-semantics": "grid-semantics",
    "race-parallel-accumulator": "grid-semantics",
    "reversed-init-flush": "grid-semantics",
    "unaliased-inplace-output": "grid-semantics",
    "cost-model-regression": "cost-model",
}


def run_fixture(name: str) -> List[Violation]:
    errors = [v for v in FIXTURES[name]() if v.severity == ERROR]
    return errors
