"""Static per-pallas_call FLOPs / HBM-bytes / VMEM cost model (DESIGN.md §14).

Everything is derived from the captured call alone — grid, BlockSpecs,
dtypes and the kernel's ``functools.partial`` configuration — with no
execution:

* **HBM traffic** — Pallas walks the grid in lexicographic order (last
  axis fastest) and re-fetches an operand block only when its
  ``index_map`` value changes between consecutive steps.  The model
  counts those transitions per operand (``bytes_traffic``) and also the
  distinct-block footprint (``bytes_unique`` — what an ideal
  infinite-VMEM schedule would move, and what the analytic counters in
  ``kernel_bench`` count).  Mantissa and exponent planes are separate
  operands, so their bytes are accounted separately, at 1 byte/element —
  the paper's packed-plane memory win is visible per row.
* **FLOPs** — closed-form per kernel family from block shapes and the
  partial's config (dot products 2·m·k·n; one-hot LUT contractions
  2·elements·2^bits; O(10)·elements vector work for the rowwise
  datapaths).  Formulas are in DESIGN.md §14; they feed the arithmetic-
  intensity column of the roofline table, while the BYTE columns are the
  CI-guarded quantity.
* **VMEM residency** — ``2 × (in+out block bytes) + scratch`` (the same
  double-buffering model the kernel-contracts VMEM cap uses).

The ``cost-model`` rule (a) cross-validates the model against
``benchmarks.kernel_bench._ln_linear_hbm_bytes`` — the analytic counter
the bench already publishes — at the bench LN→linear shape and on the
DeiT-tiny LN→qkv fusion study (the fused datapath must reproduce the
~23% byte saving), and (b) diffs every sweep row against the committed
baseline ``benchmarks/_cache/cost_model_baseline.json``, failing on
>2% traffic-byte regressions (refresh with
``tools/repro_lint.py --update-cost-baseline`` after an intentional
tiling change).
"""
from __future__ import annotations

import functools
import itertools
import json
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import (DOUBLE_BUFFER, MAX_GRID_POINTS,
                                             BlockUse, PallasCapture,
                                             _nbytes, capture_pallas_calls,
                                             sweep_captures)
from repro.analysis.registry import ERROR, WARN, Violation, register_rule

BASELINE_RELPATH = Path("benchmarks/_cache/cost_model_baseline.json")
REGRESSION_THRESHOLD = 0.02     # CI fails on >2% traffic-byte growth
CROSS_VAL_RTOL = 0.02           # model vs analytic counter agreement
# gamma/beta/LUT sidecar operands the analytic counter ignores stay
# within CROSS_VAL_RTOL of the plane+activation total on every shape we
# validate; a bigger gap means the model or the kernel changed shape


# ---------------------------------------------------------------------------
# traffic
# ---------------------------------------------------------------------------
def _block_bytes(use: BlockUse) -> int:
    return _nbytes(use.block_shape, use.dtype)


def operand_traffic(use: BlockUse,
                    grid: Tuple[int, ...]) -> Optional[Tuple[int, int]]:
    """(consecutive re-fetches, distinct blocks) for one operand.

    Returns None when the grid is too large to enumerate (none of the
    swept kernels is)."""
    points = 1
    for g in grid:
        points *= g
    if points > MAX_GRID_POINTS:
        return None
    im = use.index_map
    fetches = 0
    prev: object = object()
    uniq = set()
    for idx in itertools.product(*[range(g) for g in grid]):
        if im is None:
            bid: Tuple[int, ...] = ()
        else:
            raw = im(*idx)
            raw = raw if isinstance(raw, (list, tuple)) else (raw,)
            bid = tuple(int(b) for b in raw)
        if bid != prev:
            fetches += 1
            prev = bid
        uniq.add(bid)
    return fetches, len(uniq)


# ---------------------------------------------------------------------------
# FLOPs (formulas: DESIGN.md §14)
# ---------------------------------------------------------------------------
def _partial_kwargs(cap: PallasCapture) -> Dict[str, object]:
    kw: Dict[str, object] = {}
    fn = cap.kernel_fn
    while isinstance(fn, functools.partial):
        kw.update(fn.keywords or {})
        fn = fn.func
    return kw


def _steps(grid: Tuple[int, ...]) -> int:
    n = 1
    for g in grid:
        n *= g
    return n


def _flops_matmul(cap, kw) -> int:
    bm, bk = cap.inputs[0].block_shape
    bn = cap.outputs[0].block_shape[-1]
    per = 2 * bm * bk * bn + bk * bn          # dot + exponent scale
    if kw.get("quantize_act"):
        per += 6 * bm * bk                    # in-register act quantize
    return _steps(cap.grid) * per


def _flops_ln_matmul(cap, kw) -> int:
    bm, d = cap.inputs[0].block_shape
    bn = cap.outputs[0].block_shape[-1]
    lut = 2 ** int(kw.get("lut_bits", 5))
    dot = _steps(cap.grid) * 2 * bm * d * bn
    ln = cap.grid[0] * (12 * bm * d + 2 * bm * lut)   # j == 0 only
    return dot + ln


def _flops_layernorm(cap, kw) -> int:
    br, d = cap.inputs[0].block_shape
    lut = 2 ** int(kw.get("lut_bits", 5))
    return _steps(cap.grid) * (12 * br * d + 2 * br * lut)


def _flops_softmax(cap, kw) -> int:
    br, n = cap.inputs[0].block_shape
    lut = 2 ** int(kw.get("r_bits", 2))
    return _steps(cap.grid) * (10 * br * n + 2 * br * n * lut)


def _flops_gelu(cap, kw) -> int:
    br, d = cap.inputs[0].block_shape
    lut = 2 ** int(kw.get("index_bits", 5))
    return _steps(cap.grid) * (8 * br * d + 2 * br * d * lut)


def _flops_flash(cap, kw) -> int:
    q = cap.inputs[0].block_shape       # (1, bq, d) / (1, 1, g, d)
    rows, d = q[-2], q[-1]
    bk = cap.inputs[1].block_shape[1]   # (1, bk, d) / (1, bk, 1, d)
    per = 4 * rows * bk * d + 10 * rows * bk   # qk + pv dots + update
    if kw.get("exp_mode") == "mxint":
        per += 2 * rows * bk * 2 ** int(kw.get("r_bits", 2))
    return _steps(cap.grid) * per


FLOPS: Dict[str, Callable[[PallasCapture, Dict[str, object]], int]] = {
    "_mxint_matmul_kernel": _flops_matmul,
    "_mxint_ln_matmul_kernel": _flops_ln_matmul,
    "_mxint_layernorm_kernel": _flops_layernorm,
    "_mxint_softmax_kernel": _flops_softmax,
    "_mxint_gelu_kernel": _flops_gelu,
    "_flash_kernel": _flops_flash,
    "_decode_kernel": _flops_flash,
}


# ---------------------------------------------------------------------------
# per-capture row
# ---------------------------------------------------------------------------
def capture_costs(cap: PallasCapture) -> Dict[str, object]:
    operands = []
    traffic_total = 0
    unique_total = 0
    for use in cap.inputs + cap.outputs:
        t = operand_traffic(use, cap.grid)
        bb = _block_bytes(use)
        if t is None:
            fetches, uniq = _steps(cap.grid), _steps(cap.grid)
        else:
            fetches, uniq = t
        operands.append({
            "name": use.name,
            "dtype": str(jnp.dtype(use.dtype)),
            "block": list(use.block_shape),
            "bytes_traffic": fetches * bb,
            "bytes_unique": uniq * bb,
        })
        traffic_total += fetches * bb
        unique_total += uniq * bb
    vmem = (DOUBLE_BUFFER * sum(_block_bytes(u)
                                for u in cap.inputs + cap.outputs)
            + sum(_nbytes(s.shape, s.dtype) for s in cap.scratch))
    flops_fn = FLOPS.get(cap.kernel)
    flops = flops_fn(cap, _partial_kwargs(cap)) if flops_fn else 0
    return {
        "label": cap.label,
        "kernel": cap.kernel,
        "grid": list(cap.grid),
        "flops": int(flops),
        "hbm_bytes": int(traffic_total),
        "unique_bytes": int(unique_total),
        "vmem_bytes": int(vmem),
        "intensity": round(flops / traffic_total, 3) if traffic_total else 0.0,
        "operands": operands,
    }


def build_table(caps: Optional[Sequence[PallasCapture]] = None
                ) -> List[Dict[str, object]]:
    if caps is None:
        caps = sweep_captures()
    return [capture_costs(c) for c in caps]


# ---------------------------------------------------------------------------
# query API (the repro.dse evaluator's entry point, DESIGN.md §16)
# ---------------------------------------------------------------------------
_TABLE_MEMO: List[Dict[str, object]] = []


def table(refresh: bool = False) -> List[Dict[str, object]]:
    """The standard-sweep cost table, memoized (the sweep itself is
    already memoized in kernel_contracts; this skips re-deriving rows).
    Rows are shallow copies — treat operand entries as read-only."""
    if refresh or not _TABLE_MEMO:
        _TABLE_MEMO[:] = build_table()
    return [dict(r) for r in _TABLE_MEMO]


def query(labels: Optional[Sequence[str]] = None
          ) -> Dict[str, Dict[str, object]]:
    """Label-keyed cost rows; with ``labels`` given, KeyError on any
    unknown label naming the known ones (typo-proof for callers keying
    off telemetry probe labels)."""
    rows = {r["label"]: r for r in table()}
    if labels is None:
        return rows
    missing = sorted(set(labels) - set(rows))
    if missing:
        raise KeyError(f"unknown cost-model labels {missing}; known: "
                       f"{sorted(rows)}")
    return {label: rows[label] for label in labels}


# ---------------------------------------------------------------------------
# DeiT LN->qkv fusion study (logical, unpadded shapes — what the bench's
# analytic counter accounts; the interpret wrapper's padding is a CPU
# artefact, not datapath traffic)
# ---------------------------------------------------------------------------
_FUSION_MEMO: Dict[str, Dict[str, object]] = {}


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def fusion_study(arch: str = "deit_tiny") -> Dict[str, object]:
    """Model bytes for fused vs unfused LN->qkv at DeiT logical shapes."""
    if arch in _FUSION_MEMO:
        return dict(_FUSION_MEMO[arch])
    from repro.configs.deit import BY_NAME
    from repro.kernels.mxint_layernorm import mxint_layernorm
    from repro.kernels.mxint_ln_matmul import mxint_ln_matmul
    from repro.kernels.mxint_matmul import mxint_matmul

    cfg = BY_NAME[arch]
    d = cfg.d_model
    M = (cfg.image_size // cfg.patch_size) ** 2 + 1
    w_block, n_linears = 32, 3
    bn = 64 if d % 64 == 0 else d

    fused_caps = capture_pallas_calls(
        lambda x, g, b, m, e: mxint_ln_matmul.__wrapped__(
            x, g, b, m, e, w_block=w_block, act_block=16, mant_bits=8,
            lut_bits=5, bm=1, bn=bn, interpret=True),
        _sds((M, d)), _sds((d,)), _sds((d,)),
        _sds((d, d), jnp.int8), _sds((d // w_block, d), jnp.int8),
        label=f"{arch}-lnqkv-fused")
    ln_caps = capture_pallas_calls(
        lambda x, g, b: mxint_layernorm.__wrapped__(
            x, g, b, act_block=16, mant_bits=8, lut_bits=5,
            quantize_out=True, block_rows=1, interpret=True),
        _sds((M, d)), _sds((d,)), _sds((d,)),
        label=f"{arch}-lnqkv-unfused-ln")
    mm_caps = capture_pallas_calls(
        lambda x, m, e: mxint_matmul.__wrapped__(
            x, m, e, w_block=w_block, act_block=16, act_mant_bits=8,
            quantize_act=True, bm=1, bn=bn, bk=d, interpret=True,
            out_dtype=jnp.float32),
        _sds((M, d)), _sds((d, d), jnp.int8),
        _sds((d // w_block, d), jnp.int8),
        label=f"{arch}-lnqkv-unfused-linear")

    rows = build_table(fused_caps + ln_caps + mm_caps)
    by_label = {r["label"]: r for r in rows}
    fused = n_linears * by_label[f"{arch}-lnqkv-fused"]["unique_bytes"]
    unfused = (by_label[f"{arch}-lnqkv-unfused-ln"]["unique_bytes"]
               + n_linears
               * by_label[f"{arch}-lnqkv-unfused-linear"]["unique_bytes"])
    result = {
        "arch": arch,
        "rows_tokens": M, "d_model": d, "w_block": w_block,
        "n_linears": n_linears,
        "fused_bytes": int(fused),
        "unfused_bytes": int(unfused),
        "saving_pct": round(100.0 * (unfused - fused) / unfused, 2),
        "rows": rows,
    }
    _FUSION_MEMO[arch] = result
    return dict(result)


def report(root: Path) -> Dict[str, object]:
    """The machine-readable roofline table (repro_lint --json payload)."""
    fusion = fusion_study()
    return {
        "rows": build_table(),
        "fusion": {k: v for k, v in fusion.items() if k != "rows"},
        "fusion_rows": fusion["rows"],
    }


# ---------------------------------------------------------------------------
# baseline diff + analytic cross-validation
# ---------------------------------------------------------------------------
def baseline_payload() -> Dict[str, object]:
    fusion = fusion_study()
    return {
        "version": 1,
        "threshold_pct": 100 * REGRESSION_THRESHOLD,
        "rows": {r["label"]: {k: r[k] for k in
                              ("hbm_bytes", "unique_bytes", "flops",
                               "vmem_bytes")}
                 for r in build_table()},
        "fusion": {fusion["arch"]: {k: fusion[k] for k in
                                    ("fused_bytes", "unfused_bytes",
                                     "saving_pct")}},
    }


def write_baseline(root: Path) -> Path:
    path = root / BASELINE_RELPATH
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(baseline_payload(), indent=1,
                               sort_keys=True) + "\n")
    return path


def compare_to_baseline(rows: Sequence[Dict[str, object]],
                        baseline: Dict[str, object],
                        threshold: float = REGRESSION_THRESHOLD
                        ) -> List[Violation]:
    out: List[Violation] = []
    current = {r["label"]: r for r in rows}
    base_rows = baseline.get("rows", {})
    for label, base in sorted(base_rows.items()):
        cur = current.get(label)
        if cur is None:
            out.append(Violation(
                "cost-model", label,
                "baseline row has no current counterpart — the sweep "
                "shrank; refresh the baseline if intentional"))
            continue
        b, c = int(base["hbm_bytes"]), int(cur["hbm_bytes"])
        if c > b * (1 + threshold):
            out.append(Violation(
                "cost-model", label,
                f"HBM traffic regression: {c} bytes vs baseline {b} "
                f"(+{100.0 * (c - b) / b:.1f}% > "
                f"{100 * threshold:.0f}%) — a BlockSpec/tiling change "
                f"reinflated the datapath; fix it or refresh the "
                f"baseline (--update-cost-baseline)"))
        elif c < b * (1 - threshold):
            out.append(Violation(
                "cost-model", label,
                f"HBM traffic improved {100.0 * (b - c) / b:.1f}% vs "
                f"baseline ({c} vs {b}) — refresh the baseline to guard "
                f"the win", severity=WARN))
    for label in sorted(set(current) - set(base_rows)):
        out.append(Violation(
            "cost-model", label,
            "row missing from the committed baseline — refresh it "
            "(--update-cost-baseline)", severity=WARN))
    return out


def _analytic_counter(root: Path):
    import sys
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    from benchmarks.kernel_bench import _ln_linear_hbm_bytes
    return _ln_linear_hbm_bytes


def cross_validate(root: Path) -> List[Violation]:
    """Model vs the bench's analytic byte counters."""
    out: List[Violation] = []
    try:
        analytic = _analytic_counter(root)
    except Exception as exc:   # pragma: no cover - import environment
        return [Violation(
            "cost-model", "cross-validation",
            f"cannot import benchmarks.kernel_bench analytic counter: "
            f"{exc!r}")]

    def _check(where, model, want, rtol=CROSS_VAL_RTOL):
        if not (abs(model - want) <= rtol * want):
            out.append(Violation(
                "cost-model", where,
                f"model bytes {model} vs analytic {want} "
                f"(|Δ| > {100 * rtol:.0f}%) — the static model and the "
                f"bench counter disagree"))

    # bench LN->linear shape: one fused call, rows=256, d=n=768, OCP-32
    rows = build_table()
    ln = next((r for r in rows if r["label"] == "ln-matmul-bench"), None)
    if ln is None:
        out.append(Violation("cost-model", "ln-matmul-bench",
                             "sweep lost the fused LN->matmul row"))
    else:
        _check("ln-matmul-bench", ln["unique_bytes"],
               analytic(256, 768, 768, 32, 1, fused=True))

    # DeiT-tiny LN->qkv fusion: totals and the headline saving
    fus = fusion_study()
    M, d, wb, nl = (fus["rows_tokens"], fus["d_model"], fus["w_block"],
                    fus["n_linears"])
    want_fused = analytic(M, d, d, wb, nl, fused=True)
    want_unfused = analytic(M, d, d, wb, nl, fused=False)
    _check("deit-lnqkv-fused", fus["fused_bytes"], want_fused)
    _check("deit-lnqkv-unfused", fus["unfused_bytes"], want_unfused)
    want_saving = 100.0 * (want_unfused - want_fused) / want_unfused
    if abs(fus["saving_pct"] - want_saving) > 1.5 or not (
            20.0 <= fus["saving_pct"] <= 26.0):
        out.append(Violation(
            "cost-model", "deit-lnqkv-saving",
            f"fused LN->qkv byte saving {fus['saving_pct']}% does not "
            f"reproduce the bench's ~{want_saving:.1f}% claim"))
    return out


@register_rule(
    "cost-model",
    "Static FLOPs/HBM-bytes/VMEM roofline per pallas_call, cross-"
    "validated against kernel_bench's analytic counters and diffed "
    "against benchmarks/_cache/cost_model_baseline.json (>2% byte "
    "regressions fail)")
def run(root: Path) -> List[Violation]:
    out = cross_validate(root)
    path = root / BASELINE_RELPATH
    if not path.exists():
        out.append(Violation(
            "cost-model", str(BASELINE_RELPATH),
            "committed cost-model baseline missing — generate it with "
            "tools/repro_lint.py --update-cost-baseline"))
        return out
    try:
        baseline = json.loads(path.read_text())
    except ValueError as exc:
        out.append(Violation("cost-model", str(BASELINE_RELPATH),
                             f"baseline is not valid JSON: {exc}"))
        return out
    out.extend(compare_to_baseline(build_table(), baseline))
    return out
