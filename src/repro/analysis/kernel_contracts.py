"""Static Pallas kernel-contract checker (DESIGN.md §13).

Every ``pallas_call`` in ``repro/kernels/`` is captured by ABSTRACT
evaluation — the wrapper runs under ``jax.eval_shape`` with
``pl.pallas_call`` swapped for a recorder that grabs the grid, the
Block Specs, the scratch shapes and the operand avals, then returns
zero-filled outputs of the declared ``out_shape`` (no kernel body ever
executes).  Four contracts are then verified per captured call:

1. **VMEM budget** — ``dbuf * (in-block + out-block bytes) + scratch``
   must fit the configurable per-core cap (default 16 MiB, the v5e VMEM
   size; ``dbuf=2`` models Pallas' input/output double buffering).
2. **Tile alignment** — on every axis a BlockSpec actually tiles
   (block < array dim), the block must divide the dim; the minormost
   tiled axis must be a multiple of the 128-wide lane, the second-minor
   a multiple of the 8-row f32 sublane (or exactly 1 — a supported
   degenerate layout).  Narrow dtypes have larger NATIVE sublanes
   (bf16 16, int8 32); those are reported at ``warn`` severity because
   Mosaic relayouts can legalise them and we cannot compile on CPU to
   confirm either way.
3. **index_map coverage** — every input index map, enumerated over the
   full grid with concrete ints, must stay in bounds; every OUTPUT block
   must be produced by at least one grid step (a constant out map over a
   tiled output silently leaves garbage blocks).
4. **Scratch-dtype contracts** — per-kernel declarations
   (:data:`SCRATCH_CONTRACTS`), e.g. ``mxint_ln_matmul`` keeps its
   normalised tile in MODEL dtype scratch while the matmul accumulators
   are always f32.

The built-in sweep (:func:`sweep_captures`) drives every kernel in
``repro/kernels/`` through the shapes ``benchmarks/kernel_bench.py``
uses plus the padded DeiT shapes the model path produces.
"""
from __future__ import annotations

import dataclasses
import itertools
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.registry import ERROR, WARN, Violation, register_rule

VMEM_CAP_BYTES = 16 * 2 ** 20   # per-core VMEM (TPU v5e)
DOUBLE_BUFFER = 2               # in/out blocks are double-buffered
LANE = 128
SUBLANE_F32 = 8
# native sublane tiling per element width; sub-4-byte mismatches are
# warnings (see module docstring)
NATIVE_SUBLANE = {1: 32, 2: 16, 4: 8, 8: 8}
# keep index-map enumeration cheap; none of the swept kernels comes close
MAX_GRID_POINTS = 65536


@dataclasses.dataclass(frozen=True)
class BlockUse:
    """One operand (or output) of a captured pallas_call."""

    name: str
    shape: Tuple[int, ...]
    dtype: jnp.dtype
    block_shape: Tuple[int, ...]
    index_map: Optional[Callable]


@dataclasses.dataclass(frozen=True)
class ScratchUse:
    shape: Tuple[int, ...]
    dtype: jnp.dtype


@dataclasses.dataclass(frozen=True)
class PallasCapture:
    label: str                  # sweep entry that produced this call
    kernel: str                 # kernel function __name__
    grid: Tuple[int, ...]
    inputs: Tuple[BlockUse, ...]
    outputs: Tuple[BlockUse, ...]
    scratch: Tuple[ScratchUse, ...]
    # grid-semantics capture (DESIGN.md §14): the declared per-axis
    # dimension_semantics (None == the call declared nothing), any
    # input->output aliasing, and the kernel callable itself (possibly a
    # functools.partial — grid_semantics AST-inspects its source and
    # resolves comparator names from the partial's keywords)
    dimension_semantics: Optional[Tuple[str, ...]] = None
    input_output_aliases: Tuple[Tuple[int, int], ...] = ()
    kernel_fn: Optional[Callable] = dataclasses.field(
        default=None, compare=False)


def _dimension_semantics(compiler_params) -> Optional[Tuple[str, ...]]:
    """Extract dimension_semantics from a ``compiler_params`` kwarg in any
    of the forms pallas_call accepts (TPUCompilerParams dataclass, flat
    dict, or the legacy {"mosaic": {...}} nesting)."""
    if compiler_params is None:
        return None
    if isinstance(compiler_params, dict):
        inner = compiler_params.get("mosaic", compiler_params)
        ds = inner.get("dimension_semantics") if isinstance(inner, dict) \
            else getattr(inner, "dimension_semantics", None)
    else:
        ds = getattr(compiler_params, "dimension_semantics", None)
    return tuple(ds) if ds is not None else None


# ---------------------------------------------------------------------------
# capture
# ---------------------------------------------------------------------------
def _as_tuple(x):
    if x is None:
        return ()
    if isinstance(x, (list, tuple)):
        return tuple(x)
    return (x,)


def _kernel_name(kernel) -> str:
    return getattr(getattr(kernel, "func", kernel), "__name__", str(kernel))


def capture_pallas_calls(fn, *args, label: str = "?",
                         **kwargs) -> List[PallasCapture]:
    """Abstractly evaluate ``fn(*args, **kwargs)`` recording every
    ``pallas_call`` it stages.  ``args`` may be arrays or
    ``ShapeDtypeStruct``s; nothing is executed.

    The pjit trace cache is cleared first: a jit-wrapped kernel wrapper
    whose jaxpr is already cached would be inlined WITHOUT re-running its
    Python body, and the recorder would silently miss the call.
    """
    import jax.experimental.pallas as plmod

    records: List[PallasCapture] = []
    real = plmod.pallas_call

    def spy(kernel, out_shape=None, **kw):
        osh = kw.get("out_shape", out_shape)
        grid = kw.get("grid", ())
        in_specs = _as_tuple(kw.get("in_specs"))
        out_specs = _as_tuple(kw.get("out_specs"))
        scratch = _as_tuple(kw.get("scratch_shapes", ()))
        out_sds = _as_tuple(osh)
        dim_sem = _dimension_semantics(kw.get("compiler_params"))
        aliases = tuple(sorted(
            (int(a), int(b))
            for a, b in dict(kw.get("input_output_aliases") or {}).items()))

        def runner(*operands):
            ins = tuple(
                BlockUse(name=f"in{i}", shape=tuple(jnp.shape(o)),
                         dtype=jnp.dtype(o.dtype),
                         block_shape=tuple(s.block_shape)
                         if s.block_shape is not None else tuple(jnp.shape(o)),
                         index_map=s.index_map)
                for i, (s, o) in enumerate(zip(in_specs, operands)))
            outs = tuple(
                BlockUse(name=f"out{i}", shape=tuple(sd.shape),
                         dtype=jnp.dtype(sd.dtype),
                         block_shape=tuple(s.block_shape)
                         if s.block_shape is not None else tuple(sd.shape),
                         index_map=s.index_map)
                for i, (s, sd) in enumerate(zip(out_specs, out_sds)))
            scr = tuple(ScratchUse(shape=tuple(s.shape),
                                   dtype=jnp.dtype(s.dtype)) for s in scratch)
            records.append(PallasCapture(
                label=label, kernel=_kernel_name(kernel),
                grid=tuple(grid) if isinstance(grid, (list, tuple))
                else (grid,),
                inputs=ins, outputs=outs, scratch=scr,
                dimension_semantics=dim_sem,
                input_output_aliases=aliases, kernel_fn=kernel))
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), osh)

        return runner

    jax.clear_caches()
    plmod.pallas_call = spy
    try:
        jax.eval_shape(lambda *a: fn(*a, **kwargs), *args)
    finally:
        plmod.pallas_call = real
        jax.clear_caches()     # drop jaxprs traced against the spy
    return records


# ---------------------------------------------------------------------------
# contracts
# ---------------------------------------------------------------------------
def _nbytes(shape, dtype) -> int:
    n = 1
    for d in shape:
        n *= d
    return n * jnp.dtype(dtype).itemsize


def _where(cap: PallasCapture) -> str:
    return f"{cap.label}/{cap.kernel}"


def _check_alignment(cap: PallasCapture, use: BlockUse) -> List[Violation]:
    out: List[Violation] = []
    if len(use.block_shape) != len(use.shape):
        out.append(Violation(
            "kernel-contracts", _where(cap),
            f"{use.name}: block rank {use.block_shape} != array rank "
            f"{use.shape}"))
        return out
    for dim, blk in zip(use.shape, use.block_shape):
        if blk <= 0 or dim % blk:
            out.append(Violation(
                "kernel-contracts", _where(cap),
                f"{use.name}: block {use.block_shape} does not divide "
                f"array {use.shape} (dim {dim} % block {blk} != 0); the "
                f"wrapper must pad before launching"))
            return out
    # lane/sublane alignment only matters on axes the grid actually tiles
    tiled = [blk < dim for dim, blk in zip(use.shape, use.block_shape)]
    if len(use.shape) >= 1 and tiled[-1]:
        blk = use.block_shape[-1]
        if blk % LANE:
            out.append(Violation(
                "kernel-contracts", _where(cap),
                f"{use.name}: minormost tiled block dim {blk} is not a "
                f"multiple of the {LANE}-wide lane "
                f"(block {use.block_shape} over {use.shape})"))
    if len(use.shape) >= 2 and tiled[-2]:
        blk = use.block_shape[-2]
        if blk != 1 and blk % SUBLANE_F32:
            out.append(Violation(
                "kernel-contracts", _where(cap),
                f"{use.name}: second-minor tiled block dim {blk} is neither "
                f"1 nor a multiple of the {SUBLANE_F32}-row sublane "
                f"(block {use.block_shape} over {use.shape})"))
        else:
            native = NATIVE_SUBLANE[jnp.dtype(use.dtype).itemsize]
            if blk != 1 and native != SUBLANE_F32 and blk % native:
                out.append(Violation(
                    "kernel-contracts", _where(cap),
                    f"{use.name}: second-minor tiled block dim {blk} is not "
                    f"a multiple of {use.dtype}'s native ({native},{LANE}) "
                    f"tile — Mosaic may need a relayout on real hardware "
                    f"(for mxint exponent planes, exp_block_rows={native} "
                    f"selects the native fetch)", severity=WARN))
    return out


def _iter_grid(grid: Tuple[int, ...]):
    return itertools.product(*[range(g) for g in grid])


def _check_index_maps(cap: PallasCapture) -> List[Violation]:
    out: List[Violation] = []
    points = 1
    for g in cap.grid:
        points *= g
    if points > MAX_GRID_POINTS:
        out.append(Violation(
            "kernel-contracts", _where(cap),
            f"grid {cap.grid} has {points} steps (> {MAX_GRID_POINTS}); "
            f"index-map coverage not enumerated", severity=WARN))
        return out
    for use in cap.inputs + cap.outputs:
        if use.index_map is None:
            continue
        nblocks = tuple(dim // blk for dim, blk
                        in zip(use.shape, use.block_shape))
        if any(b == 0 for b in nblocks):
            continue  # divisibility already flagged
        seen = set()
        for idx in _iter_grid(cap.grid):
            bid = use.index_map(*idx)
            bid = tuple(bid) if isinstance(bid, (list, tuple)) else (bid,)
            if len(bid) != len(nblocks):
                out.append(Violation(
                    "kernel-contracts", _where(cap),
                    f"{use.name}: index_map returns rank {len(bid)} for a "
                    f"rank-{len(nblocks)} blocked operand"))
                break
            if any(not (0 <= int(b) < n) for b, n in zip(bid, nblocks)):
                out.append(Violation(
                    "kernel-contracts", _where(cap),
                    f"{use.name}: index_map{idx} -> {tuple(int(b) for b in bid)} "
                    f"out of bounds for {nblocks} blocks "
                    f"(array {use.shape}, block {use.block_shape})"))
                break
            seen.add(tuple(int(b) for b in bid))
        else:
            if use.name.startswith("out"):
                every = set(itertools.product(*[range(n) for n in nblocks]))
                missing = sorted(every - seen)
                if missing:
                    out.append(Violation(
                        "kernel-contracts", _where(cap),
                        f"{use.name}: index_map never writes output "
                        f"block(s) {missing[:4]}{'...' if len(missing) > 4 else ''} "
                        f"of {len(every)} — uncovered blocks hold garbage"))
    return out


def _check_vmem(cap: PallasCapture, cap_bytes: int) -> List[Violation]:
    blocks = sum(_nbytes(u.block_shape, u.dtype)
                 for u in cap.inputs + cap.outputs)
    scratch = sum(_nbytes(s.shape, s.dtype) for s in cap.scratch)
    total = DOUBLE_BUFFER * blocks + scratch
    if total > cap_bytes:
        return [Violation(
            "kernel-contracts", _where(cap),
            f"per-step VMEM {total} bytes ({DOUBLE_BUFFER}x{blocks} block + "
            f"{scratch} scratch) exceeds the {cap_bytes}-byte cap")]
    return []


def _ln_matmul_scratch(cap: PallasCapture) -> List[str]:
    """mxint_ln_matmul: scratch[0] holds the normalised x tile in the
    MODEL dtype (DESIGN.md §12) — an f32-only scratch would silently
    change the requantisation grid for bf16 models."""
    if not cap.scratch:
        return ["expected a (bm, d) model-dtype scratch, found none"]
    want = cap.inputs[0].dtype
    got = cap.scratch[0].dtype
    if got != want:
        return [f"LN scratch dtype {got} != model/x dtype {want}"]
    return []


def _f32_scratch(cap: PallasCapture) -> List[str]:
    bad = [s for s in cap.scratch if jnp.dtype(s.dtype) != jnp.float32]
    if bad:
        return [f"accumulator scratch must be f32, found "
                f"{[str(jnp.dtype(s.dtype)) for s in bad]}"]
    return []


def _flash_scratch(cap: PallasCapture) -> List[str]:
    probs = _f32_scratch(cap)
    if len(cap.scratch) != 3:
        probs.append(f"flash kernels carry (m, l, acc) scratch, "
                     f"found {len(cap.scratch)}")
    return probs


SCRATCH_CONTRACTS: Dict[str, Callable[[PallasCapture], List[str]]] = {
    "_mxint_ln_matmul_kernel": _ln_matmul_scratch,
    "_mxint_matmul_kernel": _f32_scratch,
    "_flash_kernel": _flash_scratch,
    "_decode_kernel": _flash_scratch,
}


def check_capture(cap: PallasCapture,
                  vmem_cap: int = VMEM_CAP_BYTES) -> List[Violation]:
    out: List[Violation] = []
    for use in cap.inputs + cap.outputs:
        out.extend(_check_alignment(cap, use))
    out.extend(_check_index_maps(cap))
    out.extend(_check_vmem(cap, vmem_cap))
    contract = SCRATCH_CONTRACTS.get(cap.kernel)
    if contract is not None:
        out.extend(Violation("kernel-contracts", _where(cap), msg)
                   for msg in contract(cap))
    return out


# ---------------------------------------------------------------------------
# the built-in sweep (kernel_bench shapes + padded DeiT shapes)
# ---------------------------------------------------------------------------
def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _sweep_matmul() -> List[PallasCapture]:
    from repro.kernels.mxint_matmul import mxint_matmul
    caps = []
    # kernel_bench: 128x1024 @ 1024x512, paper W-block 256
    caps += capture_pallas_calls(
        lambda x, m, e: mxint_matmul.__wrapped__(
            x, m, e, w_block=256, act_block=16, act_mant_bits=8,
            quantize_act=True, bm=128, bn=128, bk=256, interpret=True,
            out_dtype=jnp.float32),
        _sds((128, 1024)), _sds((1024, 512), jnp.int8),
        _sds((4, 512), jnp.int8), label="matmul-bench")
    # mxint_linear compiled-TPU tiling: bk=512, OCP-32 weight blocks,
    # exponent plane fetched in its native int8 (32, 128) tile (the
    # exp_block_rows ops.py wiring — keeps the relayout WARN retired)
    caps += capture_pallas_calls(
        lambda x, m, e: mxint_matmul.__wrapped__(
            x, m, e, w_block=32, act_block=16, act_mant_bits=8,
            quantize_act=True, bm=128, bn=128, bk=512, exp_block_rows=32,
            interpret=False, out_dtype=jnp.float32),
        _sds((128, 1024)), _sds((1024, 768), jnp.int8),
        _sds((32, 768), jnp.int8), label="matmul-compiled")
    # DeiT-Tiny model-path linear: 2x197 tokens padded to 400 rows,
    # d=192 contraction, lanes padded to 256, OCP-32 weight blocks —
    # the config ops.mxint_linear launches for the qkv/proj/FFN
    # projections.  Runtime twin: repro.telemetry.probes
    # ("matmul-deit"), joined by label in predicted_vs_measured.
    caps += capture_pallas_calls(
        lambda x, m, e: mxint_matmul.__wrapped__(
            x, m, e, w_block=32, act_block=16, act_mant_bits=8,
            quantize_act=True, bm=16, bn=128, bk=192, interpret=True,
            out_dtype=jnp.float32),
        _sds((400, 192)), _sds((192, 256), jnp.int8),
        _sds((6, 256), jnp.int8), label="matmul-deit")
    return caps


def _sweep_rowwise() -> List[PallasCapture]:
    from repro.kernels.mxint_gelu import mxint_gelu
    from repro.kernels.mxint_layernorm import mxint_layernorm
    from repro.kernels.mxint_softmax import mxint_softmax
    caps = []
    x = _sds((256, 768))
    g = _sds((768,))
    caps += capture_pallas_calls(
        lambda a, b, c: mxint_layernorm.__wrapped__(
            a, b, c, act_block=16, mant_bits=8, lut_bits=5,
            block_rows=128, interpret=True),
        x, g, g, label="layernorm-bench")
    caps += capture_pallas_calls(
        lambda a: mxint_softmax.__wrapped__(
            a, act_block=16, mant_bits=8, r_bits=2, block_rows=128,
            interpret=True),
        x, label="softmax-bench")
    caps += capture_pallas_calls(
        lambda a: mxint_gelu.__wrapped__(
            a, act_block=16, mant_bits=8, lut_bits=5, block_rows=128,
            interpret=True),
        x, label="gelu-bench")
    # DeiT-Tiny model-path rows: 2*197 tokens padded to 400, d=192
    caps += capture_pallas_calls(
        lambda a, b, c: mxint_layernorm.__wrapped__(
            a, b, c, act_block=16, mant_bits=8, lut_bits=5,
            block_rows=16, interpret=True),
        _sds((400, 192)), _sds((192,)), _sds((192,)), label="layernorm-deit")
    return caps


def _sweep_ln_matmul() -> List[PallasCapture]:
    from repro.kernels.mxint_ln_matmul import mxint_ln_matmul
    return capture_pallas_calls(
        lambda x, g, b, m, e: mxint_ln_matmul.__wrapped__(
            x, g, b, m, e, w_block=32, act_block=16, mant_bits=8,
            lut_bits=5, bm=128, bn=128, interpret=True),
        _sds((256, 768)), _sds((768,)), _sds((768,)),
        _sds((768, 768), jnp.int8), _sds((24, 768), jnp.int8),
        label="ln-matmul-bench")


def _sweep_flash() -> List[PallasCapture]:
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_decode)
    caps = []
    # kernel_bench: (4, 256, 128)
    caps += capture_pallas_calls(
        lambda q, k, v: flash_attention.__wrapped__(
            q, k, v, causal=True, block_q=128, block_k=128, interpret=True),
        _sds((4, 256, 128)), _sds((4, 256, 128)), _sds((4, 256, 128)),
        label="flash-bench")
    # DeiT padded attention shape the model path produces:
    # (b*h, 197->200, 64->128), kv padded to 256
    caps += capture_pallas_calls(
        lambda q, k, v: flash_attention.__wrapped__(
            q, k, v, causal=False, block_q=8, block_k=128, kv_len=197,
            interpret=True),
        _sds((6, 200, 128)), _sds((6, 256, 128)), _sds((6, 256, 128)),
        label="flash-deit")
    # decode over a 128-slot ring, GQA heads folded to sublane rows,
    # per-row (B, W) ring validity (slot-level batching contract)
    caps += capture_pallas_calls(
        lambda q, k, v, m: flash_attention_decode.__wrapped__(
            q, k, v, m, block_k=128, w_len=128, interpret=True),
        _sds((2, 2, 8, 128)), _sds((2, 128, 2, 128)),
        _sds((2, 128, 2, 128)), _sds((2, 128), jnp.bool_),
        label="flash-decode")
    return caps


SWEEP: Tuple[Callable[[], List[PallasCapture]], ...] = (
    _sweep_matmul, _sweep_rowwise, _sweep_ln_matmul, _sweep_flash)

# three rules (kernel-contracts, grid-semantics, cost-model) walk the
# same sweep; captures are immutable, so one abstract-eval pass serves
# them all within a process
_SWEEP_MEMO: List[PallasCapture] = []


def sweep_captures(refresh: bool = False) -> List[PallasCapture]:
    if _SWEEP_MEMO and not refresh:
        return list(_SWEEP_MEMO)
    caps: List[PallasCapture] = []
    for builder in SWEEP:
        caps.extend(builder())
    _SWEEP_MEMO[:] = caps
    return list(caps)


def check_captures(caps: Sequence[PallasCapture],
                   vmem_cap: int = VMEM_CAP_BYTES) -> List[Violation]:
    out: List[Violation] = []
    for cap in caps:
        out.extend(check_capture(cap, vmem_cap))
    return out


@register_rule(
    "kernel-contracts",
    "Pallas grid/BlockSpec/scratch contracts (VMEM budget, tile "
    "alignment, index-map coverage, scratch dtypes) over the "
    "kernel_bench + DeiT shape sweep")
def run(root: Path) -> List[Violation]:
    caps = sweep_captures()
    out = check_captures(caps)
    if not caps:
        out.append(Violation("kernel-contracts", "sweep",
                             "sweep captured no pallas_calls — the "
                             "recorder or the kernels moved"))
    return out
