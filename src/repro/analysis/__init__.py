"""repro.analysis — static kernel-contract + trace-invariant checking
(DESIGN.md §13-14).

Five passes over the MXInt datapath's load-bearing invariants:

* :mod:`repro.analysis.kernel_contracts` — abstract-eval capture of
  every ``pallas_call`` (VMEM budget, tile alignment, index-map
  coverage, scratch-dtype contracts) over the kernel_bench shape sweep.
* :mod:`repro.analysis.grid_semantics` — per-axis
  ``dimension_semantics`` race checker over the same captures:
  accumulator axes must be ``"arbitrary"``, independent tile axes
  ``"parallel"``, init/flush gates in order, in-place outputs aliased.
* :mod:`repro.analysis.cost_model` — static FLOPs / HBM-bytes / VMEM
  roofline per ``pallas_call``, cross-validated against kernel_bench's
  analytic counters and diffed against a committed baseline.
* :mod:`repro.analysis.trace_lint` — jaxpr allow/deny lists per datapath
  mode (no float softmax/f64 outside ``pallas_call`` in kernel mode, no
  ``pallas_call`` in XLA modes, per-block pallas budgets).
* :mod:`repro.analysis.source_rules` — AST rules (single NEG_INF
  sentinel, no bare float nonlinears in ``models/``, no
  ``interpret=True`` literals in ``src/``).

Importing this package registers every rule; run them with
``tools/repro_lint.py`` (CI) or :func:`repro.analysis.run_rules`
(tier-1 via ``tests/test_analysis.py``).
"""
from repro.analysis.registry import (ERROR, WARN, Rule, Violation,
                                     get_rule, register_rule, rules,
                                     run_rules)
from repro.analysis import kernel_contracts, source_rules, trace_lint
from repro.analysis import cost_model, grid_semantics
from repro.analysis import fixtures

__all__ = [
    "ERROR", "WARN", "Rule", "Violation", "get_rule", "register_rule",
    "rules", "run_rules", "kernel_contracts", "grid_semantics",
    "cost_model", "source_rules", "trace_lint", "fixtures",
]
