"""Static Pallas grid-semantics race checker (DESIGN.md §14).

Mosaic executes a ``pallas_call`` grid sequentially unless
``compiler_params.dimension_semantics`` marks axes ``"parallel"`` — and
our kernels depend on that default: ``mxint_matmul`` accumulates into a
f32 VMEM scratch across the K axis, ``mxint_ln_matmul`` keeps its
normalised tile resident across the N axis, the flash kernels carry
(m, l, acc) online-softmax state across the key axis.  Re-ordering (or
multi-core-partitioning) those axes is a data race; re-ordering the
independent tile axes is free parallelism.  This pass makes the contract
explicit and machine-checked, per captured call:

1. **Revisit inference** — each ref's ``index_map`` is probed per grid
   axis (holding the other axes at the grid corners): an axis the map
   does not depend on revisits the same block on every step of that
   axis.  An OUTPUT revisited along an axis is written on multiple steps
   — that axis needs ``"arbitrary"`` ordering.
2. **Accumulator-gate inference** — the kernel body (and one level of
   helpers it forwards ``program_id`` values to) is AST-scanned for
   ``pl.when(program_id(a) == ...)`` gates, resolving comparators
   through the ``functools.partial`` keywords the wrappers bind
   (``n_k - 1`` really is the last step of THIS grid).  A gated axis
   carries scratch state sequentially and needs ``"arbitrary"``.
3. **Declaration check** — every call must declare
   ``dimension_semantics``; a required-sequential axis declared
   ``"parallel"`` is a race (ERROR), an independent axis declared
   ``"arbitrary"`` is contradictory serialisation (ERROR, only when the
   kernel source was inspectable), missing/short declarations are
   ERRORs.
4. **Ordering hazards** — accumulator init gates must fire on step 0 and
   output flush gates on the LAST step of their axis; a reversed or
   interior (or dead, out-of-range) gate flushes garbage (ERROR).
5. **Unaliased in-place outputs** — a kernel that READS an output ref
   sees uninitialised VMEM on a block's first visit unless an input is
   aliased over it via ``input_output_aliases`` (ERROR; accumulate in
   scratch instead).

The rule walks the same abstract-eval sweep as ``kernel_contracts``
(shared memo), so every kernel in ``repro/kernels/`` is covered at the
kernel_bench + DeiT shapes.
"""
from __future__ import annotations

import ast
import dataclasses
import functools
import inspect
import textwrap
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.kernel_contracts import (BlockUse, PallasCapture,
                                             sweep_captures)
from repro.analysis.registry import ERROR, Violation, register_rule

VALID_SEMANTICS = ("parallel", "arbitrary")
_MAX_HELPER_DEPTH = 2


# ---------------------------------------------------------------------------
# 1. index-map axis dependence
# ---------------------------------------------------------------------------
def map_axis_dependence(use: BlockUse, grid: Tuple[int, ...]) -> Set[int]:
    """Grid axes ``use.index_map`` depends on, probed along each axis with
    the other axes pinned at the grid's corners (affine maps — the only
    kind BlockSpecs use — cannot hide a dependence from both corners)."""
    im = use.index_map
    if im is None:
        return set()
    deps: Set[int] = set()
    corners = [tuple(0 for _ in grid), tuple(g - 1 for g in grid)]
    for a, ga in enumerate(grid):
        if ga <= 1:
            continue
        for base in corners:
            seen = set()
            for v in range(ga):
                idx = list(base)
                idx[a] = v
                bid = im(*idx)
                bid = tuple(bid) if isinstance(bid, (list, tuple)) else (bid,)
                seen.add(tuple(int(b) for b in bid))
            if len(seen) > 1:
                deps.add(a)
                break
    return deps


def output_revisit_axes(cap: PallasCapture) -> Set[int]:
    """Axes along which some output block is written more than once."""
    out: Set[int] = set()
    for use in cap.outputs:
        deps = map_axis_dependence(use, cap.grid)
        for a, ga in enumerate(cap.grid):
            if ga > 1 and a not in deps:
                out.add(a)
    return out


# ---------------------------------------------------------------------------
# 2. AST accumulator-gate inference
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Gate:
    """One ``pl.when(...)`` whose predicate involves a ``program_id``."""

    axis: int
    is_eq: bool                    # equality predicate (init/flush shape)
    value: Optional[int]           # resolved comparator, None if opaque
    writes: Tuple[str, ...]        # ref roles stored in the gated body


def _unwrap_partial(kernel):
    env: Dict[str, object] = {}
    n_pos = 0
    fn = kernel
    while isinstance(fn, functools.partial):
        env.update(fn.keywords or {})
        n_pos += len(fn.args or ())
        fn = fn.func
    return fn, env, n_pos


def _fn_node(fn) -> Optional[ast.FunctionDef]:
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
    except (OSError, TypeError, SyntaxError, ValueError):
        return None
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return node
    return None


def _dotted(node: ast.AST) -> Optional[str]:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _pid_axis(node: ast.AST, axis_alias: Dict[str, int]) -> Optional[int]:
    """Axis index if ``node`` is ``pl.program_id(<const>)`` or an alias."""
    if isinstance(node, ast.Call):
        name = _dotted(node.func)
        if (name is not None and name.split(".")[-1] == "program_id"
                and node.args and isinstance(node.args[0], ast.Constant)):
            return int(node.args[0].value)
    if isinstance(node, ast.Name) and node.id in axis_alias:
        return axis_alias[node.id]
    return None


def _eval_expr(node: ast.AST, env: Dict[str, object]) -> Optional[int]:
    """Resolve a comparator expression against the partial-keyword env."""
    try:
        code = compile(ast.fix_missing_locations(
            ast.Expression(body=node)), "<gate>", "eval")
        val = eval(code, {"__builtins__": {}}, dict(env))  # noqa: S307
    except Exception:
        return None
    return int(val) if isinstance(val, (int, float)) and not isinstance(
        val, bool) else None


def _written_roles(body: Sequence[ast.stmt],
                   roles: Dict[str, str]) -> Tuple[str, ...]:
    found: Set[str] = set()
    for stmt in body:
        for node in ast.walk(stmt):
            tgt = None
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Subscript) and \
                            isinstance(t.value, ast.Name):
                        tgt = t.value.id
            elif isinstance(node, ast.AugAssign):
                if isinstance(node.target, ast.Subscript) and \
                        isinstance(node.target.value, ast.Name):
                    tgt = node.target.value.id
            if tgt is not None and tgt in roles:
                found.add(roles[tgt])
    return tuple(sorted(found))


@dataclasses.dataclass
class _BodyFacts:
    gates: List[Gate] = dataclasses.field(default_factory=list)
    output_reads: Set[str] = dataclasses.field(default_factory=set)
    src_ok: bool = True


def _scan_function(fn, env: Dict[str, object], roles: Dict[str, str],
                   axis_alias: Dict[str, int], facts: _BodyFacts,
                   depth: int) -> None:
    node = _fn_node(fn)
    if node is None:
        facts.src_ok = False
        return
    axis_alias = dict(axis_alias)

    # program_id aliases assigned in this body (``kb = pl.program_id(2)``)
    for stmt in ast.walk(node):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Name):
            axis = _pid_axis(stmt.value, {})
            if axis is not None:
                axis_alias[stmt.targets[0].id] = axis

    for sub in ast.walk(node):
        # pl.when-decorated inner functions
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for deco in sub.decorator_list:
                if not (isinstance(deco, ast.Call) and deco.args):
                    continue
                dname = _dotted(deco.func)
                if dname is None or dname.split(".")[-1] != "when":
                    continue
                pred = deco.args[0]
                if not isinstance(pred, ast.Compare) or len(pred.ops) != 1:
                    continue
                left, op, right = pred.left, pred.ops[0], pred.comparators[0]
                axis = _pid_axis(left, axis_alias)
                other = right
                if axis is None:
                    axis = _pid_axis(right, axis_alias)
                    other = left
                if axis is None:
                    continue
                facts.gates.append(Gate(
                    axis=axis, is_eq=isinstance(op, ast.Eq),
                    value=_eval_expr(other, env),
                    writes=_written_roles(sub.body, roles)))
        # in-place reads of output refs (Subscript load / AugAssign)
        if isinstance(sub, ast.Subscript) and isinstance(sub.value, ast.Name):
            name = sub.value.id
            if roles.get(name) == "output" and (
                    isinstance(sub.ctx, ast.Load)
                    or isinstance(sub.ctx, ast.AugStore)
                    if hasattr(ast, "AugStore") else False):
                facts.output_reads.add(name)
        if isinstance(sub, ast.AugAssign) and \
                isinstance(sub.target, ast.Subscript) and \
                isinstance(sub.target.value, ast.Name) and \
                roles.get(sub.target.value.id) == "output":
            facts.output_reads.add(sub.target.value.id)

    if depth >= _MAX_HELPER_DEPTH:
        return
    # one level of helper-call propagation: forward program_id aliases,
    # ref roles and resolvable values into same-module helpers
    globals_ = getattr(fn, "__globals__", {})
    for sub in ast.walk(node):
        if not (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)):
            continue
        target = globals_.get(sub.func.id)
        if not (inspect.isfunction(target) and target is not fn):
            continue
        tnode = _fn_node(target)
        if tnode is None:
            continue
        params = [a.arg for a in tnode.args.posonlyargs + tnode.args.args]
        kwparams = [a.arg for a in tnode.args.kwonlyargs]
        bound: List[Tuple[str, ast.AST]] = list(zip(params, sub.args))
        bound += [(kw.arg, kw.value) for kw in sub.keywords
                  if kw.arg is not None and kw.arg in params + kwparams]
        c_env: Dict[str, object] = {}
        c_roles: Dict[str, str] = {}
        c_alias: Dict[str, int] = {}
        for pname, arg in bound:
            if isinstance(arg, ast.Name):
                if arg.id in axis_alias:
                    c_alias[pname] = axis_alias[arg.id]
                elif arg.id in roles:
                    c_roles[pname] = roles[arg.id]
                elif arg.id in env:
                    c_env[pname] = env[arg.id]
            elif isinstance(arg, ast.Constant):
                c_env[pname] = arg.value
            else:
                axis = _pid_axis(arg, axis_alias)
                if axis is not None:
                    c_alias[pname] = axis
        _scan_function(target, c_env, c_roles, c_alias, facts, depth + 1)


def kernel_body_facts(cap: PallasCapture) -> _BodyFacts:
    """Gates, output reads and source availability for a capture's kernel."""
    facts = _BodyFacts()
    if cap.kernel_fn is None:
        facts.src_ok = False
        return facts
    fn, env, n_bound = _unwrap_partial(cap.kernel_fn)
    node = _fn_node(fn)
    if node is None:
        facts.src_ok = False
        return facts
    params = [a.arg for a in node.args.posonlyargs + node.args.args]
    params = params[n_bound:]
    n_in, n_out = len(cap.inputs), len(cap.outputs)
    n_scr = len(cap.scratch)
    if len(params) < n_in + n_out + n_scr and node.args.vararg is None:
        facts.src_ok = False
        return facts
    roles: Dict[str, str] = {}
    for i, p in enumerate(params[:n_in + n_out + n_scr]):
        roles[p] = ("input" if i < n_in
                    else "output" if i < n_in + n_out else "scratch")
    _scan_function(fn, env, roles, {}, facts, 0)
    return facts


# ---------------------------------------------------------------------------
# 3-5. the checks
# ---------------------------------------------------------------------------
def _where(cap: PallasCapture) -> str:
    return f"{cap.label}/{cap.kernel}"


def check_capture_semantics(cap: PallasCapture) -> List[Violation]:
    out: List[Violation] = []
    naxes = len(cap.grid)
    revisit = output_revisit_axes(cap)
    facts = kernel_body_facts(cap)
    gate_axes = {g.axis for g in facts.gates if 0 <= g.axis < naxes}
    required = revisit | gate_axes

    def _why(a: int) -> str:
        bits = []
        if a in revisit:
            bits.append("an output block is written on multiple steps")
        if a in gate_axes:
            bits.append("program_id-gated accumulator state crosses steps")
        return " and ".join(bits)

    ds = cap.dimension_semantics
    if ds is None:
        out.append(Violation(
            "grid-semantics", _where(cap),
            f"pallas_call declares no dimension_semantics for grid "
            f"{cap.grid}; required: "
            f"{tuple('arbitrary' if a in required else 'parallel' for a in range(naxes))} "
            f"(declare via compiler_params=pltpu.TPUCompilerParams(...))"))
    elif len(ds) != naxes:
        out.append(Violation(
            "grid-semantics", _where(cap),
            f"dimension_semantics {ds} has {len(ds)} entries for a "
            f"{naxes}-axis grid {cap.grid}"))
    else:
        for a, sem in enumerate(ds):
            if sem not in VALID_SEMANTICS:
                out.append(Violation(
                    "grid-semantics", _where(cap),
                    f"axis {a}: unknown semantics {sem!r} "
                    f"(expected one of {VALID_SEMANTICS})"))
            elif a in required and sem != "arbitrary":
                out.append(Violation(
                    "grid-semantics", _where(cap),
                    f"axis {a} (size {cap.grid[a]}) declared "
                    f"{sem!r} but {_why(a)} — re-ordering this axis is a "
                    f"data race; declare it \"arbitrary\""))
            elif (a not in required and sem == "arbitrary"
                  and cap.grid[a] > 1 and facts.src_ok):
                out.append(Violation(
                    "grid-semantics", _where(cap),
                    f"axis {a} (size {cap.grid[a]}) declared \"arbitrary\" "
                    f"but no output revisit or accumulator gate depends on "
                    f"it — declare it \"parallel\" (free grid parallelism)"))

    # 4. init/flush ordering hazards
    for g in facts.gates:
        if not (g.is_eq and g.value is not None and 0 <= g.axis < naxes):
            continue
        last = cap.grid[g.axis] - 1
        if last <= 0:
            continue
        if "output" in g.writes:
            if g.value != last:
                out.append(Violation(
                    "grid-semantics", _where(cap),
                    f"axis {g.axis}: output flush gated on step {g.value} "
                    f"of {cap.grid[g.axis]} — results leave before the "
                    f"last accumulation step ({last})"))
        elif "scratch" in g.writes:
            if g.value != 0:
                out.append(Violation(
                    "grid-semantics", _where(cap),
                    f"axis {g.axis}: accumulator init gated on step "
                    f"{g.value} != 0 — earlier steps accumulate into "
                    f"uninitialised scratch"))
        elif g.value not in (0, last):
            out.append(Violation(
                "grid-semantics", _where(cap),
                f"axis {g.axis}: program_id equality gate on interior "
                f"step {g.value} (grid size {cap.grid[g.axis]}) — neither "
                f"the init (0) nor the flush ({last}) step"))

    # 5. unaliased in-place outputs
    if facts.output_reads:
        aliased_outputs = {dst for _, dst in cap.input_output_aliases}
        if len(aliased_outputs) < len(cap.outputs):
            out.append(Violation(
                "grid-semantics", _where(cap),
                f"kernel reads output ref(s) {sorted(facts.output_reads)} "
                f"in-place without input_output_aliases — the first visit "
                f"of a block reads uninitialised VMEM; alias an input over "
                f"the output or accumulate in scratch"))
    return out


def check_captures_semantics(
        caps: Sequence[PallasCapture]) -> List[Violation]:
    out: List[Violation] = []
    for cap in caps:
        out.extend(check_capture_semantics(cap))
    return out


@register_rule(
    "grid-semantics",
    "Pallas dimension_semantics race checker: accumulator axes declared "
    "\"arbitrary\", independent axes \"parallel\", init/flush ordering "
    "and output aliasing over the kernel_bench + DeiT sweep")
def run(root: Path) -> List[Violation]:
    caps = sweep_captures()
    out = check_captures_semantics(caps)
    if not caps:
        out.append(Violation("grid-semantics", "sweep",
                             "sweep captured no pallas_calls — the "
                             "recorder or the kernels moved"))
    return out
