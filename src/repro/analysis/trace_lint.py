"""Trace invariant checker: declarative allow/deny lists over jaxprs
(DESIGN.md §13).

Each target traces a datapath entry point (a backend op, the kernel-mode
DeiT forward, the kernel-mode decode step) with ``jax.make_jaxpr`` and
walks the jaxpr RECURSIVELY through ``pjit``/``scan``/``cond`` bodies —
but never into a ``pallas_call`` body: primitives inside the kernel are
the datapath working as designed; the same primitive OUTSIDE one is the
XLA float path leaking back in.  This generalises PR 3's hand-rolled
"no ``L.softmax`` in the kernel-mode decode trace" spy
(tests/test_kernel_mode.py), which is now written on top of this pass.

Per-target :class:`TraceRules`:

* ``deny_outside_pallas`` — ``{primitive: min_operand_ndim}``.  The rank
  floor exists because ``jax.make_jaxpr`` stages primitives even on
  concrete constants: RoPE's frequency ladder is a legitimate rank-1
  ``exp`` in every mode, while a score-tensor ``exp`` is always rank >= 2.
* ``forbid_softmax_chain`` — the structural form of "no float softmax":
  an ``exp`` fed (within a few hops) by a ``reduce_max`` subtraction
  whose result feeds a ``reduce_sum`` is a softmax whatever name it was
  called by.
* ``forbid_f64`` — no float64/complex128 aval anywhere (the MXInt
  datapath is f32-and-narrower by construction).
* ``forbid_pallas`` — XLA-only backends (off/fake/sim/packed) must not
  lower kernels.
* ``pallas_budget`` — ``(lo, hi)`` bounds on the number of
  ``pallas_call`` eqns.  DeiT's transformer blocks run under
  ``lax.scan``, so the count is per-BLOCK by construction and pins the
  kernel-fusion structure (3 fused LN->qkv, softmax, wo, fused LN->wi,
  gelu, wo2).
* ``allowed_dtypes`` — closed dtype universe for the trace; any aval
  outside it is an unexpected promotion.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable, Dict, FrozenSet, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.registry import Violation, register_rule

_F64 = ("float64", "complex128")

# backward producers a softmax `exp` input may route through before the
# reduce_max that stabilises it
_CHAIN_THROUGH = frozenset({
    "sub", "add", "mul", "div", "max", "min", "convert_element_type",
    "broadcast_in_dim", "select_n", "stop_gradient", "reshape",
    "transpose", "neg"})


@dataclasses.dataclass(frozen=True)
class TraceRules:
    deny_outside_pallas: Tuple[Tuple[str, int], ...] = ()
    forbid_softmax_chain: bool = False
    forbid_f64: bool = True
    forbid_pallas: bool = False
    pallas_budget: Optional[Tuple[int, int]] = None
    allowed_dtypes: Optional[FrozenSet[str]] = None


# kernel-mode nonlinear rules: the Eq. 14-20 softmax, the LUT gelu and
# the LN rsqrt must all be inside pallas_call; erf/logistic have no
# business in ANY kernel-mode trace, exp only below rank 2 (RoPE ladder)
KERNEL_NL_DENY = (("exp", 2), ("erf", 0), ("erf_inv", 0), ("logistic", 0))


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        vs = list(v) if isinstance(v, (list, tuple)) else [v]
        for j in vs:
            if hasattr(j, "jaxpr"):        # ClosedJaxpr
                yield j.jaxpr
            elif hasattr(j, "eqns"):       # raw Jaxpr
                yield j


def iter_jaxprs(jaxpr, into_pallas: bool = False):
    """Yield ``jaxpr`` and every reachable sub-jaxpr scope, skipping
    pallas_call bodies unless asked."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            continue
        for sub in _sub_jaxprs(eqn):
            yield from iter_jaxprs(sub, into_pallas)


def iter_eqns(jaxpr, into_pallas: bool = False):
    for scope in iter_jaxprs(jaxpr, into_pallas):
        for eqn in scope.eqns:
            yield eqn


def _max_operand_ndim(eqn) -> int:
    nd = 0
    for v in eqn.invars:
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "ndim"):
            nd = max(nd, aval.ndim)
    return nd


def _is_var(v) -> bool:
    # jaxpr operands are Vars or (unhashable) Literals
    return type(v).__name__ != "Literal"


def _softmax_chains(scope) -> List[str]:
    """Structural softmax finder within one jaxpr scope (no cross-scope
    dataflow: jax.nn.softmax and hand-rolled variants inline into one)."""
    producer = {}
    for eqn in scope.eqns:
        for ov in eqn.outvars:
            if _is_var(ov):
                producer[ov] = eqn
    consumers: Dict[object, List] = {}
    for eqn in scope.eqns:
        for iv in eqn.invars:
            if _is_var(iv):
                consumers.setdefault(iv, []).append(eqn)
    found = []
    for eqn in scope.eqns:
        if eqn.primitive.name != "exp":
            continue
        # backward: reduce_max within a few producer hops?
        saw_max = False
        frontier = list(eqn.invars)
        for _ in range(4):
            nxt = []
            for v in frontier:
                if not _is_var(v):
                    continue
                p = producer.get(v)
                if p is None:
                    continue
                if p.primitive.name == "reduce_max":
                    saw_max = True
                elif p.primitive.name in _CHAIN_THROUGH:
                    nxt.extend(p.invars)
            frontier = nxt
            if saw_max or not frontier:
                break
        if not saw_max:
            continue
        # forward: does the exp feed a reduce_sum (normaliser)?
        frontier = list(eqn.outvars)
        for _ in range(4):
            nxt = []
            for v in frontier:
                for c in consumers.get(v, ()):
                    if c.primitive.name == "reduce_sum":
                        found.append(
                            "exp(x - max) ... reduce_sum: float softmax "
                            "shape outside pallas_call")
                        frontier = []
                        nxt = []
                        break
                    if c.primitive.name in _CHAIN_THROUGH:
                        nxt.extend(c.outvars)
                else:
                    continue
                break
            if not nxt:
                break
            frontier = nxt
    return found


def lint_jaxpr(closed_jaxpr, rules: TraceRules, label: str) -> List[Violation]:
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    out: List[Violation] = []
    deny = dict(rules.deny_outside_pallas)
    n_pallas = 0
    seen_denied = set()
    bad_dtypes = set()
    saw_f64 = False
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "pallas_call":
            n_pallas += 1
        if name in deny and _max_operand_ndim(eqn) >= deny[name]:
            key = (name, _max_operand_ndim(eqn))
            if key not in seen_denied:
                seen_denied.add(key)
                out.append(Violation(
                    "trace-invariants", label,
                    f"denied primitive '{name}' (operand rank "
                    f"{_max_operand_ndim(eqn)}) outside pallas_call"))
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is None:
                continue
            if rules.forbid_f64 and str(dt) in _F64 and not saw_f64:
                saw_f64 = True
                out.append(Violation(
                    "trace-invariants", label,
                    f"f64 leak: {name} touches a {dt} value"))
            if (rules.allowed_dtypes is not None
                    and str(dt) not in rules.allowed_dtypes
                    and str(dt) not in bad_dtypes):
                bad_dtypes.add(str(dt))
                out.append(Violation(
                    "trace-invariants", label,
                    f"unexpected dtype promotion: {name} touches {dt} "
                    f"(allowed: {sorted(rules.allowed_dtypes)})"))
    if rules.forbid_softmax_chain:
        for scope in iter_jaxprs(jaxpr):
            for msg in _softmax_chains(scope):
                out.append(Violation("trace-invariants", label, msg))
    if rules.forbid_pallas and n_pallas:
        out.append(Violation(
            "trace-invariants", label,
            f"{n_pallas} pallas_call(s) in an XLA-only backend trace"))
    if rules.pallas_budget is not None:
        lo, hi = rules.pallas_budget
        if not (lo <= n_pallas <= hi):
            out.append(Violation(
                "trace-invariants", label,
                f"pallas_call count {n_pallas} outside budget "
                f"[{lo}, {hi}] — a kernel was dropped from or duplicated "
                f"in the fused structure"))
    return out


def lint_fn(fn, args, rules: TraceRules, label: str) -> List[Violation]:
    return lint_jaxpr(jax.make_jaxpr(fn)(*args), rules, label)


# ---------------------------------------------------------------------------
# built-in targets
# ---------------------------------------------------------------------------
# DeiT-Micro kernel mode, 1 layer (blocks run under lax.scan, so the
# pallas budget counts per BLOCK): patch linear, 3 fused LN->qkv
# projections, whole-row softmax, wo, fused LN->wi, gelu, wo2, final LN,
# classifier head = 11.
_DEIT_PALLAS_BUDGET = (11, 11)
_DEIT_DTYPES = frozenset({"bool", "float32", "int32", "int8"})


def _deit_kernel_target() -> List[Violation]:
    import dataclasses as dc

    from repro.configs.deit import DEIT_MICRO
    from repro.core.mx_types import QuantConfig
    from repro.models import build_model
    from repro.serving.engine import pack_params_mxint

    kq = QuantConfig(mode="kernel", quantize_nonlinear=True)
    cfg = dc.replace(DEIT_MICRO, n_layers=1, n_classes=10, quant=kq)
    sim_cfg = dc.replace(cfg, quant=QuantConfig(mode="sim",
                                                quantize_nonlinear=True))
    params = build_model(sim_cfg).init(jax.random.key(0))
    packed = pack_params_mxint(params, kq.weight_fmt)
    m = build_model(cfg)
    imgs = jnp.zeros((1, cfg.image_size, cfg.image_size, 3), jnp.float32)
    rules = TraceRules(deny_outside_pallas=KERNEL_NL_DENY,
                       forbid_softmax_chain=True,
                       pallas_budget=_DEIT_PALLAS_BUDGET,
                       allowed_dtypes=_DEIT_DTYPES)
    return lint_fn(lambda p, im: m.logits(p, im), (packed, imgs), rules,
                   "deit-micro-forward[kernel]")


def _decode_kernel_target() -> List[Violation]:
    from repro.core.mx_types import QuantConfig
    from repro.models import attention as A
    from repro.models.model_api import ModelConfig

    kq = QuantConfig(mode="kernel", quantize_nonlinear=True)
    cfg = ModelConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=100, ffn_kind="gelu",
                      dtype=jnp.float32)
    p = A.init_attn_params(jax.random.key(0), cfg, jnp.float32)
    x = jnp.zeros((2, 1, 64), jnp.float32)
    cache = A.init_kv_cache(cfg, 2, 32, 0, jnp.float32)
    # q/k/v projections + fused decode kernel + wo = 5 pallas calls; the
    # old XLA scoring path would re-introduce a float softmax chain.
    # Heterogeneous PER-ROW indices (ISSUE 7): the (b, W) ring validity
    # must not change the lowered kernel structure.
    rules = TraceRules(deny_outside_pallas=KERNEL_NL_DENY,
                       forbid_softmax_chain=True, pallas_budget=(5, 5))
    return lint_fn(
        lambda xv, c: A.attention(p, xv, cfg, quant=kq, cache=c,
                                  cache_index=jnp.asarray([7, 4],
                                                          jnp.int32))[0],
        (x, cache), rules, "decode-step[kernel]")


def _slot_step_kernel_target() -> List[Violation]:
    """The slot-level scheduler's MIXED step (ISSUE 7): one batch-1 slot
    prefill scattered into the live cache + one full-batch decode.  The
    pallas budget pins the fused structure of BOTH phases — 17 kernels
    total: 8 from prefill + 9 from decode (q/k/v projections + the
    fused decode-ring kernel + the FFN/norm set).  A count drift here
    means a kernel was dropped from (or duplicated in) either phase —
    e.g. per-slot cache scatter accidentally re-lowering the whole
    prefill per row.  Budget ONLY, no nonlinear deny rules: cache
    prefill deliberately scores through the XLA q-chunked online
    softmax (``models/attention.py:_q_chunked_attention`` — the §Perf
    llama3-prefill structure), so a float exp in the prefill phase is
    by design; the no-float-softmax contract for the decode phase is
    pinned by ``_decode_kernel_target`` above."""
    from repro.core.mx_types import QuantConfig
    from repro.models.model_api import ModelConfig
    from repro.models.transformer import DecoderLM
    from repro.serving.engine import (make_decode_step,
                                      make_slot_prefill_step,
                                      pack_params_mxint)

    kq = QuantConfig(mode="kernel", quantize_nonlinear=True)
    cfg = ModelConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=100, ffn_kind="gelu",
                      dtype=jnp.float32, quant=kq)
    model = DecoderLM(cfg)
    packed = pack_params_mxint(model.init(jax.random.key(0)),
                               kq.weight_fmt)
    slot_prefill = make_slot_prefill_step(model, 32)
    decode = make_decode_step(model)
    cache = model.cache_init(2, 32)
    tokens = jnp.zeros((1, 8), jnp.int32)
    tok = jnp.zeros((2, 1), jnp.int32)

    def mixed(tokens, cache, tok):
        _, cache = slot_prefill(packed, tokens, jnp.int32(5),
                                jnp.int32(1), cache)
        return decode(packed, tok, cache)

    rules = TraceRules(pallas_budget=(17, 17))
    return lint_fn(mixed, (tokens, cache, tok), rules,
                   "slot-prefill+decode-step[kernel]")


def _backend_op_targets() -> List[Violation]:
    """Trace softmax/gelu/layernorm through every registered backend.

    XLA backends must never lower a pallas_call; the kernel backend must
    lower exactly one per op and keep the float nonlinear primitives out
    of the surrounding trace."""
    from repro.core.mx_types import QuantConfig
    from repro.datapath import backends
    from repro.models.model_api import Param

    out: List[Violation] = []
    x = jnp.zeros((32, 64), jnp.float32)
    gamma = Param(value=jnp.ones((64,), jnp.float32), axes=(None,))
    beta = Param(value=jnp.zeros((64,), jnp.float32), axes=(None,))
    for mode in sorted(backends()):
        q = QuantConfig(mode=mode, quantize_nonlinear=True)
        dp = q.datapath
        if mode == "kernel":
            rules = TraceRules(deny_outside_pallas=KERNEL_NL_DENY,
                               forbid_softmax_chain=True,
                               pallas_budget=(1, 1))
        else:
            rules = TraceRules(forbid_pallas=True)
        ops = {
            "softmax": (lambda v, dp=dp, q=q: dp.softmax(v, q=q), (x,)),
            "gelu": (lambda v, dp=dp, q=q: dp.act(v, "gelu", q=q), (x,)),
            "layernorm": (lambda v, dp=dp, q=q: dp.layernorm(
                v, gamma, beta, q=q), (x,)),
        }
        for op, (fn, args) in ops.items():
            out.extend(lint_fn(fn, args, rules, f"{op}[{mode}]"))
    return out


TARGETS: Tuple[Callable[[], List[Violation]], ...] = (
    _deit_kernel_target, _decode_kernel_target, _slot_step_kernel_target,
    _backend_op_targets)


@register_rule(
    "trace-invariants",
    "jaxpr allow/deny lists per datapath mode (no float softmax/f64 "
    "outside pallas_call in kernel mode, no pallas_call in XLA modes, "
    "per-block pallas budgets)")
def run(root: Path) -> List[Violation]:
    out: List[Violation] = []
    for target in TARGETS:
        out.extend(target())
    return out
