"""Rule registry for the static-analysis passes (DESIGN.md §13).

Every pass registers one or more named :class:`Rule` objects; the unified
runner (``tools/repro_lint.py``) and the tier-1 self-tests
(``tests/test_analysis.py``) iterate the registry rather than hard-coding
pass lists, so a new invariant is one ``@register_rule`` away from CI.

Severity: ``error`` violations fail the run; ``warn`` violations are
printed but do not affect the exit code (used for contracts we believe in
but cannot validate off-hardware, e.g. narrow-dtype native sublane tiling
— see kernel_contracts).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

ERROR = "error"
WARN = "warn"


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding.  ``where`` is ``path:line`` for source rules and a
    target/kernel label for the abstract-eval passes."""

    rule: str
    where: str
    message: str
    severity: str = ERROR

    def __str__(self) -> str:  # the runner's one-line report format
        return f"[{self.rule}] {self.where}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered analysis pass entry.

    ``run(root)`` receives the repo root and returns the violations it
    found (empty list == clean).  Rules must be side-effect free and
    runnable in any order.
    """

    name: str
    description: str
    run: Callable[[Path], List[Violation]]


_RULES: "Dict[str, Rule]" = {}


def register_rule(name: str, description: str):
    """Decorator: register ``fn(root) -> list[Violation]`` under ``name``."""

    def deco(fn):
        if name in _RULES:
            raise ValueError(f"duplicate analysis rule {name!r}")
        _RULES[name] = Rule(name=name, description=description, run=fn)
        return fn

    return deco


def rules() -> Tuple[Rule, ...]:
    return tuple(_RULES.values())


def get_rule(name: str) -> Rule:
    return _RULES[name]


def run_rules(root: Path, only: Optional[List[str]] = None,
              skip: Tuple[str, ...] = ()) -> List[Violation]:
    """Run the selected rules over ``root`` and pool their violations."""
    out: List[Violation] = []
    for rule in rules():
        if only is not None and rule.name not in only:
            continue
        if rule.name in skip:
            continue
        out.extend(rule.run(Path(root)))
    return out
