"""AST source rules: repo-specific lint the generic linters can't know
(DESIGN.md §13).

Three rules, all suppressible per line with::

    # repro-lint: allow[rule-name] <reason>

on the offending line or the line directly above it (the reason is
mandatory by convention — a suppression without one fails review, not
this tool).

``neg-inf-literal``
    The masking sentinel has exactly one definition
    (``repro.core.mx_types.NEG_INF``); any other ``-2.0e38`` float
    literal is a fork of the padding contract that the Eq. 2-3 score
    quantisation depends on bit-for-bit.

``models-float-nonlinear``
    ``models/`` must route exp/softmax/gelu/silu through the datapath
    seam (``L.softmax``, ``dp.act``, ``dp.exp``) so every backend keeps
    its numerics pluggable.  Documented float-by-design sites:
    the chunked attention cores in ``models/attention.py`` (the XLA
    backends' own execution bodies, dispatched *to* by the seam) and
    ``models/recurrent.py`` (float gate/decay algebra is those archs'
    spec; their quantised seam is the single ``datapath.exp`` gate).

``interpret-literal``
    ``interpret=True`` hardcoded at a call site inside ``src/`` pins a
    kernel to interpret mode in library code; the backend gate
    (``ops._interpret()``) is the only switch.  Tests and benchmarks may
    pin it freely.

``no-adhoc-timing``
    ``time.time()``/``time.perf_counter()``/``time.monotonic()`` inside
    ``src/`` bypasses ``repro.telemetry`` — durations belong in
    ``telemetry.span`` histograms and timestamps in
    ``telemetry.walltime()`` so every clock read lands in the one
    metrics snapshot (DESIGN.md §15).  ``repro/telemetry/`` itself is
    the sanctioned implementation site; tests, benchmarks, examples and
    tools time freely.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

from repro.analysis.registry import Violation, register_rule
from repro.core.mx_types import NEG_INF as _NEG_INF_SENTINEL

SUPPRESS_TOKEN = "repro-lint: allow["

NEG_INF_VALUE = abs(_NEG_INF_SENTINEL)   # compare against the real sentinel
# the single definition site
NEG_INF_HOME = "src/repro/core/mx_types.py"

FLOAT_NONLINEAR_CALLS = {
    "jnp.exp", "jax.numpy.exp",
    "jax.nn.softmax", "jax.nn.gelu", "jax.nn.silu",
}
# (path suffix, enclosing function or None=whole file) allowed to spell
# float nonlinears: the dispatched-to execution bodies themselves
FLOAT_NONLINEAR_ALLOWED: Tuple[Tuple[str, Optional[str]], ...] = (
    ("repro/models/attention.py", "_q_chunked_attention"),
    ("repro/models/attention.py", "_chunked_attention"),
    ("repro/models/recurrent.py", None),
)

SCAN_DIRS = ("src", "benchmarks", "examples", "tests", "tools")
MODELS_PREFIX = "src/repro/models/"
INTERPRET_SCAN_PREFIX = "src/"
# the contract sweep mirrors wrapper kernel configs under abstract eval
# (pallas_call is swapped for a recorder; the flag never executes)
INTERPRET_EXEMPT_PREFIX = "src/repro/analysis/"

ADHOC_TIMING_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic",
    "perf_counter", "monotonic",     # from-imported forms
}
TIMING_SCAN_PREFIX = "src/"
TIMING_HOME_PREFIX = "src/repro/telemetry/"   # the implementation itself


def _suppressed(lines: Sequence[str], lineno: int, rule: str) -> bool:
    token = f"{SUPPRESS_TOKEN}{rule}]"
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and token in lines[ln - 1]:
            return True
    return False


def _dotted(node: ast.AST) -> Optional[str]:
    """'jnp.exp'-style dotted name of a call target, if it is one."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str, lines: Sequence[str]):
        self.relpath = relpath
        self.lines = lines
        self.violations: List[Violation] = []
        self._func_stack: List[str] = []

    # -- helpers ------------------------------------------------------------
    def _flag(self, rule: str, node: ast.AST, message: str):
        if not _suppressed(self.lines, node.lineno, rule):
            self.violations.append(Violation(
                rule, f"{self.relpath}:{node.lineno}", message))

    def _in_allowed_float_site(self) -> bool:
        for suffix, func in FLOAT_NONLINEAR_ALLOWED:
            if not self.relpath.endswith(suffix):
                continue
            if func is None or func in self._func_stack:
                return True
        return False

    # -- visitors -----------------------------------------------------------
    def visit_FunctionDef(self, node):
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Constant(self, node):
        if (isinstance(node.value, float)
                and abs(node.value) == NEG_INF_VALUE
                and not self.relpath.endswith(NEG_INF_HOME)):
            self._flag(
                "neg-inf-literal", node,
                "raw -2.0e38 masking literal; import NEG_INF from "
                "repro.core (single sentinel, DESIGN.md §13)")
        self.generic_visit(node)

    def visit_Call(self, node):
        name = _dotted(node.func)
        if (name in FLOAT_NONLINEAR_CALLS
                and self.relpath.startswith(MODELS_PREFIX)
                and not self._in_allowed_float_site()):
            self._flag(
                "models-float-nonlinear", node,
                f"bare {name} in models/ bypasses the datapath seam; "
                f"route through L.*/q.datapath (DESIGN.md §12)")
        if (self.relpath.startswith(INTERPRET_SCAN_PREFIX)
                and not self.relpath.startswith(INTERPRET_EXEMPT_PREFIX)):
            for kw in node.keywords:
                if (kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    self._flag(
                        "interpret-literal", kw.value,
                        "interpret=True hardcoded in library code; gate "
                        "on ops._interpret() so TPU runs compile")
        if (name in ADHOC_TIMING_CALLS
                and self.relpath.startswith(TIMING_SCAN_PREFIX)
                and not self.relpath.startswith(TIMING_HOME_PREFIX)):
            self._flag(
                "no-adhoc-timing", node,
                f"ad-hoc {name}() in src/; durations go through "
                f"telemetry.span, timestamps through telemetry.walltime "
                f"(DESIGN.md §15)")
        self.generic_visit(node)


def check_source(text: str, relpath: str) -> List[Violation]:
    """Run the AST rules over one file's source.  ``relpath`` is the
    repo-relative posix path — rule scoping keys off it."""
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Violation("source-rules", f"{relpath}:{e.lineno or 0}",
                          f"unparseable: {e.msg}")]
    v = _Visitor(relpath, text.splitlines())
    v.visit(tree)
    return v.violations


@register_rule(
    "source-rules",
    "AST rules: single NEG_INF sentinel, no bare float nonlinears in "
    "models/, no interpret=True literals in src/, no ad-hoc timing "
    "outside repro/telemetry/")
def run(root: Path) -> List[Violation]:
    out: List[Violation] = []
    for d in SCAN_DIRS:
        base = root / d
        if not base.exists():
            continue
        for py in sorted(base.rglob("*.py")):
            if "__pycache__" in py.parts:
                continue
            rel = py.relative_to(root).as_posix()
            out.extend(check_source(py.read_text(), rel))
    return out
