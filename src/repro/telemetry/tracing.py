"""Wall-clock spans over the metrics registry (DESIGN.md §15).

``span(name, **attrs)`` is a nestable context manager:

* on exit it records the elapsed wall-clock into the histogram
  ``span/<name>/ms`` (and each numeric ``attr`` into
  ``span/<name>/<attr>`` with size buckets) in the target registry;
* while open it forwards to ``jax.profiler.TraceAnnotation`` when jax
  is importable, so host spans line up with device traces in a profiler
  UI — telemetry itself stays dependency-free;
* nesting is tracked per thread (``current_span()``), and the elapsed
  time is exposed as ``.elapsed_s``/``.elapsed_ms`` after exit, so
  callers that used to keep their own ``t0 = time.perf_counter()``
  bookkeeping read the span instead.

This module is the ONLY place in ``src/`` allowed to call
``time.time()``/``time.perf_counter()`` — the ``no-adhoc-timing``
lint rule (DESIGN.md §13) fails anything else.  For plain wall-clock
*timestamps* (heartbeats, checkpoint metadata) use :func:`walltime`.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from repro.telemetry import metrics
from repro.telemetry.metrics import (DEFAULT_MS_BUCKETS,
                                     DEFAULT_SIZE_BUCKETS, Registry)

_local = threading.local()

_TRACE_ANNOTATION = None
_TRACE_TRIED = False


def _trace_annotation_cls():
    """jax.profiler.TraceAnnotation, resolved once, None without jax."""
    global _TRACE_ANNOTATION, _TRACE_TRIED
    if not _TRACE_TRIED:
        _TRACE_TRIED = True
        try:
            from jax.profiler import TraceAnnotation
            _TRACE_ANNOTATION = TraceAnnotation
        except Exception:          # pragma: no cover - no-jax environments
            _TRACE_ANNOTATION = None
    return _TRACE_ANNOTATION


def walltime() -> float:
    """Epoch-seconds timestamp (the sanctioned ``time.time()``).

    For *metadata* — heartbeat files, checkpoint manifests, request
    submit stamps.  Durations go through :class:`span`, never through
    subtracting two ``walltime()`` calls."""
    return time.time()


class span:
    """``with span("serving/classify", images=n): ...``

    Records ``span/serving/classify/ms`` (latency histogram) and
    ``span/serving/classify/images`` (size histogram) on exit.  Attrs
    must be host scalars — jax tracers raise (the registry's jit-safety
    contract, DESIGN.md §15).
    """

    __slots__ = ("name", "attrs", "registry", "elapsed_s", "_t0", "_ta")

    def __init__(self, name: str, registry: Optional[Registry] = None,
                 **attrs):
        self.name = name
        self.attrs = attrs
        self.registry = registry or metrics.default_registry()
        self.elapsed_s: Optional[float] = None
        self._t0 = None
        self._ta = None

    @property
    def elapsed_ms(self) -> Optional[float]:
        return None if self.elapsed_s is None else self.elapsed_s * 1e3

    def __enter__(self) -> "span":
        stack = getattr(_local, "stack", None)
        if stack is None:
            stack = _local.stack = []
        stack.append(self)
        cls = _trace_annotation_cls()
        if cls is not None:
            try:
                self._ta = cls(self.name)
                self._ta.__enter__()
            except Exception:      # profiler unavailable mid-run: fine
                self._ta = None
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.elapsed_s = time.perf_counter() - self._t0
        if self._ta is not None:
            try:
                self._ta.__exit__(exc_type, exc, tb)
            except Exception:
                pass
        _local.stack.pop()
        reg = self.registry
        reg.histogram(f"span/{self.name}/ms",
                      DEFAULT_MS_BUCKETS).record(self.elapsed_ms)
        for key, val in self.attrs.items():
            reg.histogram(f"span/{self.name}/{key}",
                          DEFAULT_SIZE_BUCKETS).record(val)
        return False


def current_span() -> Optional[span]:
    """Innermost open span on this thread, or None."""
    stack = getattr(_local, "stack", None)
    return stack[-1] if stack else None


def span_stats(name: str, registry: Optional[Registry] = None):
    """(count, mean_ms) of a recorded span — the one-line read most
    report dicts need after replacing hand-rolled perf_counter math."""
    reg = registry or metrics.default_registry()
    h = reg.histogram(f"span/{name}/ms", DEFAULT_MS_BUCKETS)
    return h.count, h.mean
