"""Runnable kernel probes keyed by static cost-model row label
(DESIGN.md §15).

Each probe executes the SAME kernel configuration the analysis sweep
captures under that label (``repro.analysis.kernel_contracts``) — but
for real, on concrete arrays, with the wall-clock recorded as a
``span/kernel:<label>/ms`` histogram.  That shared label is the join
key :func:`repro.telemetry.export.predicted_vs_measured` uses, so a
probe drifting from its sweep twin shows up as an ``unmatched`` row in
the report rather than a silently wrong join.

This is the one telemetry module that imports the kernel stack — and
only inside the probe bodies, keeping ``metrics``/``tracing``/``export``
importable without jax.  On CPU the kernels run in Pallas interpret
mode (the ``ops._interpret()`` gate), so probe wall-clocks there
measure the interpreter, not the datapath — the predicted-vs-measured
fractions only mean something on compiled hardware, but the plumbing
(spans, join, report) is identical.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.telemetry import metrics
from repro.telemetry.tracing import span


def _rng(seed: int = 0):
    import numpy as np
    return np.random.default_rng(seed)


def _probe_matmul_deit() -> Callable[[], object]:
    """DeiT-Tiny model-path linear: 2x197 tokens padded to 400 rows,
    d=192, OCP-32 weight blocks, lanes padded to 256 — the shape
    ``ops.mxint_linear`` launches for the qkv/proj/FFN projections
    (sweep twin: ``matmul-deit``)."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.mxint_matmul import mxint_matmul

    rng = _rng(0)
    x = jnp.asarray(rng.normal(size=(400, 192)), jnp.float32)
    mant = jnp.asarray(rng.integers(-127, 128, (192, 256)), jnp.int8)
    exp = jnp.asarray(rng.integers(-8, 2, (6, 256)), jnp.int8)
    interp = ops._interpret()
    return lambda: mxint_matmul(
        x, mant, exp, w_block=32, act_block=16, act_mant_bits=8,
        quantize_act=True, bm=16, bn=128, bk=192, interpret=interp,
        out_dtype=jnp.float32)


def _probe_flash_deit() -> Callable[[], object]:
    """DeiT padded attention: (b*h=6, 197->200, 64->128), kv padded to
    256 with the kv_len mask (sweep twin: ``flash-deit``)."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.flash_attention import flash_attention

    rng = _rng(1)
    q, k, v = (jnp.asarray(rng.normal(size=(6, s, 128)) * 0.1,
                           jnp.float32) for s in (200, 256, 256))
    interp = ops._interpret()
    return lambda: flash_attention(
        q, k, v, causal=False, block_q=8, block_k=128, kv_len=197,
        interpret=interp)


def _probe_matmul_bench() -> Callable[[], object]:
    """kernel_bench matmul shape: 128x1024 @ 1024x512, paper W-block 256
    (sweep twin: ``matmul-bench``)."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.mxint_matmul import mxint_matmul

    rng = _rng(2)
    x = jnp.asarray(rng.normal(size=(128, 1024)), jnp.float32)
    mant = jnp.asarray(rng.integers(-127, 128, (1024, 512)), jnp.int8)
    exp = jnp.asarray(rng.integers(-8, 2, (4, 512)), jnp.int8)
    interp = ops._interpret()
    return lambda: mxint_matmul(
        x, mant, exp, w_block=256, act_block=16, act_mant_bits=8,
        quantize_act=True, bm=128, bn=128, bk=256, interpret=interp,
        out_dtype=jnp.float32)


def _probe_ln_matmul_bench() -> Callable[[], object]:
    """Fused LN->linear bench shape: 256x768 @ 768x768, OCP-32 (sweep
    twin: ``ln-matmul-bench``)."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.mxint_ln_matmul import mxint_ln_matmul

    rng = _rng(3)
    x = jnp.asarray(rng.normal(size=(256, 768)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(768,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(768,)), jnp.float32)
    mant = jnp.asarray(rng.integers(-127, 128, (768, 768)), jnp.int8)
    exp = jnp.asarray(rng.integers(-8, 2, (24, 768)), jnp.int8)
    interp = ops._interpret()
    return lambda: mxint_ln_matmul(
        x, g, b, mant, exp, w_block=32, act_block=16, mant_bits=8,
        lut_bits=5, bm=128, bn=128, interpret=interp)


PROBES: Dict[str, Callable[[], Callable[[], object]]] = {
    "matmul-deit": _probe_matmul_deit,
    "flash-deit": _probe_flash_deit,
    "matmul-bench": _probe_matmul_bench,
    "ln-matmul-bench": _probe_ln_matmul_bench,
}

# the default pair: the paper's DeiT deployment kernels (matmul + flash
# attention), the acceptance join of ISSUE 9
DEFAULT_PROBES: Tuple[str, ...] = ("matmul-deit", "flash-deit")


def run_probes(labels: Sequence[str] = DEFAULT_PROBES, repeats: int = 2,
               registry: Optional[metrics.Registry] = None) -> dict:
    """Build, warm (compile), then time each probe ``repeats`` times
    under a ``kernel:<label>`` span.  Returns ``{label: mean_ms}``."""
    import jax

    out = {}
    for label in labels:
        fn = PROBES[label]()
        jax.block_until_ready(fn())          # compile / first-call cost
        for _ in range(repeats):
            with span(f"kernel:{label}", registry=registry):
                jax.block_until_ready(fn())
        reg = registry or metrics.default_registry()
        out[label] = reg.histogram(f"span/kernel:{label}/ms").mean
    return out
