"""Process-local metrics registry: counters, gauges, histograms
(DESIGN.md §15).

The runtime half of the repo's observability story — the static
cost-model table (DESIGN.md §14) predicts what the datapath *should*
cost; these metrics record what the serving stack *actually* did, and
``repro.telemetry.export.predicted_vs_measured`` joins the two.

Design constraints:

* **Dependency-free** — stdlib only.  ``tracing``/``export``/``probes``
  layer jax forwarding and kernel probes on top; this module must import
  in any process.
* **jit-safe by construction** — every recording method coerces its
  argument with ``float()``/``int()`` on the HOST.  A jax tracer cannot
  be coerced (it raises), so recording *inside* traced code fails loudly
  instead of burning a recompile or silently baking a constant.  Record
  only at trace boundaries: request admission, step edges, after
  ``block_until_ready``.
* **Thread-safe** — one registry lock serializes every mutation, so
  host-side serving threads can share the default registry
  (tests/test_telemetry.py hammers this).

The module-level default registry is what the serving stack and the
``repro.telemetry`` convenience functions use; construct a private
``Registry`` for isolation (tests, side-by-side experiments).
``Registry.reset()`` *removes* metrics — re-fetch handles through
``counter()``/``gauge()``/``histogram()`` rather than caching them
across resets.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Optional, Sequence, Tuple

# span wall-clocks in milliseconds: sub-0.1ms host noise up to 30s jobs
DEFAULT_MS_BUCKETS: Tuple[float, ...] = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0, 30000.0)
# batch sizes / prompt lengths / image counts: powers of two to 64k
DEFAULT_SIZE_BUCKETS: Tuple[float, ...] = tuple(
    float(1 << i) for i in range(17))


def _host_scalar(value) -> float:
    """Coerce to a host float; jax tracers raise, which IS the jit-safety
    contract — telemetry records at trace boundaries only."""
    try:
        return float(value)
    except Exception as exc:
        raise TypeError(
            f"telemetry records host scalars at trace boundaries only; "
            f"cannot coerce {type(value).__name__} (recording inside "
            f"jit/traced code is a bug): {exc}") from exc


class Counter:
    """Monotonically increasing named integer."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self._value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        n = int(_host_scalar(n))
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins named float (queue depth, slot occupancy)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self._value = 0.0
        self._lock = lock

    def set(self, value) -> None:
        v = _host_scalar(value)
        with self._lock:
            self._value = v

    def add(self, delta) -> None:
        d = _host_scalar(delta)
        with self._lock:
            self._value += d

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram with sum/count/min/max.

    ``buckets`` are strictly increasing upper bounds; an implicit +inf
    bucket catches overflow, so ``len(counts) == len(buckets) + 1``.
    Bucket boundaries are fixed at creation (Prometheus semantics) — a
    later ``histogram()`` call with different buckets is an error.
    """

    __slots__ = ("name", "buckets", "counts", "_sum", "_count",
                 "_min", "_max", "_lock")

    def __init__(self, name: str, buckets: Sequence[float],
                 lock: threading.RLock):
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(
                f"histogram {name!r} buckets must be strictly "
                f"increasing and non-empty: {b}")
        self.name = name
        self.buckets = b
        self.counts = [0] * (len(b) + 1)
        self._sum = 0.0
        self._count = 0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._lock = lock

    def record(self, value) -> None:
        v = _host_scalar(value)
        idx = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self.counts[idx] += 1
            self._sum += v
            self._count += 1
            self._min = v if self._min is None else min(self._min, v)
            self._max = v if self._max is None else max(self._max, v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {
                "buckets": list(self.buckets),
                "counts": list(self.counts),
                "count": self._count,
                "sum": self._sum,
                "mean": self._sum / self._count if self._count else 0.0,
                "min": self._min,
                "max": self._max,
            }


class Registry:
    """Named metric store.  get-or-create accessors, one lock."""

    def __init__(self):
        self._lock = threading.RLock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- accessors ----------------------------------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name, self._lock)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, self._lock)
            return g

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(
                    name, buckets or DEFAULT_MS_BUCKETS, self._lock)
            elif buckets is not None and tuple(
                    float(b) for b in buckets) != h.buckets:
                raise ValueError(
                    f"histogram {name!r} already exists with buckets "
                    f"{h.buckets}; boundaries are fixed at creation")
            return h

    # -- snapshot / reset ---------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """One coherent copy of every metric: ``{"counters": {name:
        int}, "gauges": {name: float}, "histograms": {name: {...}}}``.
        Safe to mutate; json-serializable."""
        with self._lock:
            return {
                "counters": {n: c.value
                             for n, c in sorted(self._counters.items())},
                "gauges": {n: g.value
                           for n, g in sorted(self._gauges.items())},
                "histograms": {n: h.snapshot()
                               for n, h in sorted(
                                   self._histograms.items())},
            }

    def counters_with_prefix(self, prefix: str) -> Dict[str, int]:
        """Non-zero counters under ``prefix``, keyed by the suffix —
        the backing query of the ``ops.FALLBACKS`` compat view."""
        with self._lock:
            return {n[len(prefix):]: c.value
                    for n, c in self._counters.items()
                    if n.startswith(prefix) and c.value}

    def reset(self, prefix: Optional[str] = None) -> None:
        """Remove metrics (all, or those whose name starts with
        ``prefix``).  Handles obtained before a reset are detached —
        always re-fetch through the accessors."""
        with self._lock:
            for store in (self._counters, self._gauges, self._histograms):
                if prefix is None:
                    store.clear()
                else:
                    for name in [n for n in store if n.startswith(prefix)]:
                        del store[name]


_DEFAULT = Registry()


def default_registry() -> Registry:
    return _DEFAULT
