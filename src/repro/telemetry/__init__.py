"""repro.telemetry — runtime metrics, spans, and the predicted-vs-
measured roofline for the serving stack (DESIGN.md §15).

The measured half of the repo's performance story: the static analysis
layer predicts per-kernel FLOPs/bytes (DESIGN.md §14); this package
records what the engines, schedulers, kernels, and loops actually did
— request latency, batch/prefill shape histograms, queue/slot gauges,
recompile and fallback counters — in one process-local registry, and
exports it as JSON, Prometheus text, or the predicted-vs-measured join.

Convenience surface (all over the default registry)::

    from repro import telemetry as T

    T.counter("serving/requests").inc()
    T.gauge("scheduler/queue_depth").set(len(queue))
    with T.span("serving/classify", images=n):
        ...                          # -> span/serving/classify/ms + /images
    snap = T.snapshot()              # coherent dict copy
    T.reset()                        # drop everything (tests)

jit-safety contract: every recording call coerces to a host scalar, so
a jax tracer raises — telemetry lives at trace boundaries only (record
after ``block_until_ready``, around jitted calls, never inside them).
"""
from repro.telemetry.metrics import (DEFAULT_MS_BUCKETS,       # noqa: F401
                                     DEFAULT_SIZE_BUCKETS, Counter, Gauge,
                                     Histogram, Registry, default_registry)
from repro.telemetry.tracing import (current_span, span,       # noqa: F401
                                     span_stats, walltime)


def counter(name: str) -> Counter:
    return default_registry().counter(name)


def gauge(name: str) -> Gauge:
    return default_registry().gauge(name)


def histogram(name: str, buckets=None) -> Histogram:
    return default_registry().histogram(name, buckets)


def snapshot() -> dict:
    return default_registry().snapshot()


def reset(prefix: str | None = None) -> None:
    default_registry().reset(prefix)
