"""Snapshot exporters + the predicted-vs-measured roofline join
(DESIGN.md §15).

Three surfaces over one :meth:`Registry.snapshot`:

* :func:`json_snapshot` — the machine-readable dump CI archives and
  ``examples/serve_deit_mxint.py --metrics-json`` writes;
* :func:`prometheus_text` — Prometheus text exposition (counters,
  gauges, cumulative-bucket histograms) for scrape endpoints;
* :func:`predicted_vs_measured` — joins measured kernel spans
  (``span/kernel:<label>/ms``, recorded by ``repro.telemetry.probes``)
  against the STATIC cost-model table (DESIGN.md §14) by row label and
  reports the achieved fraction of the analytic roofline per kernel —
  the measured half of the compile-time-predicted vs hardware-measured
  loop the accelerator literature (e.g. CHOSEN) evaluates with.

The cost table is resolved like ``benchmarks/roofline.py`` resolves it:
a live import of ``repro.analysis.cost_model`` first, else an explicit
``repro_lint --json`` report path.
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Union

from repro.telemetry import metrics

KERNEL_SPAN_PREFIX = "span/kernel:"


@dataclasses.dataclass(frozen=True)
class RooflinePeaks:
    """Peak rates the predicted times are derived from.

    Defaults are TPU v4 order-of-magnitude (275 TFLOP/s bf16 MXU,
    1.2 TB/s HBM).  On the CPU interpret path the achieved fraction is
    microscopic — the JOIN is the product; absolute fractions only mean
    something on real hardware (ROADMAP "TPU-compiled benchmarks").
    """
    flops_per_s: float = 275e12
    hbm_bytes_per_s: float = 1.2e12
    name: str = "tpu-v4-like"


DEFAULT_PEAKS = RooflinePeaks()


def json_snapshot(snapshot: Optional[dict] = None,
                  path: Union[str, Path, None] = None,
                  extra: Optional[dict] = None,
                  registry=None) -> dict:
    """Snapshot (default registry unless given) as a json-ready dict;
    ``extra`` keys are merged top-level; ``path`` also writes the file."""
    if snapshot is None:
        snapshot = (registry or metrics.default_registry()).snapshot()
    payload = dict(snapshot)
    if extra:
        payload.update(extra)
    if path is not None:
        Path(path).write_text(json.dumps(payload, indent=1,
                                         sort_keys=True) + "\n")
    return payload


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------
def _prom_name(name: str) -> str:
    return "repro_" + re.sub(r"[^a-zA-Z0-9_]", "_", name).strip("_")


def _fmt(v: float) -> str:
    return repr(float(v)) if isinstance(v, float) else str(v)


def prometheus_text(snapshot: Optional[dict] = None, registry=None) -> str:
    """Prometheus 0.0.4 text format.  Histograms use cumulative bucket
    counts with ``le`` labels plus ``_sum``/``_count`` series."""
    if snapshot is None:
        snapshot = (registry or metrics.default_registry()).snapshot()
    out: List[str] = []
    for name, value in snapshot.get("counters", {}).items():
        p = _prom_name(name) + "_total"
        out += [f"# TYPE {p} counter", f"{p} {value}"]
    for name, value in snapshot.get("gauges", {}).items():
        p = _prom_name(name)
        out += [f"# TYPE {p} gauge", f"{p} {_fmt(value)}"]
    for name, h in snapshot.get("histograms", {}).items():
        p = _prom_name(name)
        out.append(f"# TYPE {p} histogram")
        cum = 0
        for bound, count in zip(h["buckets"], h["counts"]):
            cum += count
            out.append(f'{p}_bucket{{le="{_fmt(bound)}"}} {cum}')
        cum += h["counts"][-1]
        out.append(f'{p}_bucket{{le="+Inf"}} {cum}')
        out.append(f"{p}_sum {_fmt(h['sum'])}")
        out.append(f"{p}_count {h['count']}")
    return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# predicted vs measured
# ---------------------------------------------------------------------------
def load_cost_rows(path: Union[str, Path, None] = None
                   ) -> Dict[str, dict]:
    """Static cost-model rows keyed by label (sweep + DeiT fusion rows).

    ``path``: a ``repro_lint --json`` report (or bare cost_model
    payload); default imports ``repro.analysis.cost_model`` live.
    """
    if path is None:
        from repro.analysis import cost_model
        rows = list(cost_model.build_table())
        rows += cost_model.fusion_study()["rows"]
    else:
        payload = json.loads(Path(path).read_text())
        payload = payload.get("cost_model", payload)
        rows = list(payload.get("rows", []))
        rows += payload.get("fusion_rows", [])
    return {r["label"]: r for r in rows}


def predicted_vs_measured(snapshot: Optional[dict] = None,
                          rows: Union[Dict[str, dict],
                                      Sequence[dict], None] = None,
                          peaks: RooflinePeaks = DEFAULT_PEAKS,
                          cost_report: Union[str, Path, None] = None,
                          registry=None) -> dict:
    """Join measured ``span/kernel:<label>/ms`` histograms against the
    static cost-model rows of the same label.

    Per joined kernel: measured mean wall-clock, the analytic roofline
    time ``max(flops/peak_flops, hbm_bytes/peak_bw)``, which term binds,
    and the achieved fraction ``predicted/measured`` (1.0 == running at
    the roofline; CPU interpret mode sits far below by design).
    Measured spans with no table row land in ``unmatched`` — a probe
    label drifting from the sweep is a finding, not a silent drop.
    """
    if snapshot is None:
        snapshot = (registry or metrics.default_registry()).snapshot()
    if rows is None:
        rows = load_cost_rows(cost_report)
    elif not isinstance(rows, dict):
        rows = {r["label"]: r for r in rows}

    joined: List[dict] = []
    unmatched: List[str] = []
    for name, h in snapshot.get("histograms", {}).items():
        if not (name.startswith(KERNEL_SPAN_PREFIX)
                and name.endswith("/ms")):
            continue
        label = name[len(KERNEL_SPAN_PREFIX):-len("/ms")]
        if not h["count"]:
            continue
        row = rows.get(label)
        if row is None:
            unmatched.append(label)
            continue
        measured_ms = h["mean"]
        flops = int(row.get("flops", 0))
        hbm = int(row.get("hbm_bytes", 0))
        compute_s = flops / peaks.flops_per_s
        memory_s = hbm / peaks.hbm_bytes_per_s
        predicted_s = max(compute_s, memory_s)
        measured_s = measured_ms / 1e3
        joined.append({
            "label": label,
            "kernel": row.get("kernel"),
            "samples": h["count"],
            "measured_ms": round(measured_ms, 6),
            "predicted_ms": round(predicted_s * 1e3, 6),
            "bottleneck": "compute" if compute_s >= memory_s else "memory",
            "flops": flops,
            "hbm_bytes": hbm,
            "intensity": row.get("intensity"),
            "achieved_fraction":
                round(predicted_s / measured_s, 9) if measured_s else None,
            "achieved_gflop_per_s":
                round(flops / measured_s / 1e9, 3) if measured_s else None,
            "achieved_gb_per_s":
                round(hbm / measured_s / 1e9, 3) if measured_s else None,
        })
    joined.sort(key=lambda r: r["label"])
    return {
        "peaks": dataclasses.asdict(peaks),
        "kernels": joined,
        "unmatched": sorted(unmatched),
    }
