"""Deterministic synthetic data pipelines.

Production properties kept even though the data is synthetic:
  * deterministic and seekable — batch i is a pure function of (seed, i), so
    resuming from a checkpoint replays the exact stream (the DataState is
    part of the checkpoint);
  * host-shardable — each data-parallel host can build only its slice
    (shard_index / num_shards);
  * learnable structure — LM streams are Markov-chain token sequences (so a
    real training run shows loss going down), image streams are class-
    conditional Gaussian blobs (so DeiT PTQ experiments have a real signal
    to lose).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    next_index: int

    def to_dict(self):
        return {"seed": self.seed, "next_index": self.next_index}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), next_index=int(d["next_index"]))


class _Seekable:
    def __init__(self, seed: int, shard_index: int = 0, num_shards: int = 1):
        self.state = DataState(seed=seed, next_index=0)
        self.shard_index = shard_index
        self.num_shards = num_shards

    def _rng_for(self, index: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence(
                entropy=self.state.seed,
                spawn_key=(index, self.shard_index)))

    def batch_at(self, index: int) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        b = self.batch_at(self.state.next_index)
        self.state.next_index += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        while True:
            yield self.next_batch()


class SyntheticLMData(_Seekable):
    """Markov-chain token stream with vocab bucketing (learnable bigrams)."""

    def __init__(self, *, vocab: int, batch: int, seq_len: int, seed: int = 0,
                 shard_index: int = 0, num_shards: int = 1,
                 vision_tokens: int = 0, vision_dim: int = 0,
                 structure_seed: int = 1234):
        super().__init__(seed, shard_index, num_shards)
        self.vocab = vocab
        self.batch = batch // num_shards
        self.seq = seq_len
        self.vision_tokens = vision_tokens
        self.vision_dim = vision_dim
        # the TASK (transition structure) is fixed by structure_seed so that
        # train and eval streams with different sample seeds share it
        g = np.random.default_rng(structure_seed)
        self._succ = g.integers(0, vocab, size=(vocab, 4))

    def batch_at(self, index: int) -> Dict[str, jnp.ndarray]:
        rng = self._rng_for(index)
        toks = np.empty((self.batch, self.seq), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        choices = rng.integers(0, 4, size=(self.batch, self.seq))
        noise = rng.random((self.batch, self.seq)) < 0.05
        rand_tok = rng.integers(0, self.vocab, size=(self.batch, self.seq))
        for t in range(1, self.seq):
            nxt = self._succ[toks[:, t - 1], choices[:, t]]
            toks[:, t] = np.where(noise[:, t], rand_tok[:, t], nxt)
        out = {"tokens": jnp.asarray(toks)}
        if self.vision_tokens:
            out["vision_embeds"] = jnp.asarray(
                rng.normal(size=(self.batch, self.vision_tokens,
                                 self.vision_dim)).astype(np.float32))
        return out


class SyntheticSeq2SeqData(_Seekable):
    """Frame embeddings -> token targets for the enc-dec arch."""

    def __init__(self, *, vocab: int, batch: int, seq_len: int, d_model: int,
                 seed: int = 0, shard_index: int = 0, num_shards: int = 1):
        super().__init__(seed, shard_index, num_shards)
        self.vocab = vocab
        self.batch = batch // num_shards
        self.seq = seq_len
        self.d = d_model

    def batch_at(self, index: int) -> Dict[str, jnp.ndarray]:
        rng = self._rng_for(index)
        toks = rng.integers(0, self.vocab,
                            size=(self.batch, self.seq)).astype(np.int32)
        # frames correlate with the tokens (projected one-hot + noise)
        proj = np.random.default_rng(self.state.seed).normal(
            size=(64, self.d)).astype(np.float32)
        frames = proj[toks % 64] + 0.1 * rng.normal(
            size=(self.batch, self.seq, self.d)).astype(np.float32)
        return {"tokens": jnp.asarray(toks), "frames": jnp.asarray(frames)}


class SyntheticImageData(_Seekable):
    """Class-conditional Gaussian-blob images (learnable 10..1000-way)."""

    def __init__(self, *, n_classes: int, batch: int, image_size: int,
                 seed: int = 0, shard_index: int = 0, num_shards: int = 1,
                 structure_seed: int = 1234, noise: float = 0.35,
                 outlier_channels: bool = False, class_sep: float = 1.0):
        super().__init__(seed, shard_index, num_shards)
        self.n_classes = n_classes
        self.batch = batch // num_shards
        self.hw = image_size
        self.noise = noise
        # class prototypes are the TASK: fixed by structure_seed, shared by
        # train and eval streams regardless of their sample seed.
        # class_sep < 1 makes classes share a base pattern with small
        # per-class deltas — thin decision margins, so quantization error
        # becomes visible in accuracy (the paper's Table V regime).
        g = np.random.default_rng(structure_seed)
        base = g.normal(size=(1, 8, 8, 3)).astype(np.float32)
        delta = g.normal(size=(n_classes, 8, 8, 3)).astype(np.float32)
        if outlier_channels:
            # the outlier channel carries NO class information — like the
            # high-magnitude, class-uninformative activation dims of real
            # ViTs; per-tensor int quantization sets its LSB from the
            # outliers and crushes the thin class signal elsewhere.
            delta[..., 2] = 0.0
        self._proto = base + class_sep * delta
        # heavy-tailed channel scales emulate the activation-outlier
        # phenomenon of real ViTs that breaks per-tensor int quantization
        self._scale = (np.asarray([1.0, 1.0, 24.0], np.float32)
                       if outlier_channels else np.ones(3, np.float32))

    def batch_at(self, index: int) -> Dict[str, jnp.ndarray]:
        rng = self._rng_for(index)
        labels = rng.integers(0, self.n_classes, self.batch).astype(np.int32)
        base = self._proto[labels]                      # (b, 8, 8, 3)
        reps = self.hw // 8
        img = np.repeat(np.repeat(base, reps, axis=1), reps, axis=2)
        img = img + self.noise * rng.normal(size=img.shape).astype(np.float32)
        img = img * self._scale
        return {"images": jnp.asarray(img), "labels": jnp.asarray(labels)}
