from repro.data.pipeline import (SyntheticLMData, SyntheticImageData,
                                 SyntheticSeq2SeqData, DataState)
