"""Architecture registry: full configs + reduced smoke configs.

Every assigned architecture is one module exposing ``FULL`` (the exact
published config) and ``SMOKE`` (same family, tiny dims) plus
``long_500k_supported`` / shape-skip metadata consumed by the dry-run and
the roofline table.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.model_api import ModelConfig

ARCH_IDS: List[str] = [
    "llava_next_mistral_7b",
    "recurrentgemma_2b",
    "llama3_8b",
    "deepseek_67b",
    "phi4_mini_3_8b",
    "qwen3_14b",
    "mixtral_8x7b",
    "granite_moe_3b_a800m",
    "xlstm_350m",
    "seamless_m4t_medium",
]

VIT_IDS: List[str] = ["deit_tiny", "deit_small", "deit_base"]


def _module(arch_id: str):
    return importlib.import_module(f"repro.configs.{arch_id}")


def full_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).FULL


def smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def shape_supported(arch_id: str, shape_name: str) -> bool:
    """40-cell applicability matrix (DESIGN.md §6)."""
    mod = _module(arch_id)
    if shape_name == "long_500k":
        return getattr(mod, "LONG_500K_SUPPORTED", False)
    return True


def skip_reason(arch_id: str, shape_name: str) -> str:
    mod = _module(arch_id)
    return getattr(mod, "SKIP_REASON", "full quadratic attention at 512k "
                   "context is neither sub-quadratic nor in scope")


def all_configs() -> Dict[str, ModelConfig]:
    return {a: full_config(a) for a in ARCH_IDS}
