"""mixtral-8x7b [moe]: 32L d4096 32H (GQA kv=8) ff14336 vocab 32000,
MoE 8 experts top-2, sliding-window attention (W=4096).

SWA makes the arch sub-quadratic in context length: the long_500k decode
cell runs with a 4096-slot ring-buffer KV cache (DESIGN.md §6).
[arXiv:2401.04088; hf]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="mixtral_8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    unit=("attn",),
    window=4096,
    rope_theta=1000000.0,
    ffn_kind="moe",
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="mixtral_smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab=512,
    unit=("attn",),
    window=16,
    ffn_kind="moe",
    moe=MoEConfig(num_experts=4, top_k=2),
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = True   # SWA ring cache: O(window) per layer
