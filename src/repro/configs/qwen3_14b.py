"""qwen3-14b [dense]: 40L d5120 40H (GQA kv=8) ff17408 vocab 151936.

qk-norm (per-head RMSNorm on Q and K) + GQA + SwiGLU.
[hf:Qwen/Qwen3-8B; hf]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig

FULL = ModelConfig(
    name="qwen3_14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab=151936,
    head_dim=128,
    unit=("attn",),
    qk_norm=True,
    rope_theta=1000000.0,
    ffn_kind="swiglu",
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="qwen3_14b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    head_dim=16,
    unit=("attn",),
    qk_norm=True,
    ffn_kind="swiglu",
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = False
SKIP_REASON = ("pure full-attention decoder: dense 512k KV at batch 1 "
               "fails the sub-quadratic requirement (DESIGN.md §6)")
