"""seamless-m4t-medium [audio]: enc-dec, 12L+12L d1024 16H ff4096
vocab 256206 — multimodal speech/text translation backbone.

The speech frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings (b, s, 1024) to the encoder.  The text decoder is cached and
drives the decode shapes; MT-style training loss (frames -> tokens).
[arXiv:2308.11596; hf]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig

FULL = ModelConfig(
    name="seamless_m4t_medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    is_encoder_decoder=True,
    n_encoder_layers=12,
    audio_frames=True,
    unit=("attn",),
    ffn_kind="gelu",
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="seamless_smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    is_encoder_decoder=True,
    n_encoder_layers=2,
    audio_frames=True,
    unit=("attn",),
    ffn_kind="gelu",
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = False
SKIP_REASON = ("encoder-decoder with full attention: 512k cross+self dense "
               "KV at batch 1 fails the sub-quadratic requirement "
               "(DESIGN.md §6)")
