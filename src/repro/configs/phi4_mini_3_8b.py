"""phi4-mini-3.8b [dense]: 32L d3072 24H (GQA kv=8) ff8192 vocab 200064.

RoPE + SwiGLU + GQA.  (Real phi-4-mini uses partial rotary embedding; we
apply full RoPE — noted as a deviation in DESIGN.md §9.)
[arXiv:2412.08905; hf]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig

FULL = ModelConfig(
    name="phi4_mini_3_8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    unit=("attn",),
    rope_theta=10000.0,
    ffn_kind="swiglu",
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="phi4_mini_smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=6,
    n_kv_heads=2,
    d_ff=96,
    vocab=512,
    unit=("attn",),
    ffn_kind="swiglu",
    tie_embeddings=True,
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = False
SKIP_REASON = ("pure full-attention decoder: dense 512k KV at batch 1 "
               "fails the sub-quadratic requirement (DESIGN.md §6)")
