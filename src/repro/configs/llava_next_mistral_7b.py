"""llava-next-mistral-7b [vlm]: Mistral-7B backbone, 32L d4096 32H
(GQA kv=8) ff14336 vocab 32000, anyres vision tiling.

The modality frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed CLIP-style patch embeddings (anyres tiling of a 672x672 image
-> 5 tiles x 576 patches = 2880 vision tokens, d_vis=1024) which the
projector maps into the first 2880 token positions.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig

VISION_TOKENS = 2880   # 5 anyres tiles x (24x24) patches

FULL = ModelConfig(
    name="llava_next_mistral_7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    unit=("attn",),
    rope_theta=1000000.0,
    ffn_kind="swiglu",
    vision_tokens=VISION_TOKENS,
    vision_dim=1024,
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="llava_smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    unit=("attn",),
    ffn_kind="swiglu",
    vision_tokens=8,
    vision_dim=32,
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = False
SKIP_REASON = ("full-attention VLM backbone: dense 512k KV at batch 1 "
               "fails the sub-quadratic requirement (DESIGN.md §6)")
