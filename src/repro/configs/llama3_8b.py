"""llama3-8b [dense]: 32L d4096 32H (GQA kv=8) ff14336 vocab 128256.

RoPE theta 500k, SwiGLU, RMSNorm, untied embeddings.
[arXiv:2407.21783; unverified]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig

FULL = ModelConfig(
    name="llama3_8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    unit=("attn",),
    rope_theta=500000.0,
    ffn_kind="swiglu",
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="llama3_8b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    unit=("attn",),
    rope_theta=500000.0,
    ffn_kind="swiglu",
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = False
SKIP_REASON = ("pure full-attention decoder: a dense 512k-KV cache per "
               "layer at batch 1 is quadratic-cost prefill and out of the "
               "sub-quadratic requirement (DESIGN.md §6)")
