"""xlstm-350m [ssm]: 24L d1024 4H ff- vocab 50304 — mLSTM + sLSTM blocks.

xLSTM[7:1] layout: unit = 7 mLSTM + 1 sLSTM, repeated 3x.  mLSTM runs in
its chunkwise-parallel linear-attention form (training) and as an O(1)
matrix-memory update (decode); sLSTM is a sequential scalar-memory scan.
Attention-free: the paper's softmax datapath is inapplicable, but the
exponential input gate reuses the Eq. 14-19 pow2-LUT datapath
(DESIGN.md §6).
[arXiv:2405.04517; unverified]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig

FULL = ModelConfig(
    name="xlstm_350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    unit=("mlstm",) * 7 + ("slstm",),
    n_units=3,
    ffn_kind="none",
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="xlstm_smoke",
    family="ssm",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=512,
    unit=("mlstm",) * 3 + ("slstm",),
    n_units=2,
    ffn_kind="none",
    tie_embeddings=True,
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = True   # O(1) recurrent state for both block kinds
