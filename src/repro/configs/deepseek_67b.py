"""deepseek-67b [dense]: 95L d8192 64H (GQA kv=8) ff22016 vocab 102400.

Llama-style architecture at depth 95 — the largest assigned model; the
dry-run exercises scan-over-layers compile scalability.
[arXiv:2401.02954; hf]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig

FULL = ModelConfig(
    name="deepseek_67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    unit=("attn",),
    n_units=95,
    rope_theta=10000.0,
    ffn_kind="swiglu",
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="deepseek_67b_smoke",
    family="dense",
    n_layers=3,            # odd depth keeps the scan+unit math honest
    d_model=64,
    n_heads=8,
    n_kv_heads=1,
    d_ff=160,
    vocab=512,
    unit=("attn",),
    n_units=3,
    ffn_kind="swiglu",
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = False
SKIP_REASON = ("pure full-attention decoder (95L): dense 512k KV at batch 1 "
               "fails the sub-quadratic requirement (DESIGN.md §6)")
