"""recurrentgemma-2b [hybrid]: 26L d2560 10H (MQA kv=1) ff7680 vocab 256000.

RG-LRU + local attention at 1:2 attention:recurrent ratio — the repeating
unit is (rec, rec, attn) x 8 with a (rec, rec) tail = 26 layers.  Local
attention window 2048; GeGLU MLPs; O(1) recurrent state makes long_500k
decode a state-update, not a cache walk.
[arXiv:2402.19427; hf]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma_2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    unit=("rec", "rec", "attn"),
    n_units=8,
    tail=("rec", "rec"),
    local_attn_window=2048,
    lru_width=2560,
    conv_width=4,
    rope_theta=10000.0,
    ffn_kind="geglu",
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="recurrentgemma_smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=2,
    n_kv_heads=1,
    d_ff=128,
    vocab=512,
    unit=("rec", "rec", "attn"),
    n_units=1,
    tail=("rec", "rec"),
    local_attn_window=16,
    lru_width=64,
    ffn_kind="geglu",
    tie_embeddings=True,
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = True   # RG-LRU state + windowed local attention
