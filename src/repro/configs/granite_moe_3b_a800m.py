"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) per-expert ff512
vocab 49155, MoE 40 experts top-8.

Tiny per-expert d_ff (512) with many experts: the MXInt weight block size
(256) divides d_ff exactly; per DESIGN.md §6 blocks are clamped to never
straddle the expert dim.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="granite_moe_3b_a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    unit=("attn",),
    rope_theta=10000.0,
    ffn_kind="moe",
    moe=MoEConfig(num_experts=40, top_k=8, capacity_factor=1.25),
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    remat="block",
)

SMOKE = ModelConfig(
    name="granite_moe_smoke",
    family="moe",
    n_layers=2,
    d_model=48,
    n_heads=6,
    n_kv_heads=2,
    d_ff=32,
    vocab=512,
    unit=("attn",),
    ffn_kind="moe",
    moe=MoEConfig(num_experts=8, top_k=4),
    tie_embeddings=True,
    dtype=jnp.float32,
)

LONG_500K_SUPPORTED = False
SKIP_REASON = ("full-attention MoE decoder: dense 512k KV at batch 1 "
               "fails the sub-quadratic requirement (DESIGN.md §6)")
