"""DeiT Tiny / Small / Base — the paper's evaluation models (§IV).

DeiT-Tiny:  12L d192  3H  ff768
DeiT-Small: 12L d384  6H  ff1536
DeiT-Base:  12L d768 12H  ff3072
All: 224x224 images, patch 16 (197 tokens), 1000 classes, GELU MLP,
pre-LayerNorm.  [Touvron et al.; timm]
"""
import jax.numpy as jnp

from repro.models.model_api import ModelConfig


def _deit(name, d, heads, ff):
    return ModelConfig(
        name=name,
        family="vit",
        n_layers=12,
        d_model=d,
        n_heads=heads,
        n_kv_heads=heads,
        d_ff=ff,
        vocab=0,
        unit=("attn",),
        ffn_kind="gelu",
        image_size=224,
        patch_size=16,
        n_classes=1000,
        dtype=jnp.float32,
        norm_eps=1e-6,
    )


DEIT_TINY = _deit("deit_tiny", 192, 3, 768)
DEIT_SMALL = _deit("deit_small", 384, 6, 1536)
DEIT_BASE = _deit("deit_base", 768, 12, 3072)

# a reduced DeiT used by tests/benchmarks that train on CPU
DEIT_MICRO = ModelConfig(
    name="deit_micro",
    family="vit",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=0,
    unit=("attn",),
    ffn_kind="gelu",
    image_size=32,
    patch_size=8,
    n_classes=10,
    dtype=jnp.float32,
)

BY_NAME = {
    "deit_tiny": DEIT_TINY,
    "deit_small": DEIT_SMALL,
    "deit_base": DEIT_BASE,
    "deit_micro": DEIT_MICRO,
}
