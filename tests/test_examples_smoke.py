"""Example-driver smoke tests: the public scripts run UNCHANGED.

The datapath redesign (DESIGN.md §12) kept every ``models.layers`` /
engine call signature stable — these subprocess runs are the assertion:
``examples/serve_deit_mxint.py`` and ``examples/serve_llm_mxint.py``
exercise the full public surface (QuantConfig modes, ViTServingEngine,
ClassifyScheduler/BatchScheduler, kernel-mode decode) exactly as an
external user would, with no edits for the refactor.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow      # subprocess + interpret-mode kernels

ROOT = Path(__file__).resolve().parents[1]


def _run(script, *args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=str(ROOT))
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    return proc.stdout


def test_serve_deit_mxint_runs_unchanged():
    out = _run("serve_deit_mxint.py", "--requests", "8", "--batch", "4")
    assert "served" in out
    assert "accuracy (MXInt)" in out


def test_serve_llm_mxint_kernel_runs_unchanged():
    out = _run("serve_llm_mxint.py", "--requests", "2", "--new-tokens", "2",
               "--kernel")
    assert "generated" in out.lower() or "tok" in out.lower()
