"""End-to-end tests for QuantConfig(mode='kernel') — the Pallas model path.

The 'sim' mode is the bit-accurate oracle for the paper's MXInt datapaths;
'kernel' routes the same math through the Pallas kernels (interpret mode on
CPU).  The headline assertion: a DeiT forward in kernel mode equals the sim
forward BIT-FOR-BIT, while consuming the packed int8 planes directly (no
host-side dequantize anywhere in the traced program).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.deit import DEIT_MICRO, DEIT_TINY
from repro.core.mx_types import QuantConfig
from repro.core.quantize import MXTensor
from repro.models import build_model
from repro.serving.engine import (ServeConfig, ViTServingEngine, make_engine,
                                  pack_params_mxint)

SIM = QuantConfig(mode="sim", quantize_nonlinear=True)
KERNEL = QuantConfig(mode="kernel", quantize_nonlinear=True)


def _models(base, n_layers=2, n_classes=100):
    cfg = dataclasses.replace(base, n_layers=n_layers, n_classes=n_classes)
    m_sim = build_model(dataclasses.replace(cfg, quant=SIM))
    m_ker = build_model(dataclasses.replace(cfg, quant=KERNEL))
    params = m_sim.init(jax.random.key(0))
    packed = pack_params_mxint(params, KERNEL.weight_fmt)
    return m_sim, m_ker, params, packed


def _images(n, size, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, size, size, 3)).astype(np.float32))


class TestKernelModeParity:
    def test_deit_tiny_bit_exact_vs_sim(self):
        """DeiT-Tiny shapes (d=192, 197 tokens): kernel == sim bit-for-bit.

        Every operator is exercised: patch linear (K=768), attention
        qkv/out linears, the whole-row Pallas MXInt softmax over the prime
        197-length score rows, LayerNorm and GELU kernels, and the padded
        (N=100) classifier head.
        """
        m_sim, m_ker, params, packed = _models(DEIT_TINY)
        imgs = _images(2, DEIT_TINY.image_size)
        want = np.asarray(jax.jit(m_sim.logits)(params, imgs))
        got = np.asarray(jax.jit(m_ker.logits)(packed, imgs))
        np.testing.assert_array_equal(got, want)

    def test_deit_micro_bit_exact_vs_sim(self):
        m_sim, m_ker, params, packed = _models(DEIT_MICRO, n_classes=10)
        imgs = _images(3, DEIT_MICRO.image_size, seed=7)
        want = np.asarray(jax.jit(m_sim.logits)(params, imgs))
        got = np.asarray(jax.jit(m_ker.logits)(packed, imgs))
        np.testing.assert_array_equal(got, want)

    def test_kernel_mode_works_on_unpacked_params(self):
        """Float Param leaves are packed on the fly — same result."""
        m_sim, m_ker, params, packed = _models(DEIT_MICRO, n_classes=10)
        imgs = _images(1, DEIT_MICRO.image_size, seed=3)
        a = np.asarray(m_ker.logits(packed, imgs))
        b = np.asarray(m_ker.logits(params, imgs))
        np.testing.assert_array_equal(a, b)


class TestKernelModeAttentionParity:
    """Masked + GQA attention through attention_op vs the sim direct path.

    Regression guard for the requantize shift-saturation overflow: masked
    (-2e38) scores share rows with real scores, driving the row-alignment
    shift to its 31-bit clamp — `1 << 31` overflowed int32 there.  Also
    covers the grouped-query fold (K/V contracted once per KV head).
    """

    @pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                               (True, 8)])
    def test_gqa_masked_bit_exact(self, causal, window):
        from repro.models import attention as A
        from repro.models.model_api import ModelConfig

        cfg = ModelConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=100, ffn_kind="gelu",
                          dtype=jnp.float32)
        p = A.init_attn_params(jax.random.key(0), cfg, jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(2, 24, 64)).astype(np.float32))
        o_sim, _ = A.attention(p, x, cfg, quant=SIM, causal=causal,
                               window=window, use_rope=False)
        o_ker, _ = A.attention(p, x, cfg, quant=KERNEL, causal=causal,
                               window=window, use_rope=False)
        np.testing.assert_array_equal(np.asarray(o_ker), np.asarray(o_sim))


class TestKernelModeDecode:
    """mode='kernel' LM decode runs the fused Pallas decode kernel — no
    XLA `_gqa_scores + L.softmax` scoring on the cache branch (ISSUE 3).
    """

    def _cfg(self):
        from repro.models.model_api import ModelConfig
        return ModelConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                           d_ff=128, vocab=100, ffn_kind="gelu",
                           dtype=jnp.float32)

    def _prefill_then_decode(self, quant, window=0, w_cache=32):
        from repro.models import attention as A
        cfg = self._cfg()
        p = A.init_attn_params(jax.random.key(0), cfg, jnp.float32)
        rng = np.random.default_rng(0)
        x_pre = jnp.asarray(rng.normal(size=(2, 7, 64)).astype(np.float32))
        x_dec = jnp.asarray(rng.normal(size=(2, 1, 64)).astype(np.float32))
        cache = A.init_kv_cache(cfg, 2, w_cache, window, jnp.float32)
        _, cache = A.attention(p, x_pre, cfg, quant=quant, cache=cache,
                               cache_index=jnp.int32(0), window=window)
        o, _ = A.attention(p, x_dec, cfg, quant=quant, cache=cache,
                           cache_index=jnp.int32(7), window=window)
        return np.asarray(o)

    def test_decode_bit_exact_vs_sim(self):
        """Partially filled full ring: the fused decode kernel reproduces
        the sim decode path BIT-FOR-BIT (the ring's invalid slots go
        through the quantizer as NEG_INF in both paths, and the padded
        slots of the kernel tile are numerically invisible)."""
        o_sim = self._prefill_then_decode(SIM)
        o_ker = self._prefill_then_decode(KERNEL)
        np.testing.assert_array_equal(o_ker, o_sim)

    def test_decode_windowed_ring_bit_exact_vs_sim(self):
        o_sim = self._prefill_then_decode(SIM, window=8, w_cache=32)
        o_ker = self._prefill_then_decode(KERNEL, window=8, w_cache=32)
        np.testing.assert_array_equal(o_ker, o_sim)

    def test_no_xla_softmax_in_decode_trace(self):
        """The kernel-mode decode step satisfies the full kernel-mode
        trace contract: no float softmax chain / exp / f64 outside
        pallas_call, and exactly the expected pallas_call count (q/k/v
        projections + fused decode kernel + wo).  This is the declarative
        generalization of the old L.softmax-spy assertion — the same
        rules run over every backend in `repro.analysis.trace_lint`
        (DESIGN.md §13)."""
        from repro.analysis import trace_lint as TL
        from repro.models import attention as A
        cfg = self._cfg()
        p = A.init_attn_params(jax.random.key(0), cfg, jnp.float32)
        rng = np.random.default_rng(1)
        x_dec = jnp.asarray(rng.normal(size=(2, 1, 64)).astype(np.float32))
        cache = A.init_kv_cache(cfg, 2, 32, 0, jnp.float32)

        rules = TL.TraceRules(deny_outside_pallas=TL.KERNEL_NL_DENY,
                              forbid_softmax_chain=True,
                              pallas_budget=(5, 5))
        violations = TL.lint_fn(
            lambda x, c: A.attention(p, x, cfg, quant=KERNEL, cache=c,
                                     cache_index=jnp.int32(7))[0],
            (x_dec, cache), rules, "test:decode-step")
        assert violations == [], [str(v) for v in violations]

    def test_float_kernel_decode_matches_direct(self):
        """quantize_nonlinear off: the float decode kernel still replaces
        the XLA path and matches it numerically."""
        o_ker = self._prefill_then_decode(QuantConfig(mode="kernel"))
        o_off = self._prefill_then_decode(QuantConfig(mode="off"))
        # weights are MXInt-packed in kernel mode, so only closeness holds
        assert np.abs(o_ker - o_off).max() < 0.5
        cos = np.vdot(o_ker, o_off) / (np.linalg.norm(o_ker) *
                                       np.linalg.norm(o_off))
        assert cos > 0.99


class TestPerRowDecodeRing:
    """ISSUE 7: the decode path takes a PER-ROW (b,) cache index — each
    row masks its own ring validity (``flash_attention_decode`` reads a
    (B, W) validity plane).  Regression pins: heterogeneous indices stay
    bit-exact kernel-vs-sim within one 128-key block, every row's output
    equals a batch-1 run at its own index (row independence, including
    rings straddling the 128-key block boundary), and the scalar-index
    call keeps working (EncDecLM compat)."""

    def _cfg(self):
        from repro.models.model_api import ModelConfig
        return ModelConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                           d_ff=128, vocab=100, ffn_kind="gelu",
                           dtype=jnp.float32)

    def _setup(self, prefill_len, w_cache, window=0, seed=0):
        from repro.models import attention as A
        cfg = self._cfg()
        p = A.init_attn_params(jax.random.key(0), cfg, jnp.float32)
        rng = np.random.default_rng(seed)
        x_pre = jnp.asarray(
            rng.normal(size=(2, prefill_len, 64)).astype(np.float32))
        x_dec = jnp.asarray(rng.normal(size=(2, 1, 64)).astype(np.float32))
        return cfg, p, x_pre, x_dec

    def _decode(self, quant, idx, prefill_len=7, w_cache=32, window=0):
        from repro.models import attention as A
        cfg, p, x_pre, x_dec = self._setup(prefill_len, w_cache, window)
        cache = A.init_kv_cache(cfg, 2, w_cache, window, jnp.float32)
        _, cache = A.attention(p, x_pre, cfg, quant=quant, cache=cache,
                               cache_index=jnp.int32(0), window=window)
        o, _ = A.attention(p, x_dec, cfg, quant=quant, cache=cache,
                           cache_index=idx, window=window)
        return np.asarray(o), cache

    def _decode_rows(self, quant, idx, prefill_len=7, w_cache=32, window=0):
        """Batch-1 oracle: run each row alone at its own scalar index."""
        from repro.models import attention as A
        cfg, p, x_pre, x_dec = self._setup(prefill_len, w_cache, window)
        rows = []
        for i in range(2):
            cache = A.init_kv_cache(cfg, 1, w_cache, window, jnp.float32)
            _, cache = A.attention(p, x_pre[i:i + 1], cfg, quant=quant,
                                   cache=cache, cache_index=jnp.int32(0),
                                   window=window)
            o, _ = A.attention(p, x_dec[i:i + 1], cfg, quant=quant,
                               cache=cache, cache_index=jnp.int32(int(idx[i])),
                               window=window)
            rows.append(np.asarray(o))
        return np.concatenate(rows, axis=0)

    def test_heterogeneous_indices_bit_exact_vs_sim(self):
        """One 128-key block: kernel == sim bit-for-bit even when the two
        rows mask DIFFERENT ring prefixes (row 1 sees only 4 of the 7
        cached keys)."""
        idx = jnp.asarray([7, 4], jnp.int32)
        o_sim, _ = self._decode(SIM, idx)
        o_ker, _ = self._decode(KERNEL, idx)
        np.testing.assert_array_equal(o_ker, o_sim)

    def test_heterogeneous_windowed_ring_bit_exact_vs_sim(self):
        idx = jnp.asarray([13, 9], jnp.int32)
        o_sim, _ = self._decode(SIM, idx, prefill_len=13, w_cache=32,
                                window=8)
        o_ker, _ = self._decode(KERNEL, idx, prefill_len=13, w_cache=32,
                                window=8)
        np.testing.assert_array_equal(o_ker, o_sim)

    @pytest.mark.parametrize("quant", [SIM, KERNEL], ids=["sim", "kernel"])
    def test_rows_independent_of_batching(self, quant):
        """Batched heterogeneous decode == stacking batch-1 runs at each
        row's own index."""
        idx = [7, 4]
        got, _ = self._decode(quant, jnp.asarray(idx, jnp.int32))
        want = self._decode_rows(quant, idx)
        np.testing.assert_array_equal(got, want)

    @pytest.mark.slow
    @pytest.mark.parametrize("quant", [SIM, KERNEL], ids=["sim", "kernel"])
    def test_straddling_block_boundary_rows_independent(self, quant):
        """W=256 ring, indices [100, 200]: row 1's live keys span both
        128-key kernel blocks while row 0's stay in block 0 — per-row
        masking must not leak across the block boundary or the batch."""
        idx = [100, 200]
        got, _ = self._decode(quant, jnp.asarray(idx, jnp.int32),
                              prefill_len=200, w_cache=256)
        want = self._decode_rows(quant, idx, prefill_len=200, w_cache=256)
        np.testing.assert_array_equal(got, want)

    @pytest.mark.slow
    def test_straddling_block_boundary_kernel_close_to_sim(self):
        """Per-row indices must not widen the kernel-vs-sim gap.  Row 0
        (idx 100, one block) stays in bit-exact territory; row 1
        (idx 200, two blocks) diverges at the blocked online softmax's
        per-block score-requantization granularity — measured max |Δ|
        ~0.14 on O(0.5) outputs here — so it is pinned at 0.25, loose
        enough for LUT granularity but an order under the O(1) blowup a
        masking/index regression produces (leaked pad keys shift the
        whole distribution)."""
        idx = jnp.asarray([100, 200], jnp.int32)
        o_sim, _ = self._decode(SIM, idx, prefill_len=200, w_cache=256)
        o_ker, _ = self._decode(KERNEL, idx, prefill_len=200, w_cache=256)
        np.testing.assert_allclose(o_ker[0], o_sim[0], rtol=0.02, atol=0.02)
        np.testing.assert_allclose(o_ker[1], o_sim[1], rtol=0.1, atol=0.25)

    def test_scalar_index_still_supported(self):
        """EncDecLM and the existing call sites pass a scalar — it must
        broadcast to every row (same result as the explicit vector)."""
        o_scalar, _ = self._decode(SIM, jnp.int32(7))
        o_vec, _ = self._decode(SIM, jnp.asarray([7, 7], jnp.int32))
        np.testing.assert_array_equal(o_scalar, o_vec)


class TestDirectBranchRaggedPositions:
    """Regression: `positions.reshape(-1)[-s:]` collapsed (b, s) position
    rows to the LAST batch element's positions, so ragged batches (e.g.
    left-padded prompts) were causally masked with the wrong offsets."""

    def _run(self, quant):
        from repro.models import attention as A
        from repro.models.model_api import ModelConfig
        cfg = ModelConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=100, ffn_kind="gelu",
                          dtype=jnp.float32)
        p = A.init_attn_params(jax.random.key(2), cfg, jnp.float32)
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(2, 6, 64)).astype(np.float32))
        positions = jnp.asarray([[0, 1, 2, 3, 4, 5],
                                 [3, 4, 5, 6, 7, 8]], jnp.int32)
        batched, _ = A.attention(p, x, cfg, quant=quant,
                                 positions=positions, causal=True,
                                 window=4, use_rope=False)
        per_row = [A.attention(p, x[i:i + 1], cfg, quant=quant,
                               positions=positions[i:i + 1], causal=True,
                               window=4, use_rope=False)[0]
                   for i in range(2)]
        return np.asarray(batched), np.asarray(jnp.concatenate(per_row))

    def test_ragged_positions_mask_per_row(self):
        batched, per_row = self._run(QuantConfig(mode="off"))
        np.testing.assert_array_equal(batched, per_row)

    def test_ragged_positions_mask_per_row_sim(self):
        batched, per_row = self._run(SIM)
        np.testing.assert_array_equal(batched, per_row)

    def test_position_relabeling_is_a_noop_without_rope(self):
        """Self-attention keys carry the same position VALUES as the
        queries, so adding a constant offset to every position (rope off)
        must not change the output — comparing q position values against
        key INDICES used to let offset rows attend their own future."""
        from repro.models import attention as A
        from repro.models.model_api import ModelConfig
        cfg = ModelConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=100, ffn_kind="gelu",
                          dtype=jnp.float32)
        p = A.init_attn_params(jax.random.key(4), cfg, jnp.float32)
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(2, 6, 64)).astype(np.float32))
        base_pos = jnp.arange(6)[None, :]
        a, _ = A.attention(p, x, cfg, quant=QuantConfig(mode="off"),
                           positions=base_pos, causal=True, window=3,
                           use_rope=False)
        b, _ = A.attention(p, x, cfg, quant=QuantConfig(mode="off"),
                           positions=base_pos + 10, causal=True, window=3,
                           use_rope=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestChunkedBranchRaggedPositions:
    """Regression (PR 6): `_q_chunked_attention` ignored `positions` and
    masked every row with the contiguous ``q_offset + arange`` ladder, so
    a left-padded batch long enough to overflow the direct threshold
    (s * kv_len > 512 * 512 routes to the q-chunked branch) attended with
    the wrong causal/window masks.  Pure per-row position SHIFTS are
    mask-invariant in self-attention (keys carry the same values), so the
    discriminating input must REPEAT pad positions — left-padding with a
    run of equal pad slots."""

    @staticmethod
    def _ragged_positions(s, pad):
        """Row 0 contiguous; row 1 left-padded: `pad` repeated 0-positions
        then 1..s-pad (non-contiguous — repeated values)."""
        padded = jnp.concatenate([
            jnp.zeros((pad,), jnp.int32),
            jnp.arange(1, s - pad + 1, dtype=jnp.int32)])
        return jnp.stack([jnp.arange(s, dtype=jnp.int32), padded])

    def test_chunked_mask_matches_positions_mask_semantics(self):
        """Unit: q-chunked output equals the `positions_mask` +
        `_direct_attention` oracle on a repeated-pad ragged batch, and
        differs from the old contiguous-ladder masking (`positions=None`)
        — i.e. the test actually discriminates."""
        from repro.models import attention as A
        rng = np.random.default_rng(7)
        b, s, kvh, g, hd = 2, 32, 2, 2, 8
        qv = jnp.asarray(rng.normal(size=(b, s, kvh, g, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, kvh, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, kvh, hd)).astype(np.float32))
        positions = self._ragged_positions(s, pad=12)
        quant = QuantConfig(mode="off")
        scale = hd ** -0.5
        mask = A.positions_mask(positions, s, s, True, 8)
        want = A._direct_attention(qv, k, v, mask[:, None, None], quant,
                                   scale)
        got = A._q_chunked_attention(qv, k, v, q_offset=0, causal=True,
                                     window=8, chunk=8, scale=scale,
                                     positions=positions)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-6, atol=2e-6)
        old = A._q_chunked_attention(qv, k, v, q_offset=0, causal=True,
                                     window=8, chunk=8, scale=scale,
                                     positions=None)
        assert np.abs(np.asarray(got) - np.asarray(old)).max() > 1e-3

    def test_left_padded_batch_over_direct_threshold(self, monkeypatch):
        """End-to-end through `quant.datapath.attention`: s = 576 puts
        s * kv_len = 331776 over the 512 * 512 direct threshold, so the
        q-chunked branch runs for real; its output must match the
        force-direct oracle on the same left-padded batch."""
        from repro.models import attention as A
        from repro.models.model_api import ModelConfig
        cfg = ModelConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                          d_ff=64, vocab=100, ffn_kind="gelu",
                          dtype=jnp.float32)
        s = 576
        quant = QuantConfig(mode="off")
        dp = quant.datapath
        assert not dp._attention_use_direct(None, s, s), \
            "shape no longer overflows the direct threshold — grow s"
        p = A.init_attn_params(jax.random.key(6), cfg, jnp.float32)
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.normal(size=(2, s, 32)).astype(np.float32))
        positions = self._ragged_positions(s, pad=200)

        chunked, _ = A.attention(p, x, cfg, quant=quant, positions=positions,
                                 causal=True, window=64, use_rope=False,
                                 chunk=64)
        monkeypatch.setattr(type(dp), "_attention_use_direct",
                            lambda self, qv, ss, kv: True)
        direct, _ = A.attention(p, x, cfg, quant=quant, positions=positions,
                                causal=True, window=64, use_rope=False,
                                chunk=64)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                                   rtol=2e-5, atol=2e-5)


class TestKernelModeConsumesPackedPlanes:
    def test_no_dequantize_in_traced_program(self, monkeypatch):
        """mxint_linear eats the int8 planes: tracing the kernel-mode
        forward never calls `dequantize` (the packed-mode XLA path does).
        The spy sits on repro.core.quantize — the module attribute the
        datapath backends resolve at call time."""
        import importlib
        Q = importlib.import_module("repro.core.quantize")
        m_sim, m_ker, params, packed = _models(DEIT_MICRO, n_classes=10)
        imgs = _images(1, DEIT_MICRO.image_size)

        calls = []
        orig = Q.dequantize

        def spy(*a, **k):
            calls.append(1)
            return orig(*a, **k)

        monkeypatch.setattr(Q, "dequantize", spy)
        jaxpr = jax.make_jaxpr(m_ker.logits)(packed, imgs)
        assert not calls, "kernel mode must not dequantize packed weights"
        assert "pallas_call" in str(jaxpr)

        m_packed = build_model(dataclasses.replace(
            DEIT_MICRO, n_layers=2, n_classes=10,
            quant=QuantConfig(mode="packed", quantize_nonlinear=True)))
        jax.make_jaxpr(m_packed.logits)(packed, imgs)
        assert calls, "packed mode still uses the fused XLA dequant"

    def test_packed_planes_are_int8(self):
        _, _, _, packed = _models(DEIT_MICRO, n_classes=10)
        n_planes = 0
        for leaf in jax.tree_util.tree_leaves(
                packed, is_leaf=lambda l: isinstance(l, MXTensor)):
            if isinstance(leaf, MXTensor):
                assert leaf.mantissa.dtype == jnp.int8
                assert leaf.exponent.dtype == jnp.int8
                n_planes += 1
        assert n_planes > 0


class TestKernelModeConfig:
    def test_emulate_baselines_rejected(self):
        with pytest.raises(ValueError):
            QuantConfig(mode="kernel", emulate="int")
        with pytest.raises(ValueError):
            QuantConfig(mode="kernel", quantize_nonlinear=True,
                        nl_emulate="fixedpoint")

    def test_unknown_mode_still_rejected(self):
        with pytest.raises(ValueError):
            QuantConfig(mode="pallas")


class TestViTServingEngine:
    def test_classify_partial_batch_padding(self):
        cfg = dataclasses.replace(DEIT_MICRO, n_layers=2, n_classes=10,
                                  quant=KERNEL)
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        eng = ViTServingEngine(model, params,
                               ServeConfig(batch=4, pack_weights=True,
                                           weight_fmt=KERNEL.weight_fmt))
        imgs = _images(6, DEIT_MICRO.image_size, seed=5)   # 4 + partial 2
        labels, logits = eng.classify(imgs)
        assert labels.shape == (6,)
        assert logits.shape == (6, 10)
        # chunking must not change per-image results
        l2, _ = eng.classify(imgs[4:])
        np.testing.assert_array_equal(np.asarray(labels[4:]),
                                      np.asarray(l2))

    def test_make_engine_dispatches_on_family(self):
        cfg = dataclasses.replace(DEIT_MICRO, n_layers=2, n_classes=10)
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        eng = make_engine(model, params, ServeConfig(batch=2))
        assert isinstance(eng, ViTServingEngine)
