"""Datapath backend API: registry, backend×op parity grid, composites.

The redesign moved every ``q.mode`` decision behind ``q.datapath``
(DESIGN.md §12).  These tests pin the seam three ways:

  1. PARITY GRID — for every backend×op cell, the refactored dispatch
     must reproduce the PRE-REFACTOR oracle bit-for-bit.  The oracles
     below are verbatim copies of the old inline ``models/layers.py``
     branches (QDQ helpers, nonlinear datapath routing, emulation
     baselines), so a behavioral drift in any backend shows up as a
     bitwise diff against frozen reference code.
  2. COMPOSITE CONTRACT — ``layernorm_linear`` fused (pallas_kernel)
     equals the unfused two-op sequence exactly (array_equal), for LN
     and RMS variants, with and without bias, f32 and bf16.
  3. SEAM ENFORCEMENT — tools/check_dispatch.py runs clean in tier-1,
     the registry resolves every mode to the right backend exactly once
     per config, and unknown modes fail loudly.
"""
import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mx_types import QuantConfig
from repro.core import nonlinear as nl
from repro.core.quantize import (fake_quant, fp8_e4m3_qdq, pack_weight,
                                 per_tensor_int_qdq)
from repro.models import layers as L
from repro.models.model_api import Param

ROOT = Path(__file__).resolve().parents[1]

MODES = ("off", "fake", "sim", "packed", "kernel")


def _q(mode, **kw):
    return QuantConfig(mode=mode, **kw)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return {
        "x": jnp.asarray(rng.normal(size=(3, 37, 64)).astype(np.float32)),
        "w": jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32) * 0.1),
        "b": jnp.asarray(rng.normal(size=(48,)).astype(np.float32)),
        "g": jnp.asarray(rng.normal(size=(64,)).astype(np.float32)),
        "beta": jnp.asarray(rng.normal(size=(64,)).astype(np.float32)),
    }


# ---------------------------------------------------------------------------
# pre-refactor oracles: verbatim ports of the old models/layers.py branches
# ---------------------------------------------------------------------------
def _oracle_qdq_weight(w, q):
    if q.mode in ("fake", "sim"):
        if q.emulate == "int":
            return per_tensor_int_qdq(w, q.weight_fmt.mant_bits)
        if q.emulate == "fp8":
            return fp8_e4m3_qdq(w)
        return fake_quant(w, q.weight_fmt.mant_bits,
                          q.weight_fmt.block_size, 0)
    return w


def _oracle_qdq_act(x, q):
    if q.mode in ("fake", "sim"):
        if q.emulate == "int":
            return per_tensor_int_qdq(x, q.act_fmt.mant_bits)
        if q.emulate == "fp8":
            return fp8_e4m3_qdq(x)
        return fake_quant(x, q.act_fmt.mant_bits, q.act_fmt.block_size, -1)
    return x


def _oracle_linear(x, w, b, q):
    wf = _oracle_qdq_weight(w, q).astype(x.dtype)
    y = jnp.einsum("...k,kn->...n", _oracle_qdq_act(x, q), wf)
    return y if b is None else y + b.astype(y.dtype)


def _nl_on(q, op):
    return (q.enabled and q.quantize_nonlinear and
            q.mode in ("sim", "packed", "kernel") and op in q.nl_ops)


def _nl_em(q, op):
    return q.nl_emulate if _nl_on(q, op) else None


def _oracle_layernorm(x, g, beta, q, eps=1e-6):
    if _nl_em(q, "layernorm") == "fixedpoint":
        return nl.fixedpoint_layernorm(x.astype(jnp.float32), g, beta,
                                       bits=8, eps=eps).astype(x.dtype)
    if _nl_on(q, "layernorm"):
        return nl.layernorm_value(x.astype(jnp.float32), g, beta,
                                  q.nonlinear, q.act_fmt).astype(x.dtype)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * g + beta).astype(x.dtype)


def _oracle_rmsnorm(x, g, q, eps=1e-6):
    if _nl_em(q, "layernorm") == "fixedpoint":
        xf = nl._fixed_point_qdq(x.astype(jnp.float32), 8)
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (nl._fixed_point_qdq(y, 8) * g).astype(x.dtype)
    if _nl_on(q, "layernorm"):
        return nl.layernorm_value(x.astype(jnp.float32), g, None,
                                  q.nonlinear, q.act_fmt,
                                  rms_only=True).astype(x.dtype)
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * g).astype(x.dtype)


def _oracle_act(x, kind, q):
    em = _nl_em(q, "gelu")
    if em == "fixedpoint":
        return nl.fixedpoint_gelu(x.astype(jnp.float32)).astype(x.dtype)
    if em == "relu6":
        return nl.relu6_gelu(x.astype(jnp.float32)).astype(x.dtype)
    if _nl_on(q, "gelu"):
        f = {"gelu": nl.gelu_value, "silu": nl.silu_value}[kind]
        return f(x.astype(jnp.float32), q.nonlinear,
                 q.act_fmt).astype(x.dtype)
    return {"gelu": lambda v: jax.nn.gelu(v, approximate=False),
            "silu": jax.nn.silu}[kind](x)


def _oracle_softmax(x, q, axis=-1):
    if _nl_em(q, "softmax") in ("fixedpoint", "relu6"):
        return nl.fixedpoint_softmax(x.astype(jnp.float32),
                                     axis=axis).astype(x.dtype)
    if _nl_on(q, "softmax"):
        return nl.softmax_value(x.astype(jnp.float32), q.nonlinear,
                                q.act_fmt, axis=axis).astype(x.dtype)
    return jax.nn.softmax(x, axis=axis)


# ---------------------------------------------------------------------------
# registry / resolution
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_mode_to_backend_mapping(self):
        names = {m: _q(m).datapath.name for m in MODES}
        assert names == {"off": "xla_float", "fake": "xla_float",
                         "sim": "mxint_sim", "packed": "mxint_sim",
                         "kernel": "pallas_kernel"}

    def test_datapath_is_cached_per_config(self):
        q = _q("sim")
        assert q.datapath is q.datapath          # cached_property
        # same mode -> same singleton across configs
        assert q.datapath is _q("sim", quantize_nonlinear=True).datapath

    def test_qdq_capability_split(self):
        assert not _q("off").datapath.qdq_linears
        assert _q("fake").datapath.qdq_linears
        assert _q("sim").datapath.qdq_linears
        assert not _q("packed").datapath.qdq_linears
        assert not _q("kernel").datapath.qdq_linears

    def test_unknown_mode_fails_loudly(self):
        """QuantConfig validation rejects unknown modes first; a config
        that somehow carries one (e.g. a foreign config object) still
        fails loudly at the registry."""
        import types
        from repro.datapath import resolve
        with pytest.raises(ValueError, match="unknown quant mode"):
            dataclasses.replace(_q("off"), mode="tpu_v7")
        with pytest.raises(ValueError, match="no datapath backend"):
            resolve(types.SimpleNamespace(mode="tpu_v7"))

    def test_double_registration_rejected(self):
        from repro.datapath import register_backend, backends
        with pytest.raises(ValueError, match="already has backend"):
            register_backend("sim", backends()["sim"])

    def test_composite_hook_presence(self):
        """Only pallas_kernel provides the fused LN->linear composite;
        callers fall back to the two-op sequence everywhere else."""
        for m in ("off", "fake", "sim", "packed"):
            assert _q(m).datapath.layernorm_linear is None
        assert callable(_q("kernel").datapath.layernorm_linear)

    def test_fuses_norm_linear_predicate(self):
        """Blocks hoist the norm unless fusion actually engages: only
        kernel mode WITH the MXInt LN datapath fuses, and psum/row
        sharded planes decline (the contraction shard never sees the
        full row)."""
        q_on = _q("kernel", quantize_nonlinear=True)
        assert q_on.datapath.fuses_norm_linear(q_on)
        q_float_ln = _q("kernel", quantize_nonlinear=True,
                        nl_ops=("softmax",))
        assert not q_float_ln.datapath.fuses_norm_linear(q_float_ln)
        for m in ("off", "fake", "sim", "packed"):
            q = _q(m, quantize_nonlinear=True)
            assert not q.datapath.fuses_norm_linear(q)
        # psum-sharded planes decline per-weight
        w = pack_weight(jnp.ones((64, 48), jnp.float32), q_on.weight_fmt,
                        axis=0)
        psum = Param(w._replace(tp_axis="model", tp_mode="psum"),
                     ("embed", "mlp"))
        gather = Param(w._replace(tp_axis="model", tp_mode="gather"),
                       ("embed", "mlp"))
        x = jnp.ones((4, 64), jnp.float32)
        assert not q_on.datapath.fuses_norm_linear(q_on, x, psum)
        assert q_on.datapath.fuses_norm_linear(q_on, x, gather)


# ---------------------------------------------------------------------------
# backend x op parity grid vs the pre-refactor oracles
# ---------------------------------------------------------------------------
QUANT_VARIANTS = [
    ("plain", {}),
    ("nl", {"quantize_nonlinear": True}),
    ("nl_subset", {"quantize_nonlinear": True, "nl_ops": ("layernorm",)}),
]


class TestParityGrid:
    @pytest.mark.parametrize("mode", ("off", "fake", "sim", "packed"))
    @pytest.mark.parametrize("variant,kw", QUANT_VARIANTS)
    def test_linear(self, data, mode, variant, kw):
        q = _q(mode, **kw)
        got = L.linear(data["x"], Param(data["w"], ("embed", "mlp")),
                       Param(data["b"], ("mlp",)), q=q)
        want = _oracle_linear(data["x"], data["w"], data["b"], q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("mode", ("fake", "sim"))
    @pytest.mark.parametrize("emulate", ("int", "fp8"))
    def test_linear_emulate_baselines(self, data, mode, emulate):
        q = _q(mode, emulate=emulate)
        got = L.linear(data["x"], Param(data["w"], ("embed", "mlp")),
                       None, q=q)
        want = _oracle_linear(data["x"], data["w"], None, q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize("variant,kw", QUANT_VARIANTS)
    @pytest.mark.parametrize("op", ("layernorm", "rmsnorm"))
    def test_norms(self, data, mode, variant, kw, op):
        q = _q(mode, **kw)
        if op == "layernorm":
            got = L.layernorm(data["x"], Param(data["g"], ("embed",)),
                              Param(data["beta"], ("embed",)), q=q)
            want = _oracle_layernorm(data["x"], data["g"], data["beta"], q)
        else:
            got = L.rmsnorm(data["x"], Param(data["g"], ("embed",)), q=q)
            want = _oracle_rmsnorm(data["x"], data["g"], q)
        # 'kernel' has no single-op pre-refactor XLA oracle — its contract
        # is bitwise equality with 'sim' (the kernel-vs-sim exactness
        # tests); assert THAT here instead
        if mode == "kernel":
            qs = _q("sim", **kw)
            want = (_oracle_layernorm(data["x"], data["g"], data["beta"], qs)
                    if op == "layernorm"
                    else _oracle_rmsnorm(data["x"], data["g"], qs))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize("variant,kw", QUANT_VARIANTS)
    @pytest.mark.parametrize("kind", ("gelu", "silu"))
    def test_act(self, data, mode, variant, kw, kind):
        q = _q(mode, **kw)
        got = L.act_fn(data["x"], kind, q)
        ref_q = _q("sim", **kw) if mode == "kernel" else q
        want = _oracle_act(data["x"], kind, ref_q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize("variant,kw", QUANT_VARIANTS)
    def test_softmax(self, data, mode, variant, kw):
        q = _q(mode, **kw)
        x = data["x"] * 4.0
        got = L.softmax(x, q)
        ref_q = _q("sim", **kw) if mode == "kernel" else q
        want = _oracle_softmax(x, ref_q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_softmax_non_trailing_axis_kernel_routes_sim(self, data):
        q = _q("kernel", quantize_nonlinear=True)
        got = L.softmax(data["x"], q, axis=1)
        want = _oracle_softmax(data["x"], _q("sim", quantize_nonlinear=True),
                               axis=1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("nl_emulate", ("fixedpoint", "relu6"))
    @pytest.mark.parametrize("op", ("layernorm", "gelu", "softmax"))
    def test_nl_emulate_baselines(self, data, nl_emulate, op):
        """Tables II-IV baselines route exactly as the old inline
        branches did (fixedpoint LN, fixedpoint/relu6 GELU + softmax)."""
        q = _q("sim", quantize_nonlinear=True, nl_emulate=nl_emulate)
        if op == "layernorm":
            got = L.layernorm(data["x"], Param(data["g"], ("embed",)),
                              Param(data["beta"], ("embed",)), q=q)
            want = _oracle_layernorm(data["x"], data["g"], data["beta"], q)
        elif op == "gelu":
            got = L.act_fn(data["x"], "gelu", q)
            want = _oracle_act(data["x"], "gelu", q)
        else:
            got = L.softmax(data["x"], q)
            want = _oracle_softmax(data["x"], q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_mlstm_exp_gate_routing(self):
        """sim/packed + quantized softmax -> pow2 LUT datapath; everything
        else -> float exp (verbatim old recurrent.py gate)."""
        x = jnp.asarray(np.linspace(-3.0, 0.0, 32, dtype=np.float32))
        _LOG2E = 1.4426950408889634
        q_on = _q("sim", quantize_nonlinear=True)
        want = nl.exp_datapath(x * _LOG2E, q_on.nonlinear.softmax_r_bits)
        np.testing.assert_array_equal(
            np.asarray(q_on.datapath.exp(x, q=q_on)), np.asarray(want))
        for q_off in (_q("off"), _q("fake"),
                      _q("kernel", quantize_nonlinear=True),
                      _q("sim", quantize_nonlinear=True, nl_ops=("gelu",))):
            np.testing.assert_array_equal(
                np.asarray(q_off.datapath.exp(x, q=q_off)),
                np.asarray(jnp.exp(x)))


# ---------------------------------------------------------------------------
# fused LN -> linear composite: bit-identical to the unfused sequence
# ---------------------------------------------------------------------------
class TestFusedLayernormLinear:
    def _params(self, data, q, bias=True):
        wq = pack_weight(data["w"].astype(jnp.float32), q.weight_fmt, axis=0)
        return (Param(wq, ("embed", "mlp")),
                Param(data["b"], ("mlp",)) if bias else None)

    @pytest.mark.parametrize("rms_only", (False, True))
    @pytest.mark.parametrize("bias", (True, False))
    def test_fused_equals_unfused_kernel(self, data, rms_only, bias):
        q = _q("kernel", quantize_nonlinear=True)
        w, b = self._params(data, q, bias)
        g = Param(data["g"], ("embed",))
        beta = None if rms_only else Param(data["beta"], ("embed",))
        got = L.layernorm_linear(data["x"], g, beta, w, b, q=q,
                                 rms_only=rms_only)
        h = (L.rmsnorm(data["x"], g, q=q) if rms_only
             else L.layernorm(data["x"], g, beta, q=q))
        want = L.linear(h, w, b, q=q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fused_equals_unfused_bf16(self, data):
        """The VMEM scratch holds the model dtype, so even the unfused
        path's f32 -> bf16 -> f32 HBM round-trip is reproduced."""
        q = _q("kernel", quantize_nonlinear=True)
        w, b = self._params(data, q)
        g = Param(data["g"], ("embed",))
        beta = Param(data["beta"], ("embed",))
        xb = data["x"].astype(jnp.bfloat16)
        got = L.layernorm_linear(xb, g, beta, w, b, q=q)
        want = L.linear(L.layernorm(xb, g, beta, q=q), w, b, q=q)
        np.testing.assert_array_equal(
            np.asarray(got.astype(jnp.float32)),
            np.asarray(want.astype(jnp.float32)))

    def test_fused_matches_sim_two_op(self, data):
        """Cross-backend: fused kernel composite == the sim oracle's
        norm-then-linear on packed planes (the DeiT parity argument)."""
        qk = _q("kernel", quantize_nonlinear=True)
        qs = _q("packed", quantize_nonlinear=True)
        w, b = self._params(data, qk)
        g = Param(data["g"], ("embed",))
        beta = Param(data["beta"], ("embed",))
        got = L.layernorm_linear(data["x"], g, beta, w, b, q=qk)
        want = L.layernorm_linear(data["x"], g, beta, w, b, q=qs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fused_lowers_single_pallas_call(self, data):
        q = _q("kernel", quantize_nonlinear=True)
        w, b = self._params(data, q)
        g = Param(data["g"], ("embed",))
        beta = Param(data["beta"], ("embed",))
        fused = str(jax.make_jaxpr(
            lambda x: L.layernorm_linear(x, g, beta, w, b, q=q))(data["x"]))
        unfused = str(jax.make_jaxpr(
            lambda x: L.linear(L.layernorm(x, g, beta, q=q), w, b, q=q))(
                data["x"]))
        assert fused.count("pallas_call") == 1
        assert unfused.count("pallas_call") == 2

    def test_float_norm_falls_back_to_two_op(self, data):
        """kernel mode WITHOUT quantized LN: no fused kernel exists; the
        composite must fall back and still match the sequence."""
        q = _q("kernel", quantize_nonlinear=True, nl_ops=("softmax",))
        w, b = self._params(data, q)
        g = Param(data["g"], ("embed",))
        beta = Param(data["beta"], ("embed",))
        got = L.layernorm_linear(data["x"], g, beta, w, b, q=q)
        want = L.linear(L.layernorm(data["x"], g, beta, q=q), w, b, q=q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("mode", ("off", "fake", "sim", "packed"))
    def test_layers_composite_wrapper_on_xla_backends(self, data, mode):
        """Backends without the hook: layernorm_linear IS the two-op
        sequence (same trace, bitwise)."""
        q = _q(mode, quantize_nonlinear=True)
        w = Param(data["w"], ("embed", "mlp"))
        b = Param(data["b"], ("mlp",))
        g = Param(data["g"], ("embed",))
        beta = Param(data["beta"], ("embed",))
        got = L.layernorm_linear(data["x"], g, beta, w, b, q=q)
        want = L.linear(L.layernorm(data["x"], g, beta, q=q), w, b, q=q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_rmsnorm_linear_wrapper(self, data):
        q = _q("kernel", quantize_nonlinear=True)
        w, _ = self._params(data, q, bias=False)
        g = Param(data["g"], ("embed",))
        got = L.rmsnorm_linear(data["x"], g, w, q=q)
        want = L.linear(L.rmsnorm(data["x"], g, q=q), w, None, q=q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# seam enforcement
# ---------------------------------------------------------------------------
class TestDispatchSeam:
    def test_no_mode_branching_outside_datapath(self):
        import sys
        sys.path.insert(0, str(ROOT / "tools"))
        try:
            import check_dispatch
        finally:
            sys.path.pop(0)
        assert check_dispatch.check(ROOT) == []

    def test_layers_are_thin_wrappers(self):
        """models/layers.py must not regrow dispatch: its source carries
        no 'mode' token at all outside docstrings/comments."""
        import ast, inspect
        src = inspect.getsource(L)
        for node in ast.walk(ast.parse(src)):
            if isinstance(node, ast.Attribute):
                assert node.attr != "mode", \
                    f"layers.py touches .mode at line {node.lineno}"
