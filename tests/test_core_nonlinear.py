"""Tests for the bit-accurate MXInt non-linear datapaths (paper §III-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import (MXFormat, NonlinearConfig, quantize, dequantize)
from repro.core import nonlinear as nl
from repro.core import luts

pytestmark = pytest.mark.slow    # hypothesis-heavy property suite (fast CI lane skips)

FMT = MXFormat(mant_bits=8, block_size=16)
CFG = NonlinearConfig()


def _rand(shape, seed=0, scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)) * scale


# ---------------------------------------------------------------------------
# LayerNorm (Fig 3, Eq 2-9)
# ---------------------------------------------------------------------------
class TestLayerNorm:
    def test_close_to_float_reference(self):
        x = _rand((8, 192))
        g, b = jnp.ones((192,)), jnp.zeros((192,))
        got = nl.layernorm_value(x, g, b, CFG, FMT)
        ref = (x - x.mean(-1, keepdims=True)) / jnp.sqrt(
            x.var(-1, keepdims=True) + 1e-6)
        cos = float(jnp.vdot(got.ravel(), ref.ravel()) /
                    (jnp.linalg.norm(got) * jnp.linalg.norm(ref)))
        assert cos > 0.999

    def test_exponent_invariance(self):
        """Paper Eq. 5-7: LayerNorm output must be invariant to the shared
        exponent lambda — scaling the input by powers of two changes nothing
        (that is WHY the integer-only datapath is exact w.r.t. lambda)."""
        x = _rand((4, 64))
        g, b = jnp.ones((64,)), jnp.zeros((64,))
        y1 = nl.layernorm_value(x, g, b, CFG, FMT)
        y2 = nl.layernorm_value(x * 16.0, g, b, CFG, FMT)   # 2^4 scale
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=0, atol=1e-5)

    def test_rms_only_variant(self):
        x = _rand((4, 64), seed=5)
        g = jnp.ones((64,))
        got = nl.layernorm_value(x, g, None, CFG, FMT, rms_only=True)
        ref = x / jnp.sqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6)
        assert float(jnp.max(jnp.abs(got - ref))) < 0.2

    def test_constant_row_guard(self):
        """Var -> 0 corner (paper ignores; we clamp)."""
        x = jnp.full((1, 64), 2.5)
        g, b = jnp.ones((64,)), jnp.zeros((64,))
        y = nl.layernorm_value(x, g, b, CFG, FMT)
        assert np.isfinite(np.asarray(y)).all()

    def test_lut_bitwidth_dse_monotone(self):
        """Fig 4 analogue: more LUT bits -> error weakly decreases, and the
        paper's knee (>=4 bits OK) is reproduced."""
        x = _rand((16, 192), seed=7)
        g, b = jnp.ones((192,)), jnp.zeros((192,))
        ref = (x - x.mean(-1, keepdims=True)) / jnp.sqrt(
            x.var(-1, keepdims=True) + 1e-6)
        errs = {}
        for bits in (2, 3, 4, 6, 8):
            cfg = NonlinearConfig(ln_lut_bits=bits)
            got = nl.layernorm_value(x, g, b, cfg, FMT)
            errs[bits] = float(jnp.mean(jnp.abs(got - ref)))
        assert errs[8] <= errs[4] <= errs[2] * 1.05
        assert errs[4] < 0.05   # knee: 4 bits is already near-lossless


# ---------------------------------------------------------------------------
# GELU (Fig 5-8, Eq 12)
# ---------------------------------------------------------------------------
class TestGELU:
    def test_close_to_exact(self):
        x = _rand((8, 128))
        got = nl.gelu_value(x, CFG, FMT)
        ref = jax.nn.gelu(x, approximate=False)
        assert float(jnp.max(jnp.abs(got - ref))) < 0.15
        assert float(jnp.mean(jnp.abs(got - ref))) < 0.03

    def test_relu_tails(self):
        """|x| >= a must behave as identity / zero (Eq 12)."""
        cfg = NonlinearConfig()
        big = jnp.asarray([[4.0, 8.0, 16.0, 5.5] * 4])
        got = nl.gelu_value(big, cfg, FMT)
        np.testing.assert_allclose(np.asarray(got), np.asarray(big),
                                   rtol=2 ** -6)
        neg = -big
        got_n = nl.gelu_value(neg, cfg, FMT)
        np.testing.assert_array_equal(np.asarray(got_n), 0.0)

    def test_exponent_forwarding(self):
        """Output MXTensor reuses the input block exponents."""
        x = quantize(_rand((2, 32), seed=3), FMT)
        y = nl.mxint_gelu(x, CFG)
        np.testing.assert_array_equal(np.asarray(y.exponent),
                                      np.asarray(x.exponent))

    def test_domain_dse_fig7(self):
        """Fig 7 analogue: domain a=3 beats a=1 (truncation error) and is
        comparable to a=4 for standard-normal-ish inputs."""
        x = _rand((32, 128), seed=11, scale=1.5)
        ref = jax.nn.gelu(x, approximate=False)
        errs = {}
        for a in (1.0, 2.0, 3.0, 4.0):
            cfg = NonlinearConfig(gelu_domain=a, gelu_lut_bits=8)
            errs[a] = float(jnp.mean(jnp.abs(nl.gelu_value(x, cfg, FMT) - ref)))
        assert errs[3.0] < errs[1.0]
        assert errs[3.0] < 0.02

    def test_silu_variant(self):
        x = _rand((8, 128), seed=13, scale=2.0)
        got = nl.silu_value(x, CFG, FMT)
        ref = jax.nn.silu(x)
        assert float(jnp.mean(jnp.abs(got - ref))) < 0.05


# ---------------------------------------------------------------------------
# Softmax (Eq 14-20)
# ---------------------------------------------------------------------------
class TestSoftmax:
    def test_close_to_float_reference(self):
        x = _rand((8, 197), seed=17)     # ViT token count, non-divisible
        got = nl.softmax_value(x, CFG, FMT)
        ref = jax.nn.softmax(x, -1)
        assert float(jnp.max(jnp.abs(got - ref))) < 0.05

    def test_rows_sum_to_one(self):
        x = _rand((16, 64), seed=19, scale=8.0)
        got = nl.softmax_value(x, CFG, FMT)
        np.testing.assert_allclose(np.asarray(got.sum(-1)), 1.0, atol=0.02)

    def test_argmax_preserved(self):
        """What matters for attention + the paper's top-1 metric."""
        x = _rand((64, 128), seed=23, scale=4.0)
        got = nl.softmax_value(x, CFG, FMT)
        ref = jax.nn.softmax(x, -1)
        agree = float(jnp.mean((jnp.argmax(got, -1) == jnp.argmax(ref, -1))
                               .astype(jnp.float32)))
        assert agree > 0.98

    def test_r_bitwidth_dse_fig9(self):
        """Fig 9 analogue: r-bitwidth error knee at 2 bits."""
        x = _rand((32, 64), seed=29)
        ref = jax.nn.softmax(x, -1)
        errs = {}
        for rb in (1, 2, 4, 6):
            cfg = NonlinearConfig(softmax_r_bits=rb)
            errs[rb] = float(jnp.mean(jnp.abs(
                nl.softmax_value(x, cfg, FMT) - ref)))
        assert errs[6] <= errs[2] <= errs[1]
        assert errs[2] < 0.01

    def test_translation_invariance(self):
        """softmax(x + c) == softmax(x) survives the datapath (max-subtract
        happens in the shared-exponent domain)."""
        x = _rand((4, 64), seed=31)
        a = nl.softmax_value(x, CFG, FMT)
        # shift by an exactly-representable power of two to avoid requant noise
        b = nl.softmax_value(x + 4.0, CFG, FMT)
        # block exponents shift, so requant truncation differs slightly; the
        # invariance holds to within one output LSB plus LUT granularity.
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.05)

    def test_exp_datapath_llamacpp_identity(self):
        """2^n * LUT_pow2(r) == e^x at LUT sample points."""
        r_bits = 6
        z = jnp.asarray([-0.5, -1.25, -3.0, 0.0]) * (2 ** r_bits) / (2 ** r_bits)
        got = nl.exp_datapath(z, r_bits)
        ref = jnp.exp2(z)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2 ** -r_bits * 0.8)


# ---------------------------------------------------------------------------
# related-work emulations used by the comparison tables
# ---------------------------------------------------------------------------
class TestRelatedWorkBaselines:
    def test_relu6_gelu_is_bad_for_vits(self):
        """Table III: SDA's ReLU6 loses accuracy on negative inputs."""
        x = _rand((8, 128), seed=37)
        ref = jax.nn.gelu(x, approximate=False)
        sda = nl.relu6_gelu(x)
        ours = nl.gelu_value(x, CFG, FMT)
        assert float(jnp.mean(jnp.abs(ours - ref))) < \
            float(jnp.mean(jnp.abs(sda - ref)))

    def test_fixedpoint_ops_finite(self):
        x = _rand((4, 64), seed=41)
        for f in (lambda v: nl.fixedpoint_layernorm(v, None, None),
                  nl.fixedpoint_gelu, nl.fixedpoint_softmax):
            assert np.isfinite(np.asarray(f(x))).all()


# ---------------------------------------------------------------------------
# LUT builders
# ---------------------------------------------------------------------------
class TestLUTs:
    def test_rsqrt_table_values(self):
        lut = np.asarray(luts.rsqrt_lut(6))
        assert lut.shape == (64,)
        u = 0.5 + 1.5 * (np.arange(64) + 0.5) / 64
        np.testing.assert_allclose(lut, 1 / np.sqrt(u), rtol=1e-6)

    def test_pow2_table_truncation_keeps_max_exact(self):
        lut = np.asarray(luts.pow2_lut(2))
        assert lut[0] == 1.0          # r = 0 -> exactly 1 (softmax max elem)
        assert lut.shape == (4,)

    @settings(max_examples=25, deadline=None)
    @given(bits=st.integers(min_value=1, max_value=8),
           seed=st.integers(min_value=0, max_value=999))
    def test_property_pow2_lut_error_bound(self, bits, seed):
        """LUT_pow2 truncation error < 2^(1/2^bits) - 1 relative."""
        rng = np.random.default_rng(seed)
        r = jnp.asarray(rng.uniform(0, 1, size=64).astype(np.float32))
        got = np.asarray(jnp.take(luts.pow2_lut(bits),
                                  luts.pow2_index(r, bits)))
        ref = np.exp2(np.asarray(r))
        rel = np.abs(got - ref) / ref
        assert np.all(rel <= 2 ** (1 / 2 ** bits) - 1 + 1e-6)

    def test_table_bytes_area_proxy(self):
        # paper Table VI: vanilla softmax LUT 16 entry-bits vs ours 2 ->
        # 2^14 x table size reduction
        assert luts.table_bytes(2 ** 16) / luts.table_bytes(2 ** 2) == 2 ** 14
