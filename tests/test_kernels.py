"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles.

Every kernel is swept over shapes and dtypes and compared against ref.py.
LayerNorm / softmax / GELU kernels must match their oracles bit-for-bit
(identical op graph per row); matmul and flash attention allow accumulation-
order tolerance.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFormat, quantize
from repro.kernels import ref
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_decode)
from repro.kernels.mxint_gelu import mxint_gelu as gelu_kernel
from repro.kernels.mxint_layernorm import mxint_layernorm as ln_kernel
from repro.kernels.mxint_matmul import mxint_matmul as mm_kernel
from repro.kernels.mxint_softmax import mxint_softmax as sm_kernel
from repro.kernels import ops


def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale,
                       dtype=dtype)


# ---------------------------------------------------------------------------
# mxint_matmul
# ---------------------------------------------------------------------------
class TestMXIntMatmul:
    @pytest.mark.parametrize("m,k,n", [(8, 128, 128), (16, 256, 384),
                                       (128, 512, 128), (32, 1024, 256)])
    @pytest.mark.parametrize("w_block", [128, 256])
    def test_shape_sweep_weight_only(self, m, k, n, w_block):
        if k % w_block and w_block % k:
            pytest.skip("block/tile mismatch")
        x = _rand((m, k), seed=m + k, scale=0.5)
        w = _rand((k, n), seed=n, scale=0.1)
        wq = quantize(w, MXFormat(8, w_block), axis=0)
        got = mm_kernel(x, wq.mantissa, wq.exponent, w_block=wq.block_size,
                        bm=8, bn=128, bk=128, interpret=True)
        want = ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent,
                                    w_block=wq.block_size)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        x = _rand((16, 256), seed=1, dtype=dtype)
        w = _rand((256, 128), seed=2, scale=0.1)
        wq = quantize(w, MXFormat(6, 256), axis=0)
        got = mm_kernel(x, wq.mantissa, wq.exponent, w_block=256,
                        bm=16, bn=128, bk=256, interpret=True)
        want = ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent, w_block=256)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_quantized_activation_path(self):
        """Fig 2b full-integer datapath: kernel == oracle with act QDQ."""
        x = _rand((32, 512), seed=3, scale=2.0)
        w = _rand((512, 128), seed=4, scale=0.05)
        wq = quantize(w, MXFormat(6, 256), axis=0)
        got = mm_kernel(x, wq.mantissa, wq.exponent, w_block=256,
                        quantize_act=True, act_block=16, act_mant_bits=8,
                        bm=32, bn=128, bk=256, interpret=True)
        want = ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent, w_block=256,
                                    quantize_act=True, act_block=16,
                                    act_mant_bits=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_small_wblock_multiple_tiles(self):
        """bk < w_block: several K tiles share one exponent row."""
        x = _rand((8, 512), seed=5)
        w = _rand((512, 128), seed=6, scale=0.1)
        wq = quantize(w, MXFormat(8, 512), axis=0)
        got = mm_kernel(x, wq.mantissa, wq.exponent, w_block=512,
                        bm=8, bn=128, bk=128, interpret=True)
        want = ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent, w_block=512)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mxint_layernorm
# ---------------------------------------------------------------------------
class TestMXIntLayerNorm:
    @pytest.mark.parametrize("rows,d", [(8, 128), (32, 192), (64, 768),
                                        (128, 1024)])
    @pytest.mark.parametrize("rms_only", [False, True])
    def test_bitexact_vs_oracle(self, rows, d, rms_only):
        x = _rand((rows, d), seed=rows + d, scale=3.0)
        g = _rand((d,), seed=1, scale=0.5) + 1.0
        b = _rand((d,), seed=2, scale=0.1)
        got = ln_kernel(x, g, b, rms_only=rms_only,
                        block_rows=min(rows, 32), interpret=True)
        want = ref.mxint_layernorm_ref(x, g, b, rms_only=rms_only)
        # 1-ulp differences allowed: XLA picks different reduction trees for
        # the (block_rows, d) kernel tile vs the full-array oracle.
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-6, atol=3e-6)

    @pytest.mark.parametrize("lut_bits", [3, 4, 5, 8])
    def test_lut_bits_sweep(self, lut_bits):
        x = _rand((16, 256), seed=9, scale=2.0)
        g, b = jnp.ones((256,)), jnp.zeros((256,))
        got = ln_kernel(x, g, b, lut_bits=lut_bits, block_rows=16,
                        interpret=True)
        want = ref.mxint_layernorm_ref(x, g, b, lut_bits=lut_bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-6, atol=3e-6)

    def test_vs_float_layernorm(self):
        x = _rand((32, 768), seed=10, scale=2.0)
        g, b = jnp.ones((768,)), jnp.zeros((768,))
        got = np.asarray(ln_kernel(x, g, b, block_rows=32, interpret=True))
        mean = np.asarray(x).mean(-1, keepdims=True)
        ref_ln = (np.asarray(x) - mean) / np.sqrt(
            np.asarray(x).var(-1, keepdims=True) + 1e-6)
        cos = np.vdot(got, ref_ln) / (np.linalg.norm(got) *
                                      np.linalg.norm(ref_ln))
        assert cos > 0.999


# ---------------------------------------------------------------------------
# mxint_softmax
# ---------------------------------------------------------------------------
class TestMXIntSoftmax:
    @pytest.mark.parametrize("rows,n", [(8, 128), (32, 197 - 5), (64, 1024)])
    @pytest.mark.parametrize("r_bits", [2, 4])
    def test_bitexact_vs_oracle(self, rows, n, r_bits):
        n = n - (n % 16) if n % 16 else n   # kernel wants divisible rows
        x = _rand((rows, n), seed=rows + n, scale=4.0)
        got = sm_kernel(x, r_bits=r_bits, block_rows=min(rows, 32),
                        interpret=True)
        want = ref.mxint_softmax_ref(x, r_bits=r_bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-6, atol=1e-7)

    def test_rows_sum_to_one(self):
        x = _rand((64, 256), seed=12, scale=6.0)
        got = np.asarray(sm_kernel(x, block_rows=64, interpret=True))
        np.testing.assert_allclose(got.sum(-1), 1.0, atol=0.02)


# ---------------------------------------------------------------------------
# mxint_gelu
# ---------------------------------------------------------------------------
class TestMXIntGELU:
    @pytest.mark.parametrize("rows,d", [(8, 128), (32, 768), (128, 3072)])
    @pytest.mark.parametrize("fn", ["gelu", "silu"])
    def test_bitexact_vs_oracle(self, rows, d, fn):
        x = _rand((rows, d), seed=rows + d, scale=3.0)
        got = gelu_kernel(x, fn=fn, block_rows=min(rows, 32), interpret=True)
        want = ref.mxint_gelu_ref(x, fn=fn)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("lut_bits,domain", [(4, 3.0), (5, 3.0),
                                                 (5, 4.0), (8, 2.0)])
    def test_dse_sweep(self, lut_bits, domain):
        x = _rand((16, 256), seed=14, scale=2.0)
        got = gelu_kernel(x, lut_bits=lut_bits, domain=domain, block_rows=16,
                          interpret=True)
        want = ref.mxint_gelu_ref(x, lut_bits=lut_bits, domain=domain)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
class TestFlashAttention:
    @pytest.mark.parametrize("sq,sk", [(128, 128), (256, 256), (128, 512)])
    def test_float_vs_exact(self, sq, sk):
        q = _rand((2, sq, 128), seed=sq, scale=0.5)
        k = _rand((2, sk, 128), seed=sk + 1, scale=0.5)
        v = _rand((2, sk, 128), seed=sk + 2)
        got = flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_mxint_exp_mode_close_to_oracle(self):
        q = _rand((2, 128, 128), seed=20, scale=0.5)
        k = _rand((2, 128, 128), seed=21, scale=0.5)
        v = _rand((2, 128, 128), seed=22)
        got = flash_attention(q, k, v, causal=True, exp_mode="mxint",
                              r_bits=2, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, exp_mode="mxint",
                                 r_bits=2)
        # blocked vs row-at-once accumulation differ (exact alpha rescale);
        # values agree to LUT granularity
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0.1, atol=0.05)

    def test_sliding_window(self):
        q = _rand((1, 256, 128), seed=30, scale=0.5)
        k = _rand((1, 256, 128), seed=31, scale=0.5)
        v = _rand((1, 256, 128), seed=32)
        got = flash_attention(q, k, v, causal=True, window=64, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, window=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_mxint_attention_close_to_float(self):
        """End check: the paper's softmax datapath keeps attention faithful."""
        q = _rand((4, 128, 128), seed=40, scale=0.3)
        k = _rand((4, 128, 128), seed=41, scale=0.3)
        v = _rand((4, 128, 128), seed=42)
        a = flash_attention(q, k, v, causal=True, exp_mode="mxint",
                            interpret=True)
        b = flash_attention(q, k, v, causal=True, exp_mode="float",
                            interpret=True)
        err = float(jnp.max(jnp.abs(a - b))) / float(jnp.max(jnp.abs(b)))
        assert err < 0.15


# ---------------------------------------------------------------------------
# mxint flash attention: the full Eq. 14-20 blocked datapath (ISSUE 3)
# ---------------------------------------------------------------------------
class TestMXIntFlashAttention:
    """flash_attention(exp_mode='mxint', quantize_scores=True) vs the
    whole-row 'paper' oracle (ref.mxint_flash_attention_ref).

    Exactness contract: when ONE k block covers the whole row (block
    boundaries align), the blocked kernel degenerates to the whole-row
    datapath — per-tile Eq. 2-3 requantization IS the row requantization,
    the online max never rescales, and the flush quantizes the fully
    normalized Eq. 20 probabilities before p @ V.  Multi-block rows keep
    a per-TILE shared-exponent alignment and an exact running rescale, so
    they match within LUT/requantization granularity only.
    """

    @pytest.mark.parametrize("causal", [True, False])
    def test_single_kblock_bit_exact_vs_paper_oracle(self, causal):
        q = _rand((2, 128, 64), seed=60, scale=0.5)
        k = _rand((2, 128, 64), seed=61, scale=0.5)
        v = _rand((2, 128, 64), seed=62)
        got = flash_attention(q, k, v, causal=causal, exp_mode="mxint",
                              quantize_scores=True, interpret=True)
        want = ref.mxint_flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_single_kblock_exact_at_256(self):
        """Causal-LM row of 256 keys in one 256-wide block: still exact."""
        q = _rand((2, 256, 64), seed=63, scale=0.5)
        k = _rand((2, 256, 64), seed=64, scale=0.5)
        v = _rand((2, 256, 64), seed=65)
        got = flash_attention(q, k, v, causal=True, exp_mode="mxint",
                              quantize_scores=True, block_k=256,
                              interpret=True)
        want = ref.mxint_flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_multiblock_tolerance_vs_paper_oracle(self):
        """Unmasked rows over 4 k blocks: per-tile lambda + online rescale
        differ from whole-row alignment only at LUT granularity."""
        q = _rand((2, 128, 64), seed=66, scale=0.5)
        k = _rand((2, 512, 64), seed=67, scale=0.5)
        v = _rand((2, 512, 64), seed=68)
        got = flash_attention(q, k, v, causal=False, exp_mode="mxint",
                              quantize_scores=True, block_k=128,
                              interpret=True)
        want = ref.mxint_flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0.15, atol=0.05)

    def test_multiblock_causal_rowwise_semantics(self):
        """Causal multi-block: the documented per-row semantics hold.

        A k tile containing a masked lane is exponent-poisoned by the
        NEG_INF fill exactly like the whole-row datapath poisons the whole
        row, so rows whose REAL keys all sit in poisoned tiles (here: q
        rows < 128, whose single real tile straddles the diagonal) track
        the whole-row oracle — loosely, because interior blocks quantize
        UNnormalized probabilities while the whole-row path quantizes the
        Eq. 20 output.  A row whose tiles are all fully real (the last
        row) sees only benign per-tile score quantization and tracks the
        same-LUT attention WITHOUT score quantization tightly."""
        q = _rand((2, 256, 64), seed=69, scale=0.5)
        k = _rand((2, 256, 64), seed=70, scale=0.5)
        v = _rand((2, 256, 64), seed=71)
        got = np.asarray(flash_attention(q, k, v, causal=True,
                                         exp_mode="mxint",
                                         quantize_scores=True, block_k=128,
                                         interpret=True))
        paper = np.asarray(ref.mxint_flash_attention_ref(q, k, v,
                                                         causal=True))
        np.testing.assert_allclose(got[:, :128], paper[:, :128],
                                   rtol=0.2, atol=0.2)
        base = np.asarray(ref.attention_ref(q, k, v, causal=True,
                                            exp_mode="mxint", r_bits=2))
        np.testing.assert_allclose(got[:, 255], base[:, 255],
                                   rtol=0.05, atol=0.01)

    def test_deit_shape_via_attention_op(self):
        """DeiT-Tiny geometry (197 tokens, head_dim 64) through the padded
        attention_op: padded keys are numerically invisible, so the result
        tracks the UNPADDED whole-row oracle up to the act-block geometry
        difference (the oracle resolves prime 197 to 1-wide blocks)."""
        q = _rand((2, 3, 197, 64), seed=72, scale=0.5)
        k = _rand((2, 3, 197, 64), seed=73, scale=0.5)
        v = _rand((2, 3, 197, 64), seed=74)
        o = ops.attention_op(q, k, v, causal=False,
                             softmax_variant="online", exp_mode="mxint",
                             quantize_scores=True)
        qf, kf, vf = (x.reshape(6, 197, 64) for x in (q, k, v))
        want = ref.mxint_flash_attention_ref(qf, kf, vf, causal=False)
        np.testing.assert_allclose(np.asarray(o.reshape(6, 197, 64)),
                                   np.asarray(want), rtol=0.2, atol=0.08)


# ---------------------------------------------------------------------------
# decode variant
# ---------------------------------------------------------------------------
def _flat_decode(q4, k4, v4):
    """Native (b, hkv, g, d) / (b, W, hkv, d) -> the flat (bh, g|W, d)
    layout the jnp oracles use."""
    b, hkv, g, d = q4.shape
    W = k4.shape[1]
    qf = q4.reshape(b * hkv, g, d)
    kf = jnp.einsum("bwhd->bhwd", k4).reshape(b * hkv, W, d)
    vf = jnp.einsum("bwhd->bhwd", v4).reshape(b * hkv, W, d)
    return qf, kf, vf


class TestFlashAttentionDecode:
    def test_float_partial_ring_vs_oracle(self):
        q = _rand((2, 2, 2, 64), seed=80, scale=0.5)     # b=2, hkv=2, g=2
        k = _rand((2, 128, 2, 64), seed=81, scale=0.5)
        v = _rand((2, 128, 2, 64), seed=82)
        valid = jnp.arange(128) <= 37
        got = flash_attention_decode(q, k, v, valid, interpret=True)
        qf, kf, vf = _flat_decode(q, k, v)
        want = ref.decode_attention_ref(qf, kf, vf, valid)
        np.testing.assert_allclose(np.asarray(got.reshape(4, 2, 64)),
                                   np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_float_multiblock_ring(self):
        q = _rand((2, 1, 4, 64), seed=83, scale=0.5)
        k = _rand((2, 256, 1, 64), seed=84, scale=0.5)
        v = _rand((2, 256, 1, 64), seed=85)
        valid = jnp.arange(256) <= 200
        got = flash_attention_decode(q, k, v, valid, block_k=128,
                                     interpret=True)
        qf, kf, vf = _flat_decode(q, k, v)
        want = ref.decode_attention_ref(qf, kf, vf, valid)
        np.testing.assert_allclose(np.asarray(got.reshape(2, 4, 64)),
                                   np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_quantized_single_block_exact_vs_paper_oracle(self):
        q = _rand((2, 2, 2, 64), seed=86, scale=0.5)
        k = _rand((2, 128, 2, 64), seed=87, scale=0.5)
        v = _rand((2, 128, 2, 64), seed=88)
        valid = jnp.arange(128) <= 37
        got = flash_attention_decode(q, k, v, valid, exp_mode="mxint",
                                     quantize_scores=True, interpret=True)
        qf, kf, vf = _flat_decode(q, k, v)
        want = ref.mxint_flash_attention_ref(
            qf, kf, vf, causal=False, key_mask=valid.astype(jnp.int32),
            scale=64 ** -0.5)
        np.testing.assert_array_equal(np.asarray(got.reshape(4, 2, 64)),
                                      np.asarray(want))

    @pytest.mark.parametrize("n_valid", [12, 32])
    def test_decode_op_padded_ring_exact(self, n_valid):
        """attention_decode_op pads W=32 -> 128 and G=2 -> 8; padding must
        be numerically invisible: the QUANTIZED result still equals the
        whole-row oracle on the unpadded ring — both for a partially
        filled ring (NEG_INF lanes poison the row exponent in BOTH paths,
        sim parity) and for a full one (sane exponents in both)."""
        q = _rand((2, 2, 2, 16), seed=89, scale=0.5)
        k = _rand((2, 32, 2, 16), seed=90, scale=0.5)
        v = _rand((2, 32, 2, 16), seed=91)
        valid = jnp.arange(32) < n_valid
        got = ops.attention_decode_op(q, k, v, valid, exp_mode="mxint",
                                      quantize_scores=True)
        qf, kf, vf = _flat_decode(q, k, v)
        want = ref.mxint_flash_attention_ref(
            qf, kf, vf, causal=False, key_mask=valid.astype(jnp.int32),
            scale=16 ** -0.5)
        np.testing.assert_array_equal(np.asarray(got.reshape(4, 2, 16)),
                                      np.asarray(want))

    def test_window_ring_layout(self):
        """Sliding-window ring: validity is the caller's slot arithmetic;
        the kernel must reproduce a dense masked softmax over the ring."""
        W = 32
        t = 40                                 # decode position, ring full
        q = _rand((2, 1, 2, 64), seed=92, scale=0.5)
        k = _rand((2, W, 1, 64), seed=93, scale=0.5)
        v = _rand((2, W, 1, 64), seed=94)
        idx = jnp.arange(W)
        slot_pos = t - jnp.mod(t - idx, W)
        valid = (slot_pos >= 0) & (slot_pos <= t) & ((t - slot_pos) < W)
        got = ops.attention_decode_op(q, k, v, valid)
        qf, kf, vf = _flat_decode(q, k, v)
        want = ref.decode_attention_ref(qf, kf, vf, valid)
        np.testing.assert_allclose(np.asarray(got.reshape(2, 2, 64)),
                                   np.asarray(want), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fallback accounting: DeiT shapes must run the Pallas kernel (ISSUE 3)
# ---------------------------------------------------------------------------
class TestAttentionOpFallbacks:
    def test_deit_shapes_reach_flash_kernel(self):
        """(b*h, 197, 64) used to fail the old shape gate and silently run
        ref.attention_ref; now it pads and runs the kernel — asserted via
        the fallback counter AND the presence of pallas_call in the traced
        program."""
        ops.reset_attention_fallbacks()
        q = _rand((1, 3, 197, 64), seed=95)
        k = _rand((1, 3, 197, 64), seed=96)
        v = _rand((1, 3, 197, 64), seed=97)
        jaxpr = jax.make_jaxpr(functools.partial(
            ops.attention_op, causal=False))(q, k, v)
        assert ops.attention_fallback_counts() == {}
        assert "pallas_call" in str(jaxpr)
        o = ops.attention_op(q, k, v, causal=False)
        np.testing.assert_allclose(
            np.asarray(o.reshape(3, 197, 64)),
            np.asarray(ref.attention_ref(q.reshape(3, 197, 64),
                                         k.reshape(3, 197, 64),
                                         v.reshape(3, 197, 64),
                                         causal=False)),
            rtol=2e-4, atol=2e-4)

    def test_pathological_head_dim_counted_and_warned_exactly_once(self):
        """One fallback event = one counter bump AND one UserWarning — a
        warn-per-head or warn-per-block regression would double-fire."""
        import warnings as W
        ops.reset_attention_fallbacks()
        q = _rand((1, 1, 8, 2064), seed=98, scale=0.1)
        k = _rand((1, 1, 8, 2064), seed=99, scale=0.1)
        v = _rand((1, 1, 8, 2064), seed=100, scale=0.1)
        with W.catch_warnings(record=True) as caught:
            W.simplefilter("always")
            o = ops.attention_op(q, k, v, causal=True)
        hits = [w for w in caught if "fell back" in str(w.message)]
        assert len(hits) == 1, [str(w.message) for w in caught]
        assert issubclass(hits[0].category, UserWarning)
        assert o.shape == q.shape
        assert ops.attention_fallback_counts() == {"head_dim": 1}
        ops.reset_attention_fallbacks()


# ---------------------------------------------------------------------------
# ops wrappers
# ---------------------------------------------------------------------------
class TestOpsWrappers:
    def test_linear_nd(self):
        x = _rand((2, 3, 256), seed=50)
        w = _rand((256, 128), seed=51, scale=0.1)
        wq = quantize(w, MXFormat(8, 256), axis=0)
        y = ops.mxint_linear(x, wq.mantissa, wq.exponent, w_block=256)
        assert y.shape == (2, 3, 128)
        want = x.reshape(-1, 256) @ np.asarray(
            ref.mxint_matmul_ref(jnp.eye(256), wq.mantissa, wq.exponent,
                                 w_block=256))
        np.testing.assert_allclose(np.asarray(y).reshape(-1, 128),
                                   np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_odd_rows_padding(self):
        x = _rand((5, 7, 192), seed=52, scale=2.0)
        y = ops.mxint_layernorm_op(x, jnp.ones((192,)), jnp.zeros((192,)))
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()

    def test_attention_op_gqa_shapes(self):
        q = _rand((2, 4, 64, 64), seed=53)
        k = _rand((2, 4, 64, 64), seed=54)
        v = _rand((2, 4, 64, 64), seed=55)
        o = ops.attention_op(q, k, v, causal=True)
        assert o.shape == q.shape

    def test_attention_op_gqa_grouped_kv_no_broadcast(self):
        """Grouped K/V reach the flash kernel via the kv_groups BlockSpec
        index map (no broadcast copy): result equals the matched-heads
        kernel run on explicitly repeated K/V."""
        q = _rand((2, 4, 32, 64), seed=56)
        k = _rand((2, 2, 32, 64), seed=57)
        v = _rand((2, 2, 32, 64), seed=58)
        o = ops.attention_op(q, k, v, causal=True)
        kb = jnp.repeat(k, 2, axis=1)
        vb = jnp.repeat(v, 2, axis=1)
        want = ops.attention_op(q, kb, vb, causal=True)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(want))


# ---------------------------------------------------------------------------
# ISSUE 8: dimension_semantics annotations + native exponent-plane tiling
# ---------------------------------------------------------------------------
def _without_compiler_params(fn, *args, **kwargs):
    """Re-run a kernel wrapper with compiler_params stripped from every
    pallas_call it stages — the pre-annotation trace."""
    import jax.experimental.pallas as plmod

    real = plmod.pallas_call

    def naked(kernel, **kw):
        kw.pop("compiler_params", None)
        return real(kernel, **kw)

    jax.clear_caches()    # cached jaxprs would bypass the monkeypatch
    plmod.pallas_call = naked
    try:
        out = fn(*args, **kwargs)
        return np.asarray(jax.block_until_ready(out))
    finally:
        plmod.pallas_call = real
        jax.clear_caches()


class TestDimensionSemantics:
    """Annotating dimension_semantics must be bit-neutral in interpret
    mode (DESIGN.md §14) — asserted per kernel family."""

    def _assert_bit_identical(self, fn, *args, **kwargs):
        want = _without_compiler_params(fn, *args, **kwargs)
        got = np.asarray(fn(*args, **kwargs))
        np.testing.assert_array_equal(got, want)

    def test_matmul(self):
        x = _rand((16, 256), seed=60, scale=0.5)
        w = _rand((256, 128), seed=61, scale=0.1)
        wq = quantize(w, MXFormat(8, 32), axis=0)
        self._assert_bit_identical(
            mm_kernel, x, wq.mantissa, wq.exponent, w_block=32,
            quantize_act=True, bm=8, bn=128, bk=128, interpret=True)

    def test_ln_matmul(self):
        from repro.kernels.mxint_ln_matmul import mxint_ln_matmul
        x = _rand((32, 256), seed=62, scale=2.0)
        w = _rand((256, 128), seed=63, scale=0.1)
        wq = quantize(w, MXFormat(8, 32), axis=0)
        self._assert_bit_identical(
            mxint_ln_matmul, x, jnp.ones((256,)), jnp.zeros((256,)),
            wq.mantissa, wq.exponent, w_block=32, bm=16, bn=128,
            interpret=True)

    def test_rowwise_kernels(self):
        x = _rand((16, 256), seed=64, scale=2.0)
        self._assert_bit_identical(
            ln_kernel, x, jnp.ones((256,)), jnp.zeros((256,)),
            block_rows=8, interpret=True)
        self._assert_bit_identical(
            sm_kernel, x, block_rows=8, interpret=True)
        self._assert_bit_identical(
            gelu_kernel, x, block_rows=8, interpret=True)

    def test_flash_and_decode(self):
        q = _rand((2, 64, 128), seed=65, scale=0.3)
        k = _rand((2, 64, 128), seed=66, scale=0.3)
        v = _rand((2, 64, 128), seed=67)
        self._assert_bit_identical(
            flash_attention, q, k, v, causal=True, block_q=32, block_k=32,
            interpret=True)
        qd = _rand((2, 2, 8, 128), seed=68, scale=0.3)
        kd = _rand((2, 128, 2, 128), seed=69, scale=0.3)
        vd = _rand((2, 128, 2, 128), seed=70)
        valid = jnp.arange(128) < 100
        self._assert_bit_identical(
            flash_attention_decode, qd, kd, vd, valid, block_k=64,
            interpret=True)


class TestExpBlockRows:
    """mxint_matmul(exp_block_rows=32): the native int8 exponent-plane
    fetch must be bit-identical to the per-K-step fetch (ROADMAP item)."""

    @pytest.mark.parametrize("quantize_act", [False, True])
    def test_parity_vs_default(self, quantize_act):
        x = _rand((32, 1024), seed=71, scale=0.5)
        w = _rand((1024, 256), seed=72, scale=0.1)
        wq = quantize(w, MXFormat(8, 32), axis=0)
        kw = dict(w_block=32, quantize_act=quantize_act, bm=32, bn=128,
                  bk=512, interpret=True)
        want = mm_kernel(x, wq.mantissa, wq.exponent, **kw)
        got = mm_kernel(x, wq.mantissa, wq.exponent, exp_block_rows=32,
                        **kw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ops_autoselect(self):
        # the compiled-path policy: native tile exactly when the plane
        # divides into (32, bn) blocks spanning whole K-steps
        assert ops._pick_exp_block_rows(1024, 32, 512) == 32
        assert ops._pick_exp_block_rows(768, 32, 128) is None   # 24 rows
        assert ops._pick_exp_block_rows(1024, 32, 128) == 32    # 4-step
        assert ops._pick_exp_block_rows(256, 256, 512) is None  # kb=2, 1 row
        assert ops._pick_exp_block_rows(512, 512, 128) is None  # bk < wb
