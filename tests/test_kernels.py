"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles.

Every kernel is swept over shapes and dtypes and compared against ref.py.
LayerNorm / softmax / GELU kernels must match their oracles bit-for-bit
(identical op graph per row); matmul and flash attention allow accumulation-
order tolerance.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFormat, quantize
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mxint_gelu import mxint_gelu as gelu_kernel
from repro.kernels.mxint_layernorm import mxint_layernorm as ln_kernel
from repro.kernels.mxint_matmul import mxint_matmul as mm_kernel
from repro.kernels.mxint_softmax import mxint_softmax as sm_kernel
from repro.kernels import ops


def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale,
                       dtype=dtype)


# ---------------------------------------------------------------------------
# mxint_matmul
# ---------------------------------------------------------------------------
class TestMXIntMatmul:
    @pytest.mark.parametrize("m,k,n", [(8, 128, 128), (16, 256, 384),
                                       (128, 512, 128), (32, 1024, 256)])
    @pytest.mark.parametrize("w_block", [128, 256])
    def test_shape_sweep_weight_only(self, m, k, n, w_block):
        if k % w_block and w_block % k:
            pytest.skip("block/tile mismatch")
        x = _rand((m, k), seed=m + k, scale=0.5)
        w = _rand((k, n), seed=n, scale=0.1)
        wq = quantize(w, MXFormat(8, w_block), axis=0)
        got = mm_kernel(x, wq.mantissa, wq.exponent, w_block=wq.block_size,
                        bm=8, bn=128, bk=128, interpret=True)
        want = ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent,
                                    w_block=wq.block_size)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        x = _rand((16, 256), seed=1, dtype=dtype)
        w = _rand((256, 128), seed=2, scale=0.1)
        wq = quantize(w, MXFormat(6, 256), axis=0)
        got = mm_kernel(x, wq.mantissa, wq.exponent, w_block=256,
                        bm=16, bn=128, bk=256, interpret=True)
        want = ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent, w_block=256)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_quantized_activation_path(self):
        """Fig 2b full-integer datapath: kernel == oracle with act QDQ."""
        x = _rand((32, 512), seed=3, scale=2.0)
        w = _rand((512, 128), seed=4, scale=0.05)
        wq = quantize(w, MXFormat(6, 256), axis=0)
        got = mm_kernel(x, wq.mantissa, wq.exponent, w_block=256,
                        quantize_act=True, act_block=16, act_mant_bits=8,
                        bm=32, bn=128, bk=256, interpret=True)
        want = ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent, w_block=256,
                                    quantize_act=True, act_block=16,
                                    act_mant_bits=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_small_wblock_multiple_tiles(self):
        """bk < w_block: several K tiles share one exponent row."""
        x = _rand((8, 512), seed=5)
        w = _rand((512, 128), seed=6, scale=0.1)
        wq = quantize(w, MXFormat(8, 512), axis=0)
        got = mm_kernel(x, wq.mantissa, wq.exponent, w_block=512,
                        bm=8, bn=128, bk=128, interpret=True)
        want = ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent, w_block=512)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mxint_layernorm
# ---------------------------------------------------------------------------
class TestMXIntLayerNorm:
    @pytest.mark.parametrize("rows,d", [(8, 128), (32, 192), (64, 768),
                                        (128, 1024)])
    @pytest.mark.parametrize("rms_only", [False, True])
    def test_bitexact_vs_oracle(self, rows, d, rms_only):
        x = _rand((rows, d), seed=rows + d, scale=3.0)
        g = _rand((d,), seed=1, scale=0.5) + 1.0
        b = _rand((d,), seed=2, scale=0.1)
        got = ln_kernel(x, g, b, rms_only=rms_only,
                        block_rows=min(rows, 32), interpret=True)
        want = ref.mxint_layernorm_ref(x, g, b, rms_only=rms_only)
        # 1-ulp differences allowed: XLA picks different reduction trees for
        # the (block_rows, d) kernel tile vs the full-array oracle.
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-6, atol=3e-6)

    @pytest.mark.parametrize("lut_bits", [3, 4, 5, 8])
    def test_lut_bits_sweep(self, lut_bits):
        x = _rand((16, 256), seed=9, scale=2.0)
        g, b = jnp.ones((256,)), jnp.zeros((256,))
        got = ln_kernel(x, g, b, lut_bits=lut_bits, block_rows=16,
                        interpret=True)
        want = ref.mxint_layernorm_ref(x, g, b, lut_bits=lut_bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-6, atol=3e-6)

    def test_vs_float_layernorm(self):
        x = _rand((32, 768), seed=10, scale=2.0)
        g, b = jnp.ones((768,)), jnp.zeros((768,))
        got = np.asarray(ln_kernel(x, g, b, block_rows=32, interpret=True))
        mean = np.asarray(x).mean(-1, keepdims=True)
        ref_ln = (np.asarray(x) - mean) / np.sqrt(
            np.asarray(x).var(-1, keepdims=True) + 1e-6)
        cos = np.vdot(got, ref_ln) / (np.linalg.norm(got) *
                                      np.linalg.norm(ref_ln))
        assert cos > 0.999


# ---------------------------------------------------------------------------
# mxint_softmax
# ---------------------------------------------------------------------------
class TestMXIntSoftmax:
    @pytest.mark.parametrize("rows,n", [(8, 128), (32, 197 - 5), (64, 1024)])
    @pytest.mark.parametrize("r_bits", [2, 4])
    def test_bitexact_vs_oracle(self, rows, n, r_bits):
        n = n - (n % 16) if n % 16 else n   # kernel wants divisible rows
        x = _rand((rows, n), seed=rows + n, scale=4.0)
        got = sm_kernel(x, r_bits=r_bits, block_rows=min(rows, 32),
                        interpret=True)
        want = ref.mxint_softmax_ref(x, r_bits=r_bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-6, atol=1e-7)

    def test_rows_sum_to_one(self):
        x = _rand((64, 256), seed=12, scale=6.0)
        got = np.asarray(sm_kernel(x, block_rows=64, interpret=True))
        np.testing.assert_allclose(got.sum(-1), 1.0, atol=0.02)


# ---------------------------------------------------------------------------
# mxint_gelu
# ---------------------------------------------------------------------------
class TestMXIntGELU:
    @pytest.mark.parametrize("rows,d", [(8, 128), (32, 768), (128, 3072)])
    @pytest.mark.parametrize("fn", ["gelu", "silu"])
    def test_bitexact_vs_oracle(self, rows, d, fn):
        x = _rand((rows, d), seed=rows + d, scale=3.0)
        got = gelu_kernel(x, fn=fn, block_rows=min(rows, 32), interpret=True)
        want = ref.mxint_gelu_ref(x, fn=fn)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("lut_bits,domain", [(4, 3.0), (5, 3.0),
                                                 (5, 4.0), (8, 2.0)])
    def test_dse_sweep(self, lut_bits, domain):
        x = _rand((16, 256), seed=14, scale=2.0)
        got = gelu_kernel(x, lut_bits=lut_bits, domain=domain, block_rows=16,
                          interpret=True)
        want = ref.mxint_gelu_ref(x, lut_bits=lut_bits, domain=domain)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
class TestFlashAttention:
    @pytest.mark.parametrize("sq,sk", [(128, 128), (256, 256), (128, 512)])
    def test_float_vs_exact(self, sq, sk):
        q = _rand((2, sq, 128), seed=sq, scale=0.5)
        k = _rand((2, sk, 128), seed=sk + 1, scale=0.5)
        v = _rand((2, sk, 128), seed=sk + 2)
        got = flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_mxint_exp_mode_close_to_oracle(self):
        q = _rand((2, 128, 128), seed=20, scale=0.5)
        k = _rand((2, 128, 128), seed=21, scale=0.5)
        v = _rand((2, 128, 128), seed=22)
        got = flash_attention(q, k, v, causal=True, exp_mode="mxint",
                              r_bits=2, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, exp_mode="mxint",
                                 r_bits=2)
        # blocked vs row-at-once accumulation differ (exact alpha rescale);
        # values agree to LUT granularity
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0.1, atol=0.05)

    def test_sliding_window(self):
        q = _rand((1, 256, 128), seed=30, scale=0.5)
        k = _rand((1, 256, 128), seed=31, scale=0.5)
        v = _rand((1, 256, 128), seed=32)
        got = flash_attention(q, k, v, causal=True, window=64, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, window=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_mxint_attention_close_to_float(self):
        """End check: the paper's softmax datapath keeps attention faithful."""
        q = _rand((4, 128, 128), seed=40, scale=0.3)
        k = _rand((4, 128, 128), seed=41, scale=0.3)
        v = _rand((4, 128, 128), seed=42)
        a = flash_attention(q, k, v, causal=True, exp_mode="mxint",
                            interpret=True)
        b = flash_attention(q, k, v, causal=True, exp_mode="float",
                            interpret=True)
        err = float(jnp.max(jnp.abs(a - b))) / float(jnp.max(jnp.abs(b)))
        assert err < 0.15


# ---------------------------------------------------------------------------
# ops wrappers
# ---------------------------------------------------------------------------
class TestOpsWrappers:
    def test_linear_nd(self):
        x = _rand((2, 3, 256), seed=50)
        w = _rand((256, 128), seed=51, scale=0.1)
        wq = quantize(w, MXFormat(8, 256), axis=0)
        y = ops.mxint_linear(x, wq.mantissa, wq.exponent, w_block=256)
        assert y.shape == (2, 3, 128)
        want = x.reshape(-1, 256) @ np.asarray(
            ref.mxint_matmul_ref(jnp.eye(256), wq.mantissa, wq.exponent,
                                 w_block=256))
        np.testing.assert_allclose(np.asarray(y).reshape(-1, 128),
                                   np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_odd_rows_padding(self):
        x = _rand((5, 7, 192), seed=52, scale=2.0)
        y = ops.mxint_layernorm_op(x, jnp.ones((192,)), jnp.zeros((192,)))
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()

    def test_attention_op_gqa_shapes(self):
        q = _rand((2, 4, 64, 64), seed=53)
        k = _rand((2, 4, 64, 64), seed=54)
        v = _rand((2, 4, 64, 64), seed=55)
        o = ops.attention_op(q, k, v, causal=True)
        assert o.shape == q.shape
