"""repro.telemetry unit tests: registry semantics, span recording,
exporters, thread-safety, and the predicted-vs-measured join
(DESIGN.md §15)."""
import json
import threading

import pytest

from repro import telemetry as T
from repro.telemetry.export import (json_snapshot, predicted_vs_measured,
                                    prometheus_text)
from repro.telemetry.metrics import Registry
from repro.telemetry.tracing import current_span, span, span_stats


@pytest.fixture
def reg():
    return Registry()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
class TestMetrics:
    def test_counter_get_or_create_and_inc(self, reg):
        c = reg.counter("a/b")
        c.inc()
        c.inc(3)
        assert reg.counter("a/b").value == 4

    def test_counter_rejects_negative(self, reg):
        with pytest.raises(ValueError):
            reg.counter("c").inc(-1)

    def test_gauge_set_add(self, reg):
        g = reg.gauge("g")
        g.set(2.5)
        g.add(0.5)
        assert g.value == 3.0

    def test_histogram_bucketing(self, reg):
        h = reg.histogram("h", (1.0, 10.0, 100.0))
        for v in (0.5, 5.0, 50.0, 500.0):
            h.record(v)
        snap = h.snapshot()
        assert snap["counts"] == [1, 1, 1, 1]       # one per bucket + inf
        assert snap["count"] == 4
        assert snap["min"] == 0.5 and snap["max"] == 500.0
        assert snap["mean"] == pytest.approx(555.5 / 4)

    def test_histogram_boundary_goes_low(self, reg):
        h = reg.histogram("h", (1.0, 10.0))
        h.record(1.0)                               # le semantics: v <= bound
        assert h.snapshot()["counts"] == [1, 0, 0]

    def test_histogram_conflicting_buckets_raise(self, reg):
        reg.histogram("h", (1.0, 2.0))
        reg.histogram("h")                          # None = keep existing
        with pytest.raises(ValueError):
            reg.histogram("h", (1.0, 3.0))

    def test_histogram_bad_buckets_raise(self, reg):
        with pytest.raises(ValueError):
            reg.histogram("h", (2.0, 1.0))
        # empty buckets through the registry mean "use the defaults"
        assert reg.histogram("h2", ()).buckets == T.DEFAULT_MS_BUCKETS

    def test_snapshot_shape_and_isolation(self, reg):
        reg.counter("c").inc()
        reg.gauge("g").set(1)
        reg.histogram("h", (1.0,)).record(0.5)
        snap = reg.snapshot()
        assert set(snap) == {"counters", "gauges", "histograms"}
        snap["counters"]["c"] = 999                 # mutating a copy
        assert reg.counter("c").value == 1

    def test_reset_prefix_removes(self, reg):
        reg.counter("x/a").inc()
        reg.counter("x/b").inc()
        reg.counter("y/a").inc()
        reg.reset("x/")
        snap = reg.snapshot()
        assert list(snap["counters"]) == ["y/a"]
        # handle after reset is detached; re-fetch starts at zero
        assert reg.counter("x/a").value == 0

    def test_counters_with_prefix_drops_zero(self, reg):
        reg.counter("f/head_dim").inc()
        reg.counter("f/other")                      # created, never inc'd
        assert reg.counters_with_prefix("f/") == {"head_dim": 1}

    def test_jit_safety_tracer_raises(self, reg):
        jax = pytest.importorskip("jax")

        def traced(x):
            reg.counter("bad").inc(x)
            return x

        with pytest.raises(Exception) as ei:
            jax.jit(traced)(1)
        assert "trace boundaries" in str(ei.value)
        assert reg.counter("bad").value == 0

    def test_thread_safety_exact_totals(self, reg):
        n_threads, per_thread = 8, 2000

        def work():
            for i in range(per_thread):
                reg.counter("thr").inc()
                reg.histogram("thr_ms", (1.0, 10.0)).record(i % 20)

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert reg.counter("thr").value == n_threads * per_thread
        h = reg.histogram("thr_ms").snapshot()
        assert h["count"] == n_threads * per_thread
        assert sum(h["counts"]) == h["count"]


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
class TestSpans:
    def test_span_records_ms_and_attrs(self, reg):
        with span("op", registry=reg, items=7) as sp:
            pass
        assert sp.elapsed_s is not None and sp.elapsed_ms >= 0
        snap = reg.snapshot()["histograms"]
        assert snap["span/op/ms"]["count"] == 1
        assert snap["span/op/items"]["count"] == 1
        assert snap["span/op/items"]["sum"] == 7.0

    def test_span_nesting_and_current(self, reg):
        assert current_span() is None
        with span("outer", registry=reg) as so:
            assert current_span() is so
            with span("inner", registry=reg) as si:
                assert current_span() is si
            assert current_span() is so
        assert current_span() is None

    def test_span_records_on_exception(self, reg):
        with pytest.raises(RuntimeError):
            with span("boom", registry=reg):
                raise RuntimeError("x")
        assert reg.histogram("span/boom/ms").count == 1

    def test_span_stats(self, reg):
        for _ in range(3):
            with span("s", registry=reg):
                pass
        n, mean_ms = span_stats("s", registry=reg)
        assert n == 3 and mean_ms >= 0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
class TestExport:
    def _snap(self, reg):
        reg.counter("req/total").inc(2)
        reg.gauge("q depth").set(3)
        h = reg.histogram("lat", (1.0, 10.0))
        for v in (0.5, 5.0, 50.0):
            h.record(v)
        return reg.snapshot()

    def test_prometheus_text(self, reg):
        txt = prometheus_text(self._snap(reg))
        assert "repro_req_total_total 2" in txt
        assert "repro_q_depth 3.0" in txt
        # cumulative le buckets ending in +Inf == count
        assert 'repro_lat_bucket{le="1.0"} 1' in txt
        assert 'repro_lat_bucket{le="10.0"} 2' in txt
        assert 'repro_lat_bucket{le="+Inf"} 3' in txt
        assert "repro_lat_count 3" in txt

    def test_json_snapshot_writes_and_merges(self, reg, tmp_path):
        out = tmp_path / "m.json"
        payload = json_snapshot(self._snap(reg), path=out,
                                extra={"tag": "t1"})
        assert payload["tag"] == "t1"
        on_disk = json.loads(out.read_text())
        assert on_disk["counters"]["req/total"] == 2
        assert on_disk["tag"] == "t1"

    def test_predicted_vs_measured_join(self, reg):
        # two measured kernel spans; only one has a static row
        for label, ms in (("matmul-deit", 2.0), ("mystery", 1.0)):
            reg.histogram(f"span/kernel:{label}/ms",
                          T.DEFAULT_MS_BUCKETS).record(ms)
        rows = [{"label": "matmul-deit", "kernel": "mxint_matmul",
                 "flops": 2 * 400 * 192 * 256,
                 "hbm_bytes": 400 * 192 * 4 + 192 * 256 + 6 * 256,
                 "intensity": 7.9}]
        rep = predicted_vs_measured(reg.snapshot(), rows)
        assert rep["unmatched"] == ["mystery"]
        (k,) = rep["kernels"]
        assert k["label"] == "matmul-deit"
        assert k["kernel"] == "mxint_matmul"
        assert k["samples"] == 1
        assert k["measured_ms"] == pytest.approx(2.0)
        # predicted = max(flops/peak, bytes/bw); join math is exact
        peaks = rep["peaks"]
        want = max(k["flops"] / peaks["flops_per_s"],
                   k["hbm_bytes"] / peaks["hbm_bytes_per_s"]) * 1e3
        assert k["predicted_ms"] == pytest.approx(want, abs=1e-6)
        assert k["achieved_fraction"] == pytest.approx(want / 2.0, abs=1e-6)
        assert k["bottleneck"] in ("compute", "memory")

    def test_predicted_vs_measured_skips_empty_histograms(self, reg):
        reg.histogram("span/kernel:idle/ms", T.DEFAULT_MS_BUCKETS)
        rep = predicted_vs_measured(reg.snapshot(), [])
        assert rep["kernels"] == [] and rep["unmatched"] == []


# ---------------------------------------------------------------------------
# default-registry conveniences + the ops.FALLBACKS compat view
# ---------------------------------------------------------------------------
class TestDefaultRegistry:
    def test_module_level_api(self):
        T.reset("tmod/")
        T.counter("tmod/c").inc()
        T.gauge("tmod/g").set(1)
        snap = T.snapshot()
        assert snap["counters"]["tmod/c"] == 1
        T.reset("tmod/")
        assert "tmod/c" not in T.snapshot()["counters"]

    def test_fallback_view_counter_semantics(self):
        from repro.kernels import ops

        ops.reset_attention_fallbacks()
        assert ops.attention_fallback_counts() == {}
        assert ops.FALLBACKS == {}
        with pytest.warns(UserWarning, match="fell back"):
            ops._count_fallback("head_dim", "test")
        assert ops.FALLBACKS["head_dim"] == 1
        assert "head_dim" in ops.FALLBACKS
        assert dict(ops.FALLBACKS.items()) == {"head_dim": 1}
        assert ops.attention_fallback_counts() == {"head_dim": 1}
        # the same counts live in the telemetry snapshot
        assert T.snapshot()["counters"][
            "kernels/attention_fallback/head_dim"] == 1
        ops.reset_attention_fallbacks()
        assert ops.FALLBACKS == {}
