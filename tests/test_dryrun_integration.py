"""Integration: the dry-run machinery on a tiny forced-device mesh.

Runs repro.launch.dryrun as a SUBPROCESS (so the 8 fake devices never leak
into this test process) for one representative arch per family, on the
2x2x2 pod/data/model mesh — the same code path the 512-chip production
dry-run takes.  The full production matrix is exercised offline
(EXPERIMENTS.md §Dry-run); this test keeps the machinery honest in CI.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow    # subprocess dry-runs (fast CI lane skips)

ROOT = Path(__file__).resolve().parents[1]

CASES = [
    ("llama3_8b", "decode_32k"),          # dense + GQA + KV cache
    ("mixtral_8x7b", "long_500k"),        # MoE + SWA ring cache + seq rules
    ("xlstm_350m", "train_4k"),           # recurrent states + train step
]


@pytest.mark.parametrize("arch,shape", CASES)
def test_tiny_dryrun_cell(arch, shape, tmp_path):
    env = dict(os.environ)
    env["REPRO_XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = tmp_path / "dryrun"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--mesh", "tiny_multi",
         "--out", str(out), "--tag", "ci"],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=str(ROOT))
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    recs = [json.loads(f.read_text()) for f in out.glob("*.ci.json")
            if not f.name.startswith("summary")]
    assert recs
    for rec in recs:
        assert rec["ok"], rec.get("error", "")[:500]
        roof = rec["roofline"]
        assert roof["compute_s"] >= 0
        assert roof["memory_s"] > 0
        assert roof["bottleneck"] in ("compute", "memory", "collective")
        assert rec["memory"]["total_device_bytes"] > 0


def test_grad_compression_cell(tmp_path):
    """The beyond-paper MXInt gradient-compression train step must lower
    on a pod mesh (shard_map manual 'pod' + GSPMD auto elsewhere)."""
    env = dict(os.environ)
    env["REPRO_XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = tmp_path / "dryrun"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm_350m", "--shape", "train_4k",
         "--mesh", "tiny_multi", "--grad-compression",
         "--out", str(out), "--tag", "gc"],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=str(ROOT))
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
