"""Serving substrate: packed weights, engine generate, batch scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.mx_types import MXINT8_WEIGHT, MXFormat
from repro.core.quantize import MXTensor
from repro.models import build_model
from repro.models.model_api import is_param, unwrap
from repro.serving.engine import (ServeConfig, ServingEngine,
                                  pack_params_mxint)
from repro.serving.scheduler import BatchScheduler, Request


@pytest.fixture(scope="module")
def dense_model():
    cfg = smoke_config("llama3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


class TestPackedWeights:
    def test_pack_marks_large_kernels_only(self, dense_model):
        cfg, model, params = dense_model
        packed = pack_params_mxint(params, MXINT8_WEIGHT)
        n_mx = n_plain = 0
        for leaf in jax.tree_util.tree_leaves(
                packed, is_leaf=lambda l: isinstance(l, MXTensor)):
            if isinstance(leaf, MXTensor):
                n_mx += 1
            else:
                n_plain += 1
        assert n_mx > 0 and n_plain > 0   # kernels packed, norms not

    def test_packed_bytes_shrink(self, dense_model):
        from repro.core.quantize import packed_bytes
        cfg, model, params = dense_model
        raw = unwrap(params)
        base = sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(raw))
        packed = pack_params_mxint(params, MXFormat(6, 256))
        got = packed_bytes(unwrap(packed))
        assert got < base * 0.45           # f32 -> ~6.03 bits on kernels

    def test_packed_forward_close_to_float(self, dense_model):
        cfg, model, params = dense_model
        toks = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)),
            jnp.int32)
        ref = model.loss(params, {"tokens": toks})
        packed = pack_params_mxint(params, MXINT8_WEIGHT)
        got = model.loss(packed, {"tokens": toks})
        assert abs(float(got) - float(ref)) < 0.15, (float(got), float(ref))

    def test_abstract_pack_matches_concrete_shapes(self, dense_model):
        cfg, model, params = dense_model
        ab = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        pa = pack_params_mxint(ab, MXINT8_WEIGHT, abstract=True)
        pc = pack_params_mxint(params, MXINT8_WEIGHT)
        sa = jax.tree_util.tree_map(lambda x: x.shape,
                                    jax.tree_util.tree_leaves(unwrap(pa)))
        sc = jax.tree_util.tree_map(lambda x: x.shape,
                                    jax.tree_util.tree_leaves(unwrap(pc)))
        assert sa == sc


class TestEngine:
    def test_generate_greedy_deterministic(self, dense_model):
        cfg, model, params = dense_model
        eng = ServingEngine(model, params, ServeConfig(max_len=64, batch=2))
        toks = jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (2, 8)),
            jnp.int32)
        a = eng.generate({"tokens": toks}, max_new_tokens=6)
        b = eng.generate({"tokens": toks}, max_new_tokens=6)
        assert a.shape == (2, 6)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_packed_engine_generates(self, dense_model):
        cfg, model, params = dense_model
        eng = ServingEngine(model, params,
                            ServeConfig(max_len=64, batch=2,
                                        pack_weights=True,
                                        weight_fmt=MXINT8_WEIGHT))
        toks = jnp.asarray([[5, 6, 7, 8]], jnp.int32)
        out = eng.generate({"tokens": toks}, max_new_tokens=4)
        assert out.shape == (1, 4)

    def test_decode_matches_parallel_forward(self, dense_model):
        """Prefill+decode must agree with the teacher-forced forward pass
        (KV-cache correctness)."""
        cfg, model, params = dense_model
        rng = np.random.default_rng(2)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
        # parallel logits for positions 0..11
        x = model._embed_inputs(params, toks, None)
        pos = jnp.arange(12)[None, :]
        h, _, _ = model._run_stack(params, x, positions=pos, cache=None,
                                   cache_index=None, decode=False)
        full_logits = model.logits(params, h)
        # incremental: prefill 8, decode 4
        cache = model.cache_init(1, 32)
        lg, cache = model.prefill(params, toks[:, :8], cache)
        np.testing.assert_allclose(np.asarray(lg[0, 0]),
                                   np.asarray(full_logits[0, 7]),
                                   rtol=2e-3, atol=2e-3)
        for t in range(8, 12):
            lg, cache = model.decode_step(params, toks[:, t:t + 1], cache)
            if t < 11:
                np.testing.assert_allclose(np.asarray(lg[0, 0]),
                                           np.asarray(full_logits[0, t]),
                                           rtol=2e-3, atol=2e-3)


class TestScheduler:
    def test_continuous_batching(self, dense_model):
        cfg, model, params = dense_model
        eng = ServingEngine(model, params, ServeConfig(max_len=64, batch=2))
        sched = BatchScheduler(eng, batch_size=2)
        rng = np.random.default_rng(3)
        for uid in range(4):
            sched.submit(Request(uid=uid,
                                 prompt=rng.integers(
                                     0, cfg.vocab, 6).astype(np.int32),
                                 max_new_tokens=4))
        done = sched.run(max_steps=64)
        finished = [r for r in done if r.done]
        assert len(finished) >= 2
        for r in finished:
            assert len(r.generated) == 4
