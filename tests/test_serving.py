"""Serving substrate: packed weights, engine generate, batch scheduler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.mx_types import MXINT8_WEIGHT, MXFormat, QuantConfig
from repro.core.quantize import MXTensor
from repro.models import build_model
from repro.models.model_api import is_param, unwrap
from repro.serving.engine import (ServeConfig, ServingEngine,
                                  ViTServingEngine, pack_params_mxint)
from repro.serving.scheduler import (BatchScheduler, ClassifyRequest,
                                     ClassifyScheduler, Request)


@pytest.fixture(scope="module")
def dense_model():
    cfg = smoke_config("llama3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


class TestPackedWeights:
    def test_pack_marks_large_kernels_only(self, dense_model):
        cfg, model, params = dense_model
        packed = pack_params_mxint(params, MXINT8_WEIGHT)
        n_mx = n_plain = 0
        for leaf in jax.tree_util.tree_leaves(
                packed, is_leaf=lambda l: isinstance(l, MXTensor)):
            if isinstance(leaf, MXTensor):
                n_mx += 1
            else:
                n_plain += 1
        assert n_mx > 0 and n_plain > 0   # kernels packed, norms not

    def test_packed_bytes_shrink(self, dense_model):
        from repro.core.quantize import packed_bytes
        cfg, model, params = dense_model
        raw = unwrap(params)
        base = sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(raw))
        packed = pack_params_mxint(params, MXFormat(6, 256))
        got = packed_bytes(unwrap(packed))
        assert got < base * 0.45           # f32 -> ~6.03 bits on kernels

    def test_packed_forward_close_to_float(self, dense_model):
        cfg, model, params = dense_model
        toks = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)),
            jnp.int32)
        ref = model.loss(params, {"tokens": toks})
        packed = pack_params_mxint(params, MXINT8_WEIGHT)
        got = model.loss(packed, {"tokens": toks})
        assert abs(float(got) - float(ref)) < 0.15, (float(got), float(ref))

    def test_abstract_pack_matches_concrete_shapes(self, dense_model):
        cfg, model, params = dense_model
        ab = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        pa = pack_params_mxint(ab, MXINT8_WEIGHT, abstract=True)
        pc = pack_params_mxint(params, MXINT8_WEIGHT)
        sa = jax.tree_util.tree_map(lambda x: x.shape,
                                    jax.tree_util.tree_leaves(unwrap(pa)))
        sc = jax.tree_util.tree_map(lambda x: x.shape,
                                    jax.tree_util.tree_leaves(unwrap(pc)))
        assert sa == sc


class TestEngine:
    def test_generate_greedy_deterministic(self, dense_model):
        cfg, model, params = dense_model
        eng = ServingEngine(model, params, ServeConfig(max_len=64, batch=2))
        toks = jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (2, 8)),
            jnp.int32)
        a = eng.generate({"tokens": toks}, max_new_tokens=6)
        b = eng.generate({"tokens": toks}, max_new_tokens=6)
        assert a.shape == (2, 6)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_packed_engine_generates(self, dense_model):
        cfg, model, params = dense_model
        eng = ServingEngine(model, params,
                            ServeConfig(max_len=64, batch=2,
                                        pack_weights=True,
                                        weight_fmt=MXINT8_WEIGHT))
        toks = jnp.asarray([[5, 6, 7, 8]], jnp.int32)
        out = eng.generate({"tokens": toks}, max_new_tokens=4)
        assert out.shape == (1, 4)

    def test_decode_matches_parallel_forward(self, dense_model):
        """Prefill+decode must agree with the teacher-forced forward pass
        (KV-cache correctness)."""
        cfg, model, params = dense_model
        rng = np.random.default_rng(2)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
        # parallel logits for positions 0..11
        x = model._embed_inputs(params, toks, None)
        pos = jnp.arange(12)[None, :]
        h, _, _ = model._run_stack(params, x, positions=pos, cache=None,
                                   cache_index=None, decode=False)
        full_logits = model.logits(params, h)
        # incremental: prefill 8, decode 4
        cache = model.cache_init(1, 32)
        lg, cache = model.prefill(params, toks[:, :8], cache)
        np.testing.assert_allclose(np.asarray(lg[0, 0]),
                                   np.asarray(full_logits[0, 7]),
                                   rtol=2e-3, atol=2e-3)
        for t in range(8, 12):
            lg, cache = model.decode_step(params, toks[:, t:t + 1], cache)
            if t < 11:
                np.testing.assert_allclose(np.asarray(lg[0, 0]),
                                           np.asarray(full_logits[0, t]),
                                           rtol=2e-3, atol=2e-3)


class TestKernelModeServingDecode:
    """BatchScheduler decode in mode='kernel' exercises the Pallas decode
    path (ISSUE 3): scoring + Eq. 14-20 softmax + p @ V fused in one
    kernel over the cache ring — no XLA L.softmax in the decode step."""

    @pytest.fixture(scope="class")
    def kernel_engine(self):
        cfg = dataclasses.replace(
            smoke_config("llama3_8b"), n_layers=1,
            quant=QuantConfig(mode="kernel", quantize_nonlinear=True))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        return ServingEngine(model, params,
                             ServeConfig(max_len=32, batch=2,
                                         pack_weights=True,
                                         weight_fmt=MXINT8_WEIGHT))

    def test_scheduler_generates_through_pallas_decode(self, kernel_engine):
        from repro.models import layers as L
        eng = kernel_engine
        # the decode step's traced program carries the Pallas kernel and
        # never routes scores through L.softmax
        cache = eng.model.cache_init(2, eng.cfg.max_len)
        tok = jnp.zeros((2, 1), jnp.int32)
        calls = []
        orig = L.softmax
        L.softmax = lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
        try:
            jaxpr = jax.make_jaxpr(
                lambda t, c: eng._decode.__wrapped__(eng.params, t, c)
            )(tok, cache)
        finally:
            L.softmax = orig
        assert not calls
        assert "pallas_call" in str(jaxpr)

        sched = BatchScheduler(eng, batch_size=2)
        rng = np.random.default_rng(0)
        for uid in range(3):                       # 3 requests, 2 slots
            sched.submit(Request(uid=uid,
                                 prompt=rng.integers(1, 512, uid + 2),
                                 max_new_tokens=2))
        done = sched.run()
        assert len(done) == 3
        assert all(len(r.generated) == 2 for r in done)

    def test_slot_admission_no_per_slot_recompiles(self, kernel_engine):
        """Kernel mode: a ragged stream through the slot scheduler keeps
        the decode + slot-prefill jit caches flat after warmup — slot
        index / per-row lengths are traced values, never specialization
        keys (the ClassifyScheduler zero-recompile contract, ported to
        the token path)."""
        eng = kernel_engine
        rng = np.random.default_rng(1)

        def stream(uids, plens):
            sched = BatchScheduler(eng, batch_size=2, prefill_len=8)
            for uid, n in zip(uids, plens):
                sched.submit(Request(uid=uid,
                                     prompt=rng.integers(1, 512, n),
                                     max_new_tokens=2))
            return sched.run()

        stream([0, 1], [3, 5])                     # warm both jits
        base = eng.jit_cache_size()
        done = stream([2, 3, 4], [7, 2, 4])        # new slots + lengths
        assert len(done) == 3
        if base >= 0:
            assert eng.jit_cache_size() == base    # zero recompiles


# ---------------------------------------------------------------------------
# scripted stub engine: slot prefill emits the LAST real prompt token and
# decode counts up from it (+1, +2, ...), so EOS timing is controlled
# exactly by the prompt contents (no model in the loop)
# ---------------------------------------------------------------------------
class _StubModel:
    def cache_init(self, batch, max_len):
        return jnp.zeros((batch,), jnp.int32)


class _StubEngine:
    cfg = ServeConfig(max_len=32, batch=2)
    model = _StubModel()
    params = None

    def _prefill_slot(self, params, tokens, length, slot, cache):
        toks = np.asarray(tokens)
        tok = jnp.asarray([toks[0, int(length) - 1]], jnp.int32)
        return tok, cache

    def _decode(self, params, tok, cache):
        return tok + 1, cache


class TestSchedulerEdgeCases:
    def _mk(self, batch=2, eos=None, admission="slot"):
        return BatchScheduler(_StubEngine(), batch_size=batch, eos_id=eos,
                              admission=admission)

    def test_empty_queue_step_is_noop(self):
        sched = self._mk()
        assert sched.step() == 0
        assert sched.run(max_steps=4) == []

    def test_submit_beyond_capacity_drains(self):
        sched = self._mk(batch=2)
        for uid in range(5):                       # > 2x capacity
            sched.submit(Request(uid=uid, prompt=np.asarray([uid + 1]),
                                 max_new_tokens=3))
        done = sched.run()
        assert len(done) == 5 and all(r.done for r in done)
        for r in done:                             # scripted: last, +1, +2
            assert r.generated == [r.uid + 1, r.uid + 2, r.uid + 3]

    def test_freed_slot_refilled_next_step_under_load(self):
        """Regression (ISSUE 7): a slot freed at step t serves a queued
        request at step t+1 — eviction used to fire only at wave
        boundaries, idling freed slots until the whole batch drained —
        while the surviving row's stream is untouched."""
        eos = 12
        sched = self._mk(batch=2, eos=eos)
        a = Request(uid=0, prompt=np.asarray([10]), max_new_tokens=6)
        b = Request(uid=1, prompt=np.asarray([20]), max_new_tokens=8)
        c = Request(uid=2, prompt=np.asarray([30]), max_new_tokens=2)
        sched.submit(a)
        sched.submit(b)
        sched.step()                               # admit A:10 B:20; +1
        assert a.generated == [10, 11] and b.generated == [20, 21]
        sched.submit(c)
        sched.step()                               # A:12 (EOS) B:22
        assert a.done and a.generated == [10, 11, 12]
        sched.step()                               # A evicted, C admitted NOW
        assert c.generated == [30, 31]             # prefill + 1 decode
        assert a in sched.finished
        done = sched.run()
        # B's stream never saw the eviction or the admission
        assert b.generated == [20, 21, 22, 23, 24, 25, 26, 27]
        assert c.done and c.generated == [30, 31]
        assert {r.uid for r in done} == {0, 1, 2}

    def test_run_cannot_starve_queued_request(self):
        """A long-running slot must not starve the queue: every freed
        slot is refilled FIFO on the next step, so all short requests
        complete while the long one is still decoding."""
        sched = self._mk(batch=2)
        long = Request(uid=0, prompt=np.asarray([1]), max_new_tokens=40)
        sched.submit(long)
        shorts = [Request(uid=1 + i, prompt=np.asarray([2 + i]),
                          max_new_tokens=2) for i in range(6)]
        for r in shorts:
            sched.submit(r)
        # enough steps for the shorts only if freed slots recycle per-step
        for _ in range(16):
            sched.step()
        assert all(r.done for r in shorts)
        assert not long.done                       # still occupying its slot
        done = sched.run()
        assert {r.uid for r in done} == {r.uid for r in shorts} | {0}

    def test_wave_admission_defers_until_batch_drains(self):
        """admission='wave' retains the old policy (the kernel_bench
        baseline): no admission while any slot is active."""
        sched = self._mk(batch=2, admission="wave")
        a = Request(uid=0, prompt=np.asarray([10]), max_new_tokens=2)
        b = Request(uid=1, prompt=np.asarray([20]), max_new_tokens=4)
        c = Request(uid=2, prompt=np.asarray([30]), max_new_tokens=2)
        sched.submit(a)
        sched.submit(b)
        sched.step()                               # admit wave {A, B}
        sched.submit(c)
        sched.step()                               # A done; B alive
        assert a.done
        sched.step()                               # slot must idle
        assert c.generated == []                   # deferred admission
        done = sched.run()
        assert c.done and c.generated == [30, 31]  # admitted after drain
        assert {r.uid for r in done} == {0, 1, 2}

    def test_eos_request_evicted_to_finished(self):
        sched = self._mk(batch=1, eos=12)
        sched.submit(Request(uid=0, prompt=np.asarray([11]),
                             max_new_tokens=8))
        sched.submit(Request(uid=1, prompt=np.asarray([5]),
                             max_new_tokens=2))
        done = sched.run()
        assert [r.uid for r in done] == [0, 1]
        assert done[0].generated == [11, 12]       # EOS on first decode

    def test_prompt_longer_than_prefill_len_rejected(self):
        sched = BatchScheduler(_StubEngine(), batch_size=2, prefill_len=4)
        with pytest.raises(ValueError):
            sched.submit(Request(uid=0, prompt=np.asarray([1] * 5)))


class TestClassifyScheduler:
    @pytest.fixture(scope="class")
    def vit_engine(self):
        from repro.configs.deit import DEIT_MICRO
        cfg = dataclasses.replace(DEIT_MICRO, n_layers=2, quant=QuantConfig())
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        return cfg, ViTServingEngine(model, params, ServeConfig(batch=4))

    def _images(self, n, size, seed):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(n, size, size, 3)).astype(np.float32)

    def test_mixed_sizes_match_direct_classify(self, vit_engine):
        cfg, eng = vit_engine
        sched = ClassifyScheduler(eng)
        sizes = (3, 6, 1, 2)                       # 12 images, batch 4
        reqs = [ClassifyRequest(uid=i, images=self._images(n, cfg.image_size,
                                                           seed=i))
                for i, n in enumerate(sizes)]
        for r in reqs:
            sched.submit(r)
        done = sched.run()
        assert len(done) == len(sizes) and all(r.done for r in done)
        for r in done:
            want_labels, want_logits = eng.classify(r.images)
            np.testing.assert_array_equal(r.labels, np.asarray(want_labels))
            np.testing.assert_array_equal(r.logits, np.asarray(want_logits))

    def test_fixed_shape_jit_stays_warm(self, vit_engine):
        cfg, eng = vit_engine
        eng.classify(self._images(4, cfg.image_size, seed=99))   # warm
        base = eng.jit_cache_size()
        sched = ClassifyScheduler(eng)
        for i, n in enumerate((5, 1, 7, 3, 4)):
            sched.submit(ClassifyRequest(
                uid=i, images=self._images(n, cfg.image_size, seed=10 + i)))
        done = sched.run()
        assert len(done) == 5
        if base >= 0:                              # cache stats available
            assert eng.jit_cache_size() == base    # zero recompiles

    def test_zero_image_request_keeps_order_and_shapes(self, vit_engine):
        """Empty requests complete in FIFO order with (0, n_classes)
        results, so position-based concatenation stays aligned."""
        cfg, eng = vit_engine
        sched = ClassifyScheduler(eng)
        sched.submit(ClassifyRequest(uid=0, images=self._images(
            0, cfg.image_size, seed=0)))
        assert sched.step() == 0                   # evicted, nothing to run
        assert len(sched.finished) == 1 and sched.finished[0].done
        sched.submit(ClassifyRequest(uid=1, images=self._images(
            2, cfg.image_size, seed=1)))
        sched.submit(ClassifyRequest(uid=2, images=self._images(
            0, cfg.image_size, seed=2)))
        sched.submit(ClassifyRequest(uid=3, images=self._images(
            1, cfg.image_size, seed=3)))
        done = sched.run()
        assert [r.uid for r in done] == [0, 1, 2, 3]   # FIFO completion
        for r in done:
            assert r.logits.shape[1] == cfg.n_classes
        # the serve-example aggregation pattern must not trip on empties
        agg = np.concatenate([r.logits for r in done])
        assert agg.shape == (3, cfg.n_classes)

    def test_step_counts_images_not_requests(self, vit_engine):
        cfg, eng = vit_engine
        sched = ClassifyScheduler(eng)
        for i in range(3):                         # 3 x 2 images, batch 4
            sched.submit(ClassifyRequest(
                uid=i, images=self._images(2, cfg.image_size, seed=20 + i)))
        assert sched.step() == 4                   # spans request boundary
        assert sched.step() == 2                   # remainder, zero-padded
        assert sched.step() == 0


class TestScheduler:
    def test_continuous_batching(self, dense_model):
        cfg, model, params = dense_model
        eng = ServingEngine(model, params, ServeConfig(max_len=64, batch=2))
        sched = BatchScheduler(eng, batch_size=2)
        rng = np.random.default_rng(3)
        for uid in range(4):
            sched.submit(Request(uid=uid,
                                 prompt=rng.integers(
                                     0, cfg.vocab, 6).astype(np.int32),
                                 max_new_tokens=4))
        done = sched.run(max_steps=64)
        finished = [r for r in done if r.done]
        assert len(finished) >= 2
        for r in finished:
            assert len(r.generated) == 4
