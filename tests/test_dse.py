"""Tests for repro.dse (DESIGN.md §16) and the per-layer scoped-config
plumbing it rides on.

The two ISSUE-10 acceptance criteria live here:

* a uniform (no-override) SearchSpace point is BIT-IDENTICAL to the
  global QuantConfig for mode in {'sim', 'kernel'} on DeiT-Tiny — both
  the identity short-circuit (the uniform point materializes the base
  config object itself) and the forced-unroll case (a same-value
  override switches the ViT from lax.scan to the per-layer loop, which
  must not change a single bit);
* the exhaustive driver on a <=16-point space returns a Pareto set in
  which membership is verifiably correct, backed by a randomized
  property test on the dominance check itself.
"""
import dataclasses
import importlib.util
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.deit import DEIT_MICRO, DEIT_TINY
from repro.core.mx_types import MXFormat, QuantConfig, QuantOverride
from repro.dse import (Evaluator, GroupSpace, SearchSpace, exhaustive_search,
                       greedy_search, point_key)
from repro.dse.report import (DEFAULT_OBJECTIVES, build_report, dominates,
                              objective_vector, pareto_front)
from repro.models import build_model
from repro.serving.engine import pack_params_mxint
from repro.telemetry import metrics

ROOT = Path(__file__).resolve().parents[1]

SIM = QuantConfig(mode="sim", quantize_nonlinear=True)
KERNEL = QuantConfig(mode="kernel", quantize_nonlinear=True)


def _images(n, size, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, size, size, 3)).astype(np.float32))


# ---------------------------------------------------------------------------
# QuantConfig.scoped — the override resolution the whole subsystem rides on
# ---------------------------------------------------------------------------
class TestScopedConfig:
    def test_later_overrides_win_per_field(self):
        q = QuantConfig(
            mode="sim",
            overrides=(
                ("block/*", QuantOverride(weight_fmt=MXFormat(4, 256),
                                          act_fmt=MXFormat(6, 16))),
                ("block/1/*", QuantOverride(weight_fmt=MXFormat(8, 256))),
            ))
        q1 = q.scoped("block/1/attn")
        # block/1 matches both patterns: weight_fmt from the later entry,
        # act_fmt inherited from the earlier one
        assert q1.weight_fmt.mant_bits == 8
        assert q1.act_fmt.mant_bits == 6
        q0 = q.scoped("block/0/ffn")
        assert q0.weight_fmt.mant_bits == 4
        assert q0.act_fmt.mant_bits == 6
        # non-matching scope keeps the base fields
        qh = q.scoped("head")
        assert qh.weight_fmt == q.weight_fmt and qh.act_fmt == q.act_fmt

    def test_scoped_strips_overrides_and_caches(self):
        q = QuantConfig(overrides=(("head", QuantOverride(mode="sim")),))
        qs = q.scoped("head")
        assert qs.mode == "sim" and not qs.has_overrides
        assert q.scoped("head") is qs          # per-instance cache
        assert qs.scoped("head") is qs         # idempotent

    def test_no_overrides_and_none_scope_are_identity(self):
        q = QuantConfig(mode="sim")
        assert q.scoped(None) is q
        assert q.scoped("block/3/ffn") is q
        qo = QuantConfig(overrides=(("head", QuantOverride(mode="sim")),))
        assert qo.scoped(None) is qo

    def test_scoped_mode_override_switches_datapath(self):
        q = QuantConfig(mode="kernel", quantize_nonlinear=True,
                        overrides=(("block/*/ffn",
                                    QuantOverride(mode="sim")),))
        assert q.datapath.name == "pallas_kernel"
        assert q.scoped("block/2/ffn").datapath.name == "mxint_sim"
        assert q.scoped("block/2/attn").datapath.name == "pallas_kernel"

    def test_override_validation(self):
        with pytest.raises(ValueError, match="pairs"):
            QuantConfig(overrides=(("head",),))
        with pytest.raises(ValueError, match="pattern"):
            QuantConfig(overrides=(("", QuantOverride(mode="sim")),))
        with pytest.raises(TypeError, match="QuantOverride"):
            QuantConfig(overrides=(("head", {"mode": "sim"}),))

    def test_describe_is_json_serializable(self):
        q = QuantConfig(mode="kernel", quantize_nonlinear=True)
        d = json.loads(json.dumps(q.describe()))
        assert d["mode"] == "kernel"
        assert d["weight_fmt"]["mant_bits"] == q.weight_fmt.mant_bits
        assert d["nonlinear"]["ln_lut_bits"] == q.nonlinear.ln_lut_bits


# ---------------------------------------------------------------------------
# SearchSpace grammar
# ---------------------------------------------------------------------------
class TestSearchSpace:
    def _space(self):
        return SearchSpace(
            base=QuantConfig(mode="fake"),
            groups=(GroupSpace(scope="block/*",
                               weight_mant_bits=(6, 4),
                               act_mant_bits=(8,)),
                    GroupSpace(scope="head", weight_mant_bits=(6, 3))))

    def test_size_and_points(self):
        space = self._space()
        assert space.size() == 2 * 1 * 2
        pts = list(space.points())
        assert len(pts) == 4
        assert len({point_key(p) for p in pts}) == 4

    def test_baseline_point_materializes_base_itself(self):
        space = self._space()
        p = space.baseline_point()
        # base weight mant is 6 (MXINT6_WEIGHT), act mant 8 (MXINT8_ACT):
        # every knob has its base value among the candidates
        assert p[("block/*", "weight_mant_bits")] == 6
        assert p[("head", "weight_mant_bits")] == 6
        assert space.to_config(p) is space.base

    def test_to_config_drops_base_equal_assignments(self):
        space = self._space()
        p = space.baseline_point()
        p[("head", "weight_mant_bits")] = 3
        q = space.to_config(p)
        assert len(q.overrides) == 1
        assert q.overrides[0][0] == "head"
        assert q.scoped("head").weight_fmt.mant_bits == 3
        assert q.scoped("block/0/attn").weight_fmt.mant_bits == 6

    def test_non_candidate_value_rejected(self):
        space = self._space()
        p = space.baseline_point()
        p[("head", "weight_mant_bits")] = 5
        with pytest.raises(ValueError, match="not a candidate"):
            space.to_config(p)

    def test_mutate_changes_exactly_one_knob(self):
        space = self._space()
        rng = np.random.default_rng(0)
        p = space.baseline_point()
        for _ in range(20):
            m = space.mutate(p, rng)
            diff = [k for k in p if m[k] != p[k]]
            assert len(diff) == 1
            scope, name = diff[0]
            g = next(g for g in space.groups if g.scope == scope)
            assert m[diff[0]] in getattr(g, name)

    def test_duplicate_knob_rejected(self):
        with pytest.raises(ValueError, match="declared twice"):
            SearchSpace(base=QuantConfig(),
                        groups=(GroupSpace(scope="head",
                                           weight_mant_bits=(4, 6)),
                                GroupSpace(scope="head",
                                           weight_mant_bits=(3,))))

    def test_override_carrying_base_rejected(self):
        base = QuantConfig(overrides=(("head", QuantOverride(mode="sim")),))
        with pytest.raises(ValueError, match="override-free"):
            SearchSpace(base=base, groups=())

    def test_duplicate_candidates_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            GroupSpace(scope="head", weight_mant_bits=(4, 4))


# ---------------------------------------------------------------------------
# acceptance: uniform point bit-identity on DeiT-Tiny, sim AND kernel
# ---------------------------------------------------------------------------
class TestUniformPointBitIdentity:
    """ISSUE 10 acceptance: the no-override point of a SearchSpace is
    bit-identical to today's global QuantConfig — including when a
    same-value override FORCES the unrolled per-layer model path that
    per-layer configs require (scan vs unroll must agree bitwise)."""

    def _setup(self):
        cfg = dataclasses.replace(DEIT_TINY, n_layers=2, n_classes=100)
        params = build_model(dataclasses.replace(cfg, quant=SIM)).init(
            jax.random.key(0))
        packed = pack_params_mxint(params, KERNEL.weight_fmt)
        imgs = _images(2, cfg.image_size)
        return cfg, params, packed, imgs

    def test_uniform_point_is_the_base_config(self):
        for base in (SIM, KERNEL):
            space = SearchSpace(
                base=base,
                groups=(GroupSpace(scope="block/*",
                                   weight_mant_bits=(6, 4)),))
            assert space.to_config(space.baseline_point()) is base

    @pytest.mark.parametrize("mode", ["sim", "kernel"])
    def test_same_value_override_unroll_bit_exact(self, mode):
        """A same-value override resolves to the base fields everywhere
        but flips the ViT from lax.scan to the unrolled loop — the
        logits must not move by a single bit."""
        cfg, params, packed, imgs = self._setup()
        base = SIM if mode == "sim" else KERNEL
        p = params if mode == "sim" else packed
        forced = dataclasses.replace(
            base, overrides=(("block/*",
                              QuantOverride(weight_fmt=base.weight_fmt)),))
        assert forced.has_overrides
        want = np.asarray(
            jax.jit(build_model(dataclasses.replace(cfg, quant=base)).logits)(
                p, imgs))
        got = np.asarray(
            jax.jit(build_model(dataclasses.replace(cfg, quant=forced)).logits)(
                p, imgs))
        np.testing.assert_array_equal(got, want)

    def test_mixed_backend_kernel_with_sim_ffn_bit_exact(self):
        """kernel base + sim FFN override on PACKED params: sim is the
        bit-exact oracle of the kernels on these shapes, so the mixed
        model must equal the full-kernel model bitwise — one model, two
        live backends (the §16 headline)."""
        cfg, params, packed, imgs = self._setup()
        mixed = dataclasses.replace(
            KERNEL, overrides=(("block/*/ffn", QuantOverride(mode="sim")),))
        want = np.asarray(
            build_model(dataclasses.replace(cfg, quant=KERNEL)).logits(
                packed, imgs))
        got = np.asarray(
            build_model(dataclasses.replace(cfg, quant=mixed)).logits(
                packed, imgs))
        np.testing.assert_array_equal(got, want)

    def test_effective_override_actually_changes_logits(self):
        """Guard that the scope tags reach the layers: a 3-bit FFN
        weight override must move the logits (else the bit-identity
        tests above prove nothing)."""
        cfg, params, _, imgs = self._setup()
        base = QuantConfig(mode="fake")
        narrow = dataclasses.replace(
            base, overrides=(("block/*/ffn",
                              QuantOverride(weight_fmt=MXFormat(3, 256))),))
        a = np.asarray(build_model(dataclasses.replace(cfg, quant=base))
                       .logits(params, imgs))
        b = np.asarray(build_model(dataclasses.replace(cfg, quant=narrow))
                       .logits(params, imgs))
        assert np.abs(a - b).max() > 0


# ---------------------------------------------------------------------------
# evaluator + drivers on a micro model (fake mode: cheap float QDQ)
# ---------------------------------------------------------------------------
def _micro_setup(n_layers=1):
    cfg = dataclasses.replace(DEIT_MICRO, n_layers=n_layers, n_classes=10)
    base = QuantConfig(mode="fake",
                       weight_fmt=MXFormat(mant_bits=8, block_size=256),
                       act_fmt=MXFormat(mant_bits=16, block_size=16))
    params = build_model(dataclasses.replace(cfg, quant=base)).init(
        jax.random.key(1))
    imgs = _images(4, cfg.image_size, seed=11)
    return cfg, base, params, imgs


class TestEvaluator:
    def test_cache_and_telemetry_counters(self):
        cfg, base, params, imgs = _micro_setup()
        space = SearchSpace(base=base, groups=(
            GroupSpace(scope="block/*", weight_mant_bits=(8, 4)),))
        reg = metrics.Registry()
        ev = Evaluator(space, cfg, params, imgs, kernel_rows=(),
                       registry=reg)
        p = space.baseline_point()
        r1 = ev(p)
        r2 = ev(p)
        assert r2 is r1
        assert ev.n_evaluated == 1
        assert reg.counter("dse/evaluations").value == 1
        assert reg.counter("dse/cache_hits").value == 1
        # logits memo is shared with __call__: no new forward
        ev.logits_for(p)
        assert reg.counter("dse/evaluations").value == 1
        # the uniform point agrees with itself-as-float only partially,
        # but accuracy/fidelity are well-defined probabilities
        assert 0.0 <= r1.accuracy <= 1.0
        assert -1.0 <= r1.fidelity <= 1.0

    def test_static_cost_weights_by_group_size(self):
        cfg, base, params, imgs = _micro_setup(n_layers=2)
        space = SearchSpace(base=base, groups=(
            GroupSpace(scope="block/*", weight_mant_bits=(8, 4)),))
        ev = Evaluator(space, cfg, params, imgs, kernel_rows=(),
                       registry=metrics.Registry())
        wide = ev(space.baseline_point())
        p = space.baseline_point()
        p[("block/*", "weight_mant_bits")] = 4
        narrow = ev(p)
        assert narrow.cost.weight_bits < wide.cost.weight_bits
        # blocks shrank but patch/head stayed at 8 bits, so the weighted
        # mean sits strictly between the two uniform widths
        assert narrow.cost.weight_bits > MXFormat(4, 256).bits_per_element
        assert narrow.cost.weight_bytes < wide.cost.weight_bytes


class TestDrivers:
    def test_exhaustive_pareto_acceptance(self):
        """ISSUE 10 acceptance: exhaustive on a <=16-point space; every
        front member is undominated, every non-member is dominated by a
        front member, and the archived report is self-consistent."""
        cfg, base, params, imgs = _micro_setup()
        space = SearchSpace(base=base, groups=(
            GroupSpace(scope="block/*/attn", weight_mant_bits=(8, 3)),
            GroupSpace(scope="block/*/ffn", weight_mant_bits=(8, 3)),
            GroupSpace(scope="head", weight_mant_bits=(8, 3))))
        assert space.size() == 8 <= 16
        ev = Evaluator(space, cfg, params, imgs, kernel_rows=(),
                       registry=metrics.Registry())
        results = exhaustive_search(space, ev)
        assert len(results) == 8
        front = pareto_front(results)
        assert front
        vecs = [objective_vector(r) for r in results]
        for i in front:
            assert not any(dominates(vecs[j], vecs[i])
                           for j in range(len(vecs)) if j != i)
        for i in range(len(vecs)):
            if i not in front:
                assert any(dominates(vecs[j], vecs[i]) for j in front)

        report = build_report(space, results, driver="exhaustive",
                              n_evaluations=ev.n_evaluated)
        blob = json.loads(json.dumps(report))     # must serialize
        assert blob["schema"] == 1
        assert blob["n_candidates"] == 8
        assert blob["pareto"] == sorted(front)
        flags = [c["pareto"] for c in blob["candidates"]]
        assert [i for i, f in enumerate(flags) if f] == sorted(front)

    def test_exhaustive_limit_guard(self):
        cfg, base, params, imgs = _micro_setup()
        space = SearchSpace(base=base, groups=(
            GroupSpace(scope="block/*", weight_mant_bits=(8, 6, 4)),))
        ev = Evaluator(space, cfg, params, imgs, kernel_rows=(),
                       registry=metrics.Registry())
        with pytest.raises(ValueError, match="exhaustive limit"):
            exhaustive_search(space, ev, limit=2)

    def test_greedy_loose_budget_reaches_narrowest(self):
        cfg, base, params, imgs = _micro_setup()
        space = SearchSpace(base=base, groups=(
            GroupSpace(scope="block/*", weight_mant_bits=(8, 6, 4)),))
        ev = Evaluator(space, cfg, params, imgs, kernel_rows=(),
                       registry=metrics.Registry())
        res = greedy_search(space, ev, budget=1.0)
        assert res.bits == {"block/*": 4}
        assert res.mean_bits == 4.0
        assert [t[:2] for t in res.trace] == [("block/*", 6),
                                              ("block/*", 4)]
        assert all(ok for *_, ok in res.trace)
        # reference (widest) point + both lowerings were evaluated
        assert ev.n_evaluated == 3

    def test_greedy_impossible_budget_keeps_widest(self):
        cfg, base, params, imgs = _micro_setup()
        space = SearchSpace(base=base, groups=(
            GroupSpace(scope="block/*", weight_mant_bits=(8, 6, 4)),))
        ev = Evaluator(space, cfg, params, imgs, kernel_rows=(),
                       registry=metrics.Registry())
        res = greedy_search(space, ev, budget=-1.0)
        assert res.bits == {"block/*": 8}
        assert len(res.trace) == 1 and res.trace[0][3] is False

    def test_greedy_unswept_knob_rejected(self):
        cfg, base, params, imgs = _micro_setup()
        space = SearchSpace(base=base, groups=(
            GroupSpace(scope="block/*", weight_mant_bits=(8, 4)),))
        ev = Evaluator(space, cfg, params, imgs, kernel_rows=(),
                       registry=metrics.Registry())
        with pytest.raises(ValueError, match="act_mant_bits"):
            greedy_search(space, ev, knob="act_mant_bits")


# ---------------------------------------------------------------------------
# dominance property test (pure vectors — no model in the loop)
# ---------------------------------------------------------------------------
class _Vec:
    """Minimal EvalResult stand-in for the report-layer functions."""

    def __init__(self, v):
        self.v = tuple(float(x) for x in v)


_VOBJ = tuple((f"o{i}", +1, (lambda i: lambda r: r.v[i])(i))
              for i in range(3))


class TestDominance:
    def test_strictness_and_ties(self):
        assert dominates((1.0, 1.0), (0.0, 1.0))
        assert not dominates((1.0, 1.0), (1.0, 1.0))   # ties never dominate
        assert not dominates((0.0, 2.0), (1.0, 1.0))   # trade-off
        with pytest.raises(ValueError, match="arity"):
            dominates((1.0,), (1.0, 2.0))

    def test_sense_flips_sign(self):
        r = _Vec((0.9, 6.0, 100.0))
        objs = (("acc", +1, lambda x: x.v[0]),
                ("bits", -1, lambda x: x.v[1]))
        assert objective_vector(r, objs) == (0.9, -6.0)

    def test_duplicate_points_all_stay_on_front(self):
        results = [_Vec((1, 2, 3)) for _ in range(4)]
        assert pareto_front(results, _VOBJ) == [0, 1, 2, 3]

    def test_front_membership_property(self):
        """Randomized (fixed-seed) property: on integer grids full of
        ties, front members are undominated and every non-member is
        dominated by some front member."""
        rng = np.random.default_rng(0)
        for _ in range(50):
            n = int(rng.integers(1, 24))
            vecs = rng.integers(0, 4, size=(n, 3))
            results = [_Vec(v) for v in vecs]
            front = pareto_front(results, _VOBJ)
            assert front, "front of a non-empty set cannot be empty"
            vs = [objective_vector(r, _VOBJ) for r in results]
            for i in front:
                assert not any(dominates(vs[j], vs[i])
                               for j in range(n) if j != i)
            for i in set(range(n)) - set(front):
                assert any(dominates(vs[j], vs[i]) for j in front)


# ---------------------------------------------------------------------------
# the extended dispatch-seam rule (satellite 6)
# ---------------------------------------------------------------------------
def _check_dispatch():
    spec = importlib.util.spec_from_file_location(
        "check_dispatch", ROOT / "tools" / "check_dispatch.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestDispatchSeamOverrideRule:
    def test_override_read_flagged_outside_seam(self):
        cd = _check_dispatch()
        bad = "for pattern, ov in q.overrides:\n    pass\n"
        probs = cd.check_text(bad, "src/repro/models/foo.py")
        assert len(probs) == 1 and "DESIGN.md §16" in probs[0]

    def test_override_read_allowed_inside_seam(self):
        cd = _check_dispatch()
        text = "for pattern, ov in q.overrides:\n    pass\n"
        assert cd.check_text(text, "src/repro/datapath/foo.py") == []
        assert cd.check_text(text, "src/repro/core/mx_types.py") == []

    def test_has_overrides_gate_stays_free(self):
        cd = _check_dispatch()
        assert cd.check_text("if quant.has_overrides:\n    pass\n",
                             "src/repro/models/vit.py") == []

    def test_mode_branch_still_flagged(self):
        cd = _check_dispatch()
        probs = cd.check_text("if q.mode == 'kernel':\n    pass\n",
                              "src/repro/models/foo.py")
        assert len(probs) == 1 and "DESIGN.md §12" in probs[0]
