"""Unit + property tests for MXInt quantization (repro.core.quantize)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import (MXFormat, MXINT6_WEIGHT, MXINT8_ACT, dequantize,
                        fake_quant, quantize, quantize_dequantize,
                        requantize_to_max_exponent)
from repro.core.quantize import MXTensor, packed_bytes, pack_weight

pytestmark = pytest.mark.slow    # hypothesis-heavy property suite (fast CI lane skips)

jax.config.update("jax_enable_x64", False)


def test_paper_fig1b_bit_densities():
    """W6.03 / A8.5 notation of Fig 1b must fall out of the format math."""
    assert MXINT6_WEIGHT.bits_per_element == pytest.approx(6.03125)
    assert MXINT8_ACT.bits_per_element == pytest.approx(8.5)
    # Fig 1b: MXInt8 (W6.03/A8.5) memory density 4.99x vs FP32 -> the weight
    # format alone gives 32/6.03 = 5.31x; the blended W+A density the paper
    # reports sits between the two.
    assert MXINT6_WEIGHT.density_vs(32) > 4.99
    assert MXINT8_ACT.density_vs(32) > 3.7


def test_roundtrip_exact_for_representable():
    """Values already on the MXInt grid reconstruct exactly.

    Mantissas are drawn from [-64, 63] so the block max lands in the
    quantizer's canonical [2^(m-2), 2^(m-1)) window at the same exponent."""
    fmt = MXFormat(mant_bits=8, block_size=16)
    m = jnp.arange(-64, 64, dtype=jnp.float32).reshape(8, 16)
    x = m * 2.0 ** -3
    x = x.at[:, 0].set(-8.0)  # pin every block's amax to 64 * 2^-3
    got = quantize_dequantize(x, fmt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_block_relative_error_bound():
    """|x - Q(x)| <= 2^(e_block - 1) i.e. half an LSB of the block scale."""
    rng = np.random.default_rng(1)
    fmt = MXFormat(mant_bits=8, block_size=16)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32)) * 10
    t = quantize(x, fmt)
    err = np.abs(np.asarray(dequantize(t)) - np.asarray(x))
    lsb = np.repeat(np.exp2(np.asarray(t.exponent, np.float32)), 16, axis=-1)
    assert np.all(err <= 0.5 * lsb + 1e-7)


def test_zero_block():
    fmt = MXFormat(mant_bits=8, block_size=16)
    x = jnp.zeros((2, 32))
    t = quantize(x, fmt)
    assert np.all(np.asarray(t.mantissa) == 0)
    np.testing.assert_array_equal(np.asarray(dequantize(t)), np.zeros((2, 32)))


def test_nonuniform_blocks_isolate_outliers():
    """The point of microscaling: an outlier only wrecks its own block."""
    fmt = MXFormat(mant_bits=8, block_size=16)
    x = np.full((1, 64), 0.01, np.float32)
    x[0, 0] = 1000.0  # outlier in block 0
    got = np.asarray(quantize_dequantize(jnp.asarray(x), fmt))
    # blocks 1..3 must be almost exact despite the outlier
    np.testing.assert_allclose(got[0, 16:], x[0, 16:], rtol=2 ** -7)
    # per-tensor int8 would flatten 0.01 to zero everywhere
    per_tensor_lsb = 1000.0 / 127
    assert per_tensor_lsb > 0.01


def test_block_clamping_non_divisible():
    fmt = MXFormat(mant_bits=8, block_size=256)
    x = jnp.ones((4, 512 // 4))  # dim 128 < 256 -> clamp to 128
    t = quantize(x, fmt)
    assert t.block_size == 128
    x2 = jnp.ones((4, 96))  # 96 = 3*32: largest divisor <= 256 is 96
    t2 = quantize(x2, fmt)
    assert t2.block_size == 96


def test_quantize_axis0():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    fmt = MXFormat(mant_bits=8, block_size=16)
    t = quantize(x, fmt, axis=0)
    assert t.exponent.shape == (4, 8)
    got = dequantize(t)
    assert float(jnp.max(jnp.abs(got - x))) < 0.1


def test_requantize_to_max_exponent_monotone():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
    t = quantize(x, MXFormat(8, 16))
    m, lam = requantize_to_max_exponent(t, axis=-1)
    # reconstruction with the shared exponent only loses low bits
    rec = m.astype(jnp.float32) * jnp.exp2(lam.astype(jnp.float32))
    assert float(jnp.max(jnp.abs(rec - x))) <= float(
        jnp.max(jnp.exp2(lam.astype(jnp.float32)))) + 0.1


def test_fake_quant_straight_through_grad():
    x = jnp.linspace(-2, 2, 32)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, 8, 16, -1) ** 2))(x)
    # STE: grad flows as if identity (2*x_hat for chain of square), no zeros
    # where x is nonzero.
    assert g.shape == x.shape
    assert float(jnp.sum(jnp.abs(g))) > 0


def test_packed_bytes_counts_subbyte():
    w = jnp.ones((256, 4))
    t = pack_weight(w, MXFormat(6, 256), axis=0)
    # 1024 elems * 6 bits + 4 exps * 8 bits = 6176 bits = 772 bytes
    assert t.nbytes_packed() == (1024 * 6 + 4 * 8) // 8
    assert packed_bytes({"w": t, "b": jnp.ones((4,), jnp.float32)}) == \
        t.nbytes_packed() + 16


def test_mxtensor_is_pytree():
    t = quantize(jnp.ones((4, 16)), MXFormat(8, 16))
    leaves, treedef = jax.tree_util.tree_flatten(t)
    assert len(leaves) == 2
    t2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(t2, MXTensor) and t2.mant_bits == 8


# ---------------------------------------------------------------------------
# property-based
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(
    mant_bits=st.integers(min_value=3, max_value=10),
    block=st.sampled_from([4, 8, 16, 32]),
    scale=st.floats(min_value=1e-3, max_value=1e3),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_property_error_shrinks_with_bits(mant_bits, block, scale, seed):
    """Quantization error is bounded by half an LSB of each block and
    strictly improves (weakly) when adding a mantissa bit."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32)) * scale
    f_lo = MXFormat(mant_bits=mant_bits, block_size=block)
    f_hi = MXFormat(mant_bits=mant_bits + 1, block_size=block)
    e_lo = float(jnp.mean(jnp.abs(quantize_dequantize(x, f_lo) - x)))
    e_hi = float(jnp.mean(jnp.abs(quantize_dequantize(x, f_hi) - x)))
    assert e_hi <= e_lo * 1.01 + 1e-9


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_property_quantize_idempotent(seed):
    """Q(Q(x)) == Q(x): quantization is a projection."""
    rng = np.random.default_rng(seed)
    fmt = MXFormat(mant_bits=8, block_size=16)
    x = jnp.asarray(rng.normal(size=(2, 48)).astype(np.float32))
    once = quantize_dequantize(x, fmt)
    twice = quantize_dequantize(once, fmt)
    np.testing.assert_allclose(np.asarray(twice), np.asarray(once),
                               rtol=0, atol=0)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       sign=st.sampled_from([-1.0, 1.0]))
def test_property_sign_symmetry(seed, sign):
    """Q(-x) == -Q(x) up to the asymmetric int min (clip guards it)."""
    rng = np.random.default_rng(seed)
    fmt = MXFormat(mant_bits=8, block_size=16)
    x = jnp.asarray(rng.normal(size=(2, 32)).astype(np.float32))
    a = np.asarray(quantize_dequantize(x, fmt))
    b = np.asarray(quantize_dequantize(-x, fmt))
    np.testing.assert_allclose(-b, a, atol=float(np.max(np.abs(a))) * 2 ** -7)
