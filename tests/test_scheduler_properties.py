"""Property-based harness for slot-level continuous batching (ISSUE 7).

The contract under test: ``BatchScheduler`` (slot admission, per-row
KV-cache indices) is TOKEN-IDENTICAL to running every request alone
through the engine — an unbatched one-request-at-a-time oracle — for
any stream of ragged prompt lengths / eos positions / max_new_tokens.
In ``mode='off'`` this holds bit-exactly: right-padded slot prefill
masks pad keys to NEG_INF, whose exp underflows to exactly 0, and
per-row decode validity hides the other rows' ring slots, so batching
is numerically invisible.

Four properties per stream:
  * token identity: each uid's ``generated`` equals the oracle's;
  * conservation: no request lost, duplicated, or left unfinished;
  * zero recompiles: ``engine.jit_cache_size()`` flat after warmup
    (one decode spec per batch shape, one slot-prefill spec per
    prompt-length bucket);
  * telemetry conservation (DESIGN.md §15): the scheduler's counters
    tell the same story — ``scheduler/submitted == scheduler/completed
    + scheduler/in_flight`` at every step boundary, everything
    completed after the drain, and the ``serving/recompiles`` counter
    still 0 after warmup.

The stream checker is plain code; a seeded test drives it always, and
the hypothesis suite (optional dep, ``slow`` marker — the full CI lane
runs it with a fixed seed) searches the stream space around it.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry as T
from repro.core.mx_types import QuantConfig
from repro.models.model_api import ModelConfig
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.scheduler import BatchScheduler, Request

pytestmark = pytest.mark.slow    # model-in-the-loop property suite

VOCAB = 50
EOS = 7                          # a likely token id in a 50-vocab model
MAX_PROMPT = 12
PREFILL_LEN = 16                 # one fixed slot-prefill bucket


@pytest.fixture(scope="module")
def engine():
    from repro.models.transformer import DecoderLM
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                      d_ff=64, vocab=VOCAB, ffn_kind="gelu",
                      dtype=jnp.float32, quant=QuantConfig(mode="off"))
    model = DecoderLM(cfg)
    params = model.init(jax.random.key(0))
    return ServingEngine(model, params, ServeConfig(max_len=64, batch=4))


def oracle_generate(eng, prompt, max_new, eos):
    """One request, alone, through the engine's own prefill/decode jits
    — the unbatched reference stream."""
    cache = eng.model.cache_init(1, eng.cfg.max_len)
    logits, cache = eng._prefill(
        eng.params, {"tokens": jnp.asarray(prompt[None])}, cache)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    while len(out) < max_new and out[-1] != eos:
        tok, cache = eng._decode(eng.params, tok, cache)
        out.append(int(tok[0, 0]))
    return out


def make_stream(spec, seed):
    """spec: list of (prompt_len, max_new) -> list of Requests with
    deterministic prompts."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid, (plen, max_new) in enumerate(spec):
        prompt = rng.integers(1, VOCAB, plen).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new_tokens=max_new))
    return reqs


def _assert_telemetry_conserved():
    """submitted == completed + in_flight, from one coherent snapshot."""
    snap = T.snapshot()
    submitted = snap["counters"].get("scheduler/submitted", 0)
    completed = snap["counters"].get("scheduler/completed", 0)
    in_flight = snap["gauges"].get("scheduler/in_flight", 0)
    assert submitted == completed + in_flight, snap["counters"]


def check_stream(eng, spec, seed, batch_size, check_jit=False):
    """Run one request stream through the slot scheduler and the oracle;
    assert the four properties."""
    want = {r.uid: oracle_generate(eng, r.prompt, r.max_new_tokens, EOS)
            for r in make_stream(spec, seed)}

    reqs = make_stream(spec, seed)
    sched = BatchScheduler(eng, batch_size=batch_size, eos_id=EOS,
                           prefill_len=PREFILL_LEN)
    if check_jit:
        # warm both jits on a throwaway request (max_new 2 so the
        # batch-shape decode compiles too), then demand flatness
        warm = [(1, 2)]
        wsched = BatchScheduler(eng, batch_size=batch_size, eos_id=EOS,
                                prefill_len=PREFILL_LEN)
        for r in make_stream(warm, seed=99):
            wsched.submit(dataclasses.replace(r, uid=-1))
        wsched.run()
        base = eng.jit_cache_size()
    # fresh counters for this stream (warmup/oracle traffic excluded);
    # the engine keeps its jit-cache baseline, so any recompile in the
    # main stream would still land in the re-created counter
    T.reset("scheduler/")
    T.reset("serving/")
    for r in reqs:
        sched.submit(r)
    done = sched.run(max_steps=4096)

    # conservation: every uid exactly once, all finished
    uids = [r.uid for r in done]
    assert sorted(uids) == sorted(want), (uids, list(want))
    assert all(r.done for r in done)
    # token identity, in-order per uid
    for r in done:
        assert r.generated == want[r.uid], (
            r.uid, r.generated, want[r.uid])
    # telemetry tells the same conservation story after the drain
    snap = T.snapshot()
    assert snap["counters"].get("scheduler/submitted", 0) == len(spec)
    assert snap["counters"].get("scheduler/completed", 0) == len(spec)
    assert snap["gauges"].get("scheduler/in_flight", 1) == 0
    assert snap["gauges"].get("scheduler/queue_depth", 1) == 0
    assert snap["histograms"][
        "scheduler/request_latency_ms"]["count"] == len(spec)
    if check_jit and base >= 0:
        assert eng.jit_cache_size() == base   # zero recompiles
        assert snap["counters"].get("serving/recompiles", 0) == 0
    return done


class TestSlotSchedulerSeeded:
    """Deterministic stream shapes that always run (no hypothesis dep)."""

    def test_ragged_stream_matches_oracle(self, engine):
        spec = [(3, 5), (12, 2), (1, 6), (7, 4), (5, 1), (9, 6), (2, 3)]
        check_stream(engine, spec, seed=0, batch_size=3, check_jit=True)

    def test_burst_larger_than_batch(self, engine):
        spec = [(4, 3)] * 9                     # 3x capacity, same shape
        check_stream(engine, spec, seed=1, batch_size=3)

    def test_single_token_requests(self, engine):
        spec = [(2, 1), (6, 1), (1, 1), (8, 1)]  # done straight from prefill
        check_stream(engine, spec, seed=2, batch_size=2)

    def test_batch_one_degenerates_to_sequential(self, engine):
        spec = [(5, 4), (3, 6), (11, 2)]
        check_stream(engine, spec, seed=3, batch_size=1)

    def test_telemetry_conserved_at_every_step(self, engine):
        """submitted == completed + in_flight holds at EVERY step
        boundary (not just after the drain), through a mid-stream
        late submit, and the recompile counter stays 0 after warmup."""
        # warmup: compile decode (batch 3) + slot prefill, set baseline
        wsched = BatchScheduler(engine, batch_size=3, eos_id=EOS,
                                prefill_len=PREFILL_LEN)
        for r in make_stream([(1, 2)], seed=99):
            wsched.submit(dataclasses.replace(r, uid=-1))
        wsched.run()

        T.reset("scheduler/")
        T.reset("serving/")
        sched = BatchScheduler(engine, batch_size=3, eos_id=EOS,
                               prefill_len=PREFILL_LEN)
        reqs = make_stream([(3, 5), (12, 2), (1, 6), (7, 4), (5, 3)],
                           seed=4)
        late = reqs.pop()
        for r in reqs:
            sched.submit(r)
            _assert_telemetry_conserved()
        for i in range(4096):
            alive = sched.step()
            _assert_telemetry_conserved()
            if i == 2:
                sched.submit(late)      # churn mid-stream
                _assert_telemetry_conserved()
            if alive == 0 and not sched.queue:
                break
        done = sched.run(max_steps=4096)   # final evict bookkeeping
        assert sorted(r.uid for r in done) == [0, 1, 2, 3, 4]
        snap = T.snapshot()
        assert snap["counters"]["scheduler/submitted"] == 5
        assert snap["counters"]["scheduler/completed"] == 5
        assert snap["counters"]["scheduler/admissions"] == 5
        assert snap["gauges"]["scheduler/in_flight"] == 0
        assert snap["gauges"]["scheduler/slots_active"] == 0
        assert snap["counters"].get("serving/recompiles", 0) == 0
        assert snap["counters"]["scheduler/tokens_generated"] >= 5


try:                                     # optional dep: only the search
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                      # class skips, seeded tests run
    _HAVE_HYPOTHESIS = False


@pytest.mark.skipif(not _HAVE_HYPOTHESIS,
                    reason="property search needs the optional "
                           "hypothesis dep")
class TestSlotSchedulerHypothesis:
    """Search the stream space: ragged lengths, eos-truncated streams,
    odd batch sizes.  The full CI lane runs this with a fixed seed and
    --hypothesis-show-statistics (.github/workflows/ci.yml)."""

    if _HAVE_HYPOTHESIS:
        @settings(max_examples=12, deadline=None)
        @given(spec=st.lists(st.tuples(st.integers(1, MAX_PROMPT),
                                       st.integers(1, 6)),
                             min_size=1, max_size=8),
               seed=st.integers(0, 31),
               batch_size=st.integers(1, 4))
        def test_stream_matches_oracle(self, engine, spec, seed,
                                       batch_size):
            check_stream(engine, spec, seed, batch_size)
