"""Per-architecture smoke tests: reduced configs of the same family run one
forward + gradient step (and a prefill/decode step) on CPU; outputs must
have the right shapes and contain no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_config, full_config
from repro.configs.deit import DEIT_MICRO, BY_NAME
from repro.models import build_model, unwrap


def _batch_for(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (b, s)).astype(np.int32))}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_tokens, cfg.vision_dim))
            .astype(np.float32))
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={float(loss)}"
    leaves = jax.tree_util.tree_leaves(unwrap(grads))
    assert leaves, arch
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch_for(cfg)
    cache = model.cache_init(2, 32)
    if cfg.is_encoder_decoder:
        logits, cache = model.prefill(params, batch["frames"],
                                      batch["tokens"], cache)
    else:
        logits, cache = model.prefill(params, batch["tokens"], cache,
                                      batch.get("vision_embeds"))
    assert logits.shape == (2, 1, cfg.vocab), (arch, logits.shape)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        assert logits.shape == (2, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact published dims."""
    expected = {
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "phi4_mini_3_8b": (32, 3072, 24, 8, 8192, 200064),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
    }[arch]
    cfg = full_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected, (arch, got, expected)
    cfg.validate()
    # family-specific invariants
    if arch == "mixtral_8x7b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
        assert cfg.window == 4096
    if arch == "granite_moe_3b_a800m":
        assert cfg.moe.num_experts == 40 and cfg.moe.top_k == 8
    if arch == "qwen3_14b":
        assert cfg.qk_norm
    if arch == "recurrentgemma_2b":
        assert cfg.unit == ("rec", "rec", "attn") and cfg.tail == ("rec",
                                                                   "rec")
    if arch == "xlstm_350m":
        assert cfg.unit.count("slstm") == 1 and cfg.unit.count("mlstm") == 7
    if arch == "seamless_m4t_medium":
        assert cfg.is_encoder_decoder and cfg.n_encoder_layers == 12
    if arch == "llava_next_mistral_7b":
        assert cfg.vision_tokens == 2880


@pytest.mark.parametrize("name", ["deit_tiny", "deit_small", "deit_base"])
def test_deit_configs(name):
    expected = {"deit_tiny": (192, 3, 768), "deit_small": (384, 6, 1536),
                "deit_base": (768, 12, 3072)}[name]
    cfg = BY_NAME[name]
    assert (cfg.d_model, cfg.n_heads, cfg.d_ff) == expected
    assert cfg.n_layers == 12 and cfg.n_classes == 1000


def test_deit_micro_trains():
    model = build_model(DEIT_MICRO)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    batch = {"images": jnp.asarray(rng.normal(size=(4, 32, 32, 3))
                                   .astype(np.float32)),
             "labels": jnp.asarray([0, 1, 2, 3], jnp.int32)}
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch))(params)
    assert np.isfinite(float(loss))
    for g in jax.tree_util.tree_leaves(unwrap(grads)):
        assert np.isfinite(np.asarray(g)).all()


def test_deit_mxint_sim_mode_end_to_end():
    """The paper's configuration: full bit-accurate MXInt datapath."""
    import dataclasses as dc
    from repro.core.mx_types import QuantConfig
    cfg = dc.replace(DEIT_MICRO, quant=QuantConfig(
        mode="sim", quantize_nonlinear=True))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(2)
    imgs = jnp.asarray(rng.normal(size=(2, 32, 32, 3)).astype(np.float32))
    logits = model.logits(params, imgs)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()
    # and it agrees with the float model to within quantization error
    float_model = build_model(DEIT_MICRO)
    ref = float_model.logits(params, imgs)
    cos = float(jnp.vdot(logits.ravel(), ref.ravel()) /
                (jnp.linalg.norm(logits) * jnp.linalg.norm(ref)))
    assert cos > 0.95, cos
