"""Shared test hooks.

When ``REPRO_METRICS_JSON`` is set (the CI lanes set it), the telemetry
snapshot accumulated across the whole test session — serving spans,
scheduler counters, kernel fallback counts — is dumped there at exit
and archived next to the repro_lint report (DESIGN.md §15)."""
import os


def pytest_sessionfinish(session, exitstatus):
    path = os.environ.get("REPRO_METRICS_JSON")
    if not path:
        return
    try:
        from repro.telemetry.export import json_snapshot
        json_snapshot(path=path, extra={"pytest_exit_status": int(exitstatus)})
    except Exception as exc:       # never fail the run over the dump
        print(f"[conftest] metrics dump skipped: {exc}")
