"""Docs layer stays real: DESIGN.md sections cited in code must exist.

Runs ``tools/check_docs.py`` (the same script CI runs) and asserts the
repo has no dangling ``DESIGN.md §N`` citations, plus a few structural
guarantees the docs make to readers.
"""
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_design_citations_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py")],
        capture_output=True, text=True, timeout=120, cwd=str(ROOT))
    assert proc.returncode == 0, proc.stderr


def test_design_covers_quant_modes_and_equations():
    text = (ROOT / "docs" / "DESIGN.md").read_text()
    for mode in ("`off`", "`fake`", "`sim`", "`packed`", "`kernel`"):
        assert mode in text, f"DESIGN.md §4 must document mode {mode}"
    for eq in ("Eq. 2", "Eq. 3", "Eq. 12", "Eq. 14", "Eq. 20"):
        assert eq in text, f"DESIGN.md must map paper {eq} to source"


def test_readme_module_map_points_at_real_modules():
    text = (ROOT / "README.md").read_text()
    for mod in ("core/", "kernels/", "serving/", "parallel/", "launch/"):
        assert mod in text
        assert (ROOT / "src" / "repro" / mod.rstrip("/")).is_dir()


def test_no_tracked_binaries():
    """PR-1 accidentally committed __pycache__ binaries and two .npz
    benchmark caches; never again (mirrors the CI check)."""
    proc = subprocess.run(["git", "ls-files"], capture_output=True,
                          text=True, timeout=60, cwd=str(ROOT))
    if proc.returncode != 0:
        return                                 # not a git checkout (sdist)
    bad = [f for f in proc.stdout.splitlines()
           if f.endswith((".pyc", ".pyo", ".npz", ".npy"))
           or "__pycache__" in f]
    assert not bad, f"tracked binaries: {bad}"
