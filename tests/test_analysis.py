"""Self-tests for the static-analysis passes (DESIGN.md §13).

Two layers: (1) every deliberately violating fixture must FIRE its rule
(a rule that cannot flag its own counterexample is dead code) and the
real tree must be clean; (2) the ``tools/repro_lint.py`` CLI must mirror
that in its exit codes — 0 on the tree, non-zero per fixture (the
acceptance contract; subprocess-marked ``slow``).
"""
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import pytest

import repro.analysis as AN
from repro.analysis import cost_model as CM
from repro.analysis import grid_semantics as GS
from repro.analysis import kernel_contracts as KC
from repro.analysis import source_rules as SR
from repro.analysis import trace_lint as TL
from repro.analysis.fixtures import FIXTURE_RULES, FIXTURES, run_fixture

ROOT = Path(__file__).resolve().parents[1]
LINT = ROOT / "tools" / "repro_lint.py"


# ---------------------------------------------------------------------------
# fixtures must fire
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fixture_fires(name):
    violations = run_fixture(name)
    assert violations, f"fixture {name!r} reported nothing — dead rule"
    assert any(v.rule == FIXTURE_RULES[name] for v in violations), \
        (name, [v.rule for v in violations])


def test_fixture_messages_name_the_defect():
    msgs = " ".join(str(v) for v in run_fixture("vmem-over-budget"))
    assert "VMEM" in msgs and "cap" in msgs
    msgs = " ".join(str(v) for v in run_fixture("uncovered-output-block"))
    assert "never writes" in msgs


# ---------------------------------------------------------------------------
# the real tree is clean (the same passes CI runs, in-process)
# ---------------------------------------------------------------------------
def _errors(violations):
    return [v for v in violations if v.severity == AN.ERROR]


def test_source_rules_clean_on_tree():
    assert _errors(SR.run(ROOT)) == []


def test_kernel_contracts_clean_on_tree():
    caps = KC.sweep_captures()
    assert len(caps) >= 8, "sweep shrank — kernels or recorder moved"
    assert _errors(KC.check_captures(caps)) == []


def test_trace_invariants_clean_on_tree():
    assert _errors(TL.run(ROOT)) == []


def test_grid_semantics_clean_on_tree():
    """Every swept pallas_call declares dimension_semantics consistent
    with its revisit/gate evidence (ISSUE 8 acceptance)."""
    caps = KC.sweep_captures()
    assert _errors(GS.check_captures_semantics(caps)) == [], \
        [str(v) for v in GS.check_captures_semantics(caps)]


def test_all_captures_declare_semantics():
    for cap in KC.sweep_captures():
        assert cap.dimension_semantics is not None, cap.label
        assert len(cap.dimension_semantics) == len(cap.grid), cap.label


def test_grid_semantics_sees_the_accumulator_gates():
    """The AST scan resolves gates through partials AND the flash
    kernels' helper call — the evidence the race check rests on."""
    caps = {c.label: c for c in KC.sweep_captures()}
    for label, axis in (("matmul-bench", 2), ("ln-matmul-bench", 1),
                        ("flash-bench", 2), ("flash-decode", 2)):
        facts = GS.kernel_body_facts(caps[label])
        assert facts.src_ok, label
        assert axis in {g.axis for g in facts.gates}, (label, facts.gates)


def test_cost_model_clean_on_tree():
    assert _errors(CM.run(ROOT)) == [], [str(v) for v in CM.run(ROOT)]


def test_cost_model_reproduces_deit_fusion_saving():
    """The static model must reproduce the ~23% LN->qkv HBM saving the
    bench's analytic counters claim (ISSUE 8 acceptance)."""
    fus = CM.fusion_study()
    assert 20.0 <= fus["saving_pct"] <= 26.0, fus["saving_pct"]
    assert fus["fused_bytes"] < fus["unfused_bytes"]


def test_cost_model_counts_planes_separately():
    """Mantissa and exponent planes appear as separate int8 operands."""
    rows = {r["label"]: r for r in CM.build_table()}
    ops = rows["ln-matmul-bench"]["operands"]
    int8 = [o for o in ops if o["dtype"] == "int8"]
    assert len(int8) == 2, ops
    assert {o["bytes_unique"] for o in int8} == {768 * 768, 24 * 768}


# ---------------------------------------------------------------------------
# pass mechanics
# ---------------------------------------------------------------------------
def test_suppression_comment_waives_and_scopes():
    bad = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return jnp.exp(x)\n")
    rel = "src/repro/models/somewhere.py"
    assert SR.check_source(bad, rel)
    ok = bad.replace(
        "    return jnp.exp(x)",
        "    # repro-lint: allow[models-float-nonlinear] test reason\n"
        "    return jnp.exp(x)")
    assert SR.check_source(ok, rel) == []
    # a suppression naming a DIFFERENT rule does not waive
    wrong = bad.replace(
        "    return jnp.exp(x)",
        "    # repro-lint: allow[neg-inf-literal] wrong rule\n"
        "    return jnp.exp(x)")
    assert SR.check_source(wrong, rel)


def test_models_scope_only():
    """The float-nonlinear rule only binds inside src/repro/models/."""
    bad = "import jax\ny = jax.nn.softmax\n\ndef f(x):\n    return jax.nn.softmax(x)\n"
    assert SR.check_source(bad, "src/repro/models/m.py")
    assert SR.check_source(bad, "src/repro/datapath/b.py") == []
    assert SR.check_source(bad, "tests/t.py") == []


def test_neg_inf_literal_allowed_only_at_home():
    text = "NEG_INF = -2.0e38\n"
    assert SR.check_source(text, "src/repro/core/mx_types.py") == []
    assert SR.check_source(text, "src/repro/kernels/ops.py")


def test_capture_returns_real_blockspecs():
    caps = KC.sweep_captures()
    byk = {c.kernel for c in caps}
    assert {"_mxint_matmul_kernel", "_mxint_layernorm_kernel",
            "_mxint_softmax_kernel", "_mxint_gelu_kernel",
            "_mxint_ln_matmul_kernel", "_flash_kernel",
            "_decode_kernel"} <= byk
    ln = next(c for c in caps if c.kernel == "_mxint_ln_matmul_kernel")
    # the documented model-dtype scratch contract is actually visible
    assert ln.scratch[0].dtype == ln.inputs[0].dtype


def test_trace_lint_flags_xla_backend_with_pallas():
    """forbid_pallas fires when an XLA-mode trace lowers a kernel."""
    from repro.kernels import ops

    rules = TL.TraceRules(forbid_pallas=True)
    x = jnp.zeros((8, 128), jnp.float32)
    vs = TL.lint_fn(lambda a: ops.mxint_softmax_op(a), (x,), rules,
                    "fixture:pallas-in-xla")
    assert any("pallas_call" in v.message for v in vs)


def test_slot_step_target_within_pallas_budget():
    """ISSUE 7: the slot scheduler's mixed slot-prefill + decode step
    lints clean — exactly 17 pallas_calls (8 prefill + 9 decode).  A
    drift means the per-row index plumbing dropped or duplicated a
    kernel.  (Prefill's float online-softmax is by design — see the
    target's docstring; decode-phase nonlinear denial is pinned by the
    decode-step target.)"""
    vs = TL._slot_step_kernel_target()
    assert vs == [], [str(v) for v in vs]


def test_registry_rejects_duplicates():
    with pytest.raises(ValueError):
        AN.register_rule("kernel-contracts", "dup")(lambda root: [])


# ---------------------------------------------------------------------------
# the CLI contract (subprocess — slow lane)
# ---------------------------------------------------------------------------
def _run_lint(*args):
    return subprocess.run(
        [sys.executable, str(LINT), *args], cwd=ROOT, capture_output=True,
        text=True, timeout=900)


@pytest.mark.slow
def test_repro_lint_exits_zero_on_tree():
    r = _run_lint()
    assert r.returncode == 0, r.stderr
    assert "clean" in r.stdout


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_repro_lint_fixture_exits_nonzero(name):
    r = _run_lint("--fixture", name)
    assert r.returncode != 0, (name, r.stdout, r.stderr)
    assert FIXTURE_RULES[name] in r.stderr


@pytest.mark.slow
def test_repro_lint_lists_all_rules():
    r = _run_lint("--list")
    assert r.returncode == 0
    for rule in ("kernel-contracts", "grid-semantics", "cost-model",
                 "trace-invariants", "source-rules", "dispatch-seam",
                 "docs-links"):
        assert rule in r.stdout


@pytest.mark.slow
def test_repro_lint_json_roofline_table():
    """--only cost-model --json emits the machine-readable roofline the
    CI lanes archive and benchmarks/roofline.py ingests."""
    import json

    r = _run_lint("--only", "cost-model", "--json")
    assert r.returncode == 0, r.stderr
    payload = json.loads(r.stdout)
    rows = {row["label"]: row for row in payload["cost_model"]["rows"]}
    assert "ln-matmul-bench" in rows and "flash-deit" in rows
    for row in rows.values():
        assert row["hbm_bytes"] > 0 and row["vmem_bytes"] > 0
    fusion = payload["cost_model"]["fusion"]
    assert 20.0 <= fusion["saving_pct"] <= 26.0
