"""Training substrate: data determinism, optimizer, checkpoint/restart,
fault-tolerant loop resume, gradient compression."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import (SyntheticImageData, SyntheticLMData,
                                 SyntheticSeq2SeqData, DataState)
from repro.models import build_model, unwrap
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.state import make_train_state
from repro.train.step import make_train_step


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
class TestDataPipeline:
    def test_deterministic_and_seekable(self):
        d1 = SyntheticLMData(vocab=100, batch=4, seq_len=16, seed=7)
        d2 = SyntheticLMData(vocab=100, batch=4, seq_len=16, seed=7)
        b1 = [d1.next_batch() for _ in range(3)]
        d2.state.next_index = 2
        b2 = d2.next_batch()
        np.testing.assert_array_equal(np.asarray(b1[2]["tokens"]),
                                      np.asarray(b2["tokens"]))

    def test_shards_disjoint(self):
        a = SyntheticLMData(vocab=100, batch=8, seq_len=16, seed=1,
                            shard_index=0, num_shards=2)
        b = SyntheticLMData(vocab=100, batch=8, seq_len=16, seed=1,
                            shard_index=1, num_shards=2)
        ba, bb = a.next_batch(), b.next_batch()
        assert ba["tokens"].shape == (4, 16)
        assert not np.array_equal(np.asarray(ba["tokens"]),
                                  np.asarray(bb["tokens"]))

    def test_lm_stream_is_learnable(self):
        """Bigram structure: successor entropy must be far below uniform."""
        d = SyntheticLMData(vocab=64, batch=32, seq_len=64, seed=3)
        toks = np.asarray(d.next_batch()["tokens"])
        pairs = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a), []).append(int(b))
        distinct = np.mean([len(set(v)) for v in pairs.values()
                            if len(v) >= 4])
        assert distinct < 16   # 4 successors + noise, far below vocab

    def test_image_and_seq2seq_shapes(self):
        im = SyntheticImageData(n_classes=10, batch=4, image_size=32,
                                seed=0).next_batch()
        assert im["images"].shape == (4, 32, 32, 3)
        s2s = SyntheticSeq2SeqData(vocab=50, batch=2, seq_len=8, d_model=16,
                                   seed=0).next_batch()
        assert s2s["frames"].shape == (2, 8, 16)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
class TestAdamW:
    def test_quadratic_convergence(self):
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = adamw_init(params)
        cfg = AdamWConfig(weight_decay=0.0, clip_norm=0.0)
        for _ in range(300):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = adamw_update(grads, state, params,
                                            jnp.asarray(0.05), cfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.05

    def test_clipping(self):
        params = {"w": jnp.ones((4,))}
        state = adamw_init(params)
        grads = {"w": jnp.full((4,), 1e6)}
        _, _, norm = adamw_update(grads, state, params, jnp.asarray(1e-3),
                                  AdamWConfig(clip_norm=1.0))
        assert float(norm) > 1e5   # reported pre-clip norm

    def test_schedule_shape(self):
        lrs = [float(cosine_schedule(jnp.asarray(s), peak=1.0,
                                     warmup_steps=10, total_steps=100))
               for s in range(0, 100, 10)]
        assert lrs[0] < lrs[1]          # warmup
        assert lrs[-1] < lrs[2]         # decay


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
class TestCheckpoint:
    def _state(self, seed=0):
        cfg = smoke_config("llama3_8b")
        model = build_model(cfg)
        return model, make_train_state(model, jax.random.key(seed))

    def test_save_restore_roundtrip(self, tmp_path):
        model, state = self._state()
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        mgr.save(3, state, extra={"data_state": {"seed": 1,
                                                 "next_index": 42}})
        like = jax.eval_shape(lambda: state)
        restored, extra = mgr.restore(like)
        assert extra["data_state"]["next_index"] == 42
        for a, b in zip(jax.tree_util.tree_leaves(unwrap(state.params)),
                        jax.tree_util.tree_leaves(unwrap(restored.params))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_commit_ignores_tmp(self, tmp_path):
        model, state = self._state()
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, state)
        # simulate a crashed writer
        crash = tmp_path / "step_000002.tmp"
        crash.mkdir()
        (crash / "manifest.json").write_text("{corrupt")
        assert mgr.latest_step() == 1
        restored, _ = mgr.restore(jax.eval_shape(lambda: state))
        assert int(restored.step) == int(state.step)

    def test_retention(self, tmp_path):
        model, state = self._state()
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        steps = sorted(p.name for p in tmp_path.iterdir()
                       if p.name.startswith("step_"))
        assert steps == ["step_000003", "step_000004"]

    def test_elastic_restore_dtype_cast(self, tmp_path):
        """Restore into a different param dtype (elastic/requantize path)."""
        model, state = self._state()
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, state)
        like = jax.eval_shape(lambda: state)
        like = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            like)
        restored, _ = mgr.restore(like)
        leaf = jax.tree_util.tree_leaves(unwrap(restored.params))[0]
        assert leaf.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# end-to-end loop with crash/resume
# ---------------------------------------------------------------------------
class TestTrainLoop:
    def _setup(self, tmp_path, total=6):
        cfg = smoke_config("llama3_8b")
        model = build_model(cfg)
        state = make_train_state(model, jax.random.key(0))
        data = SyntheticLMData(vocab=cfg.vocab, batch=4, seq_len=16, seed=5)
        step = jax.jit(make_train_step(
            model, lr_fn=lambda s: jnp.asarray(1e-3, jnp.float32)))
        lcfg = LoopConfig(total_steps=total, checkpoint_every=2, log_every=1,
                          checkpoint_dir=str(tmp_path / "ck"),
                          metrics_path=str(tmp_path / "metrics.jsonl"),
                          heartbeat_path=str(tmp_path / "hb.json"))
        return model, state, data, step, lcfg

    def test_loss_decreases(self, tmp_path):
        cfg = smoke_config("llama3_8b")
        model = build_model(cfg)
        state = make_train_state(model, jax.random.key(0))
        data = SyntheticLMData(vocab=cfg.vocab, batch=8, seq_len=32, seed=5)
        step = jax.jit(make_train_step(
            model, lr_fn=lambda s: jnp.asarray(3e-3, jnp.float32)))
        lcfg = LoopConfig(total_steps=60, checkpoint_every=1000, log_every=1,
                          checkpoint_dir=str(tmp_path / "ck"))
        loop = TrainLoop(train_step=step, state=state, data=data, cfg=lcfg)
        metrics = loop.run(start_step=0)
        first = np.mean([m["loss"] for m in metrics[:5]])
        last = np.mean([m["loss"] for m in metrics[-5:]])
        assert last < first - 0.1, (first, last)

    def test_crash_resume_continues_exactly(self, tmp_path):
        model, state, data, step, lcfg = self._setup(tmp_path, total=4)
        loop = TrainLoop(train_step=step, state=state, data=data, cfg=lcfg)
        loop.run()                      # runs to 4, ckpt at 2 and 4
        # "crash": new process = new loop object from scratch
        state2 = make_train_state(model, jax.random.key(0))
        data2 = SyntheticLMData(vocab=512, batch=4, seq_len=16, seed=5)
        lcfg2 = dataclasses.replace(lcfg, total_steps=6)
        loop2 = TrainLoop(train_step=step, state=state2, data=data2,
                          cfg=lcfg2)
        resumed_from = loop2.try_resume()
        assert resumed_from == 4
        assert int(loop2.state.step) == int(loop.state.step)
        assert data2.state.next_index == data.state.next_index
        loop2.run(start_step=resumed_from)
        assert int(loop2.state.step) == 6
        # heartbeat reflects the last step
        hb = json.loads((tmp_path / "hb.json").read_text())
        assert hb["step"] == 6


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
class TestGradCompression:
    def test_compression_preserves_convergence(self):
        """EF-SGD property: compressed training still converges on a
        quadratic (error feedback recovers what quantization drops)."""
        from repro.core import gradient_compression as gc
        w = jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                        jnp.float32)
        target = jnp.ones((64,))
        err = jnp.zeros((64,))
        for _ in range(200):
            g = 2 * (w - target)
            mx, deq, residual, pad = gc.compress_leaf(g + err)
            err = residual
            w = w - 0.05 * deq
        assert float(jnp.max(jnp.abs(w - target))) < 0.05

    def test_compression_ratio(self):
        from repro.core import gradient_compression as gc
        assert gc.compression_ratio() > 3.5   # ~3.88x vs f32
