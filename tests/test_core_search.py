"""Direct unit tests for core/search.py (the paper's §III-A greedy
loop and its metrics) and the strengthened MXFormat validation —
previously exercised only through benchmarks/greedy_search_bench.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mx_types import MXFormat
from repro.core.search import (argmax_agreement, cosine_fidelity,
                               greedy_bitwidth_search)


class TestMetrics:
    def test_argmax_agreement_exact(self):
        a = jnp.asarray([[0.1, 0.9], [0.8, 0.2], [0.3, 0.7], [0.6, 0.4]])
        b = jnp.asarray([[0.2, 0.8], [0.1, 0.9], [0.4, 0.6], [0.9, 0.1]])
        # rows 0, 2, 3 agree on argmax; row 1 flips
        assert argmax_agreement(a, b) == pytest.approx(0.75)
        assert argmax_agreement(a, a) == 1.0

    def test_cosine_fidelity(self):
        a = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
        assert cosine_fidelity(a, a) == pytest.approx(1.0, abs=1e-6)
        assert cosine_fidelity(a, -a) == pytest.approx(-1.0, abs=1e-6)
        assert cosine_fidelity(a, 10.0 * a) == pytest.approx(1.0, abs=1e-6)
        b = jnp.asarray([[2.0, -1.0], [4.0, -3.0]])   # orthogonal to a
        assert cosine_fidelity(a, b) == pytest.approx(0.0, abs=1e-6)


def _threshold_apply_fn(thresholds, n_rows=8):
    """apply_fn whose output argmax degrades per group below a
    threshold width: each group below threshold flips a distinct 25% of
    the rows, so the agreement drop is additive and deterministic."""
    base = np.zeros((n_rows, 4), np.float32)
    base[:, 0] = 1.0

    def apply_fn(bits):
        out = base.copy()
        for gi, (g, t) in enumerate(sorted(thresholds.items())):
            if bits[g] < t:
                rows = slice(2 * gi, 2 * gi + 2)      # 2/8 rows = 25%
                out[rows] = 0.0
                out[rows, 1 + gi % 3] = 1.0
        return jnp.asarray(out)

    return apply_fn


class TestGreedyBitwidthSearch:
    def test_stops_at_per_group_thresholds(self):
        apply_fn = _threshold_apply_fn({"a": 6, "b": 4})
        res = greedy_bitwidth_search(apply_fn, ["a", "b"], max_bits=10,
                                     min_bits=3, budget=0.01)
        # each group lowers until one step below threshold is rejected
        assert res.bits == {"a": 6, "b": 4}
        assert res.mean_bits == pytest.approx(5.0)
        # trace records the rejected probe one step below each threshold
        rejected = [(g, b) for g, b, _, ok in res.trace if not ok]
        assert rejected == [("a", 5), ("b", 3)]
        accepted = [(g, b) for g, b, _, ok in res.trace if ok]
        assert ("a", 6) in accepted and ("b", 4) in accepted

    def test_loose_budget_reaches_min_bits(self):
        apply_fn = _threshold_apply_fn({"a": 6, "b": 4})
        res = greedy_bitwidth_search(apply_fn, ["a", "b"], max_bits=8,
                                     min_bits=3, budget=1.0)
        assert res.bits == {"a": 3, "b": 3}

    def test_explicit_reference_and_cosine_metric(self):
        apply_fn = _threshold_apply_fn({"a": 5})
        ref = apply_fn({"a": 10})
        res = greedy_bitwidth_search(apply_fn, ["a"], max_bits=7,
                                     min_bits=3, budget=0.01,
                                     metric="cosine", reference=ref)
        assert res.bits == {"a": 5}

    def test_unknown_metric_raises(self):
        with pytest.raises(ValueError, match="unknown metric"):
            greedy_bitwidth_search(lambda b: jnp.zeros((2, 2)), ["a"],
                                   metric="nope")


class TestMXFormatValidation:
    """The quantizer round-trips through f32 and int mantissa planes:
    widths it cannot represent must be rejected at construction."""

    @pytest.mark.parametrize("bad", [True, False, 6.0, "8", None, 6.5])
    def test_non_int_mant_bits_rejected(self, bad):
        with pytest.raises(TypeError, match="mant_bits"):
            MXFormat(mant_bits=bad)

    @pytest.mark.parametrize("bad", [-3, 0, 1, 25, 64])
    def test_out_of_range_mant_bits_rejected(self, bad):
        with pytest.raises(ValueError, match="mant_bits"):
            MXFormat(mant_bits=bad)

    @pytest.mark.parametrize("bad", [True, 16.0, "32"])
    def test_non_int_block_size_rejected(self, bad):
        with pytest.raises(TypeError, match="block_size"):
            MXFormat(block_size=bad)

    def test_nonpositive_block_size_rejected(self):
        with pytest.raises(ValueError, match="block_size"):
            MXFormat(block_size=0)

    def test_valid_bounds_accepted(self):
        assert MXFormat(mant_bits=2).mant_max == 1
        f = MXFormat(mant_bits=24, block_size=1)
        assert f.bits_per_element == pytest.approx(32.0)
        assert MXFormat(mant_bits=6, block_size=256).bits_per_element == \
            pytest.approx(6.03125)
