"""Property-based tests on system invariants (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.core.mx_types import QuantConfig
from repro.models import ModelConfig, MoEConfig, build_model
from repro.models import layers as L
from repro.models.model_api import Param
from repro.models.moe import moe_ffn, init_moe_params

pytestmark = pytest.mark.slow    # hypothesis-heavy property suite (fast CI lane skips)


# ---------------------------------------------------------------------------
# causality: logits at position i must not depend on tokens > i
# ---------------------------------------------------------------------------
class TestCausality:
    @pytest.mark.parametrize("arch", ["llama3_8b", "mixtral_8x7b",
                                      "recurrentgemma_2b", "xlstm_350m"])
    def test_future_tokens_do_not_leak(self, arch):
        cfg = smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(7)
        toks = rng.integers(0, cfg.vocab, (1, 24)).astype(np.int32)
        toks2 = toks.copy()
        toks2[0, 16:] = rng.integers(0, cfg.vocab, 8)   # perturb the future

        def logits_at(t):
            x = model._embed_inputs(params, jnp.asarray(t), None)
            pos = jnp.arange(t.shape[1])[None, :]
            h, _, _ = model._run_stack(params, x, positions=pos, cache=None,
                                       cache_index=None, decode=False)
            return model.logits(params, h)

        a = np.asarray(logits_at(toks), np.float32)
        b = np.asarray(logits_at(toks2), np.float32)
        np.testing.assert_allclose(a[0, :16], b[0, :16], rtol=2e-2,
                                   atol=2e-3)
        assert np.abs(a[0, 16:] - b[0, 16:]).max() > 1e-3  # future did change

    def test_q_chunked_attention_is_causal(self):
        """Direct check on the chunked path with chunk < seq."""
        from repro.models.attention import (_q_chunked_attention,
                                            _direct_attention)
        rng = np.random.default_rng(0)
        b, s, kv, g, hd = 1, 64, 2, 2, 16
        q = jnp.asarray(rng.normal(size=(b, s, kv, g, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
        got = _q_chunked_attention(q, k, v, q_offset=0, causal=True,
                                   window=0, chunk=16, scale=hd ** -0.5)
        q_pos = np.arange(s)
        mask = jnp.asarray(q_pos[:, None] >= q_pos[None, :])
        want = _direct_attention(q, k, v, mask[None, None, None],
                                 QuantConfig(), hd ** -0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_q_chunked_sliding_window_matches_direct(self):
        from repro.models.attention import (_q_chunked_attention,
                                            _direct_attention)
        rng = np.random.default_rng(1)
        b, s, kv, g, hd = 1, 64, 2, 1, 16
        q = jnp.asarray(rng.normal(size=(b, s, kv, g, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
        got = _q_chunked_attention(q, k, v, q_offset=0, causal=True,
                                   window=16, chunk=32, scale=hd ** -0.5)
        q_pos = np.arange(s)
        mask = jnp.asarray((q_pos[:, None] >= q_pos[None, :]) &
                           (q_pos[:, None] - q_pos[None, :] < 16))
        want = _direct_attention(q, k, v, mask[None, None, None],
                                 QuantConfig(), hd ** -0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# RoPE: attention scores depend only on RELATIVE position
# ---------------------------------------------------------------------------
class TestRoPE:
    @settings(max_examples=20, deadline=None)
    @given(shift=st.integers(min_value=1, max_value=512),
           seed=st.integers(min_value=0, max_value=99))
    def test_property_shift_invariance(self, shift, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(1, 4, 1, 32)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(1, 4, 1, 32)).astype(np.float32))
        p = jnp.asarray(rng.integers(0, 256, (1, 4)))
        s1 = jnp.einsum("bshd,bShd->bsS", L.rope(q, p, 1e4),
                        L.rope(k, p, 1e4))
        s2 = jnp.einsum("bshd,bShd->bsS", L.rope(q, p + shift, 1e4),
                        L.rope(k, p + shift, 1e4))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------
class TestMoEInvariants:
    def _setup(self, E=4, k=2, d=16, ff=32, cap=8.0):
        cfg = ModelConfig(n_layers=1, d_model=d, n_heads=2, n_kv_heads=2,
                          d_ff=ff, vocab=64, ffn_kind="moe",
                          moe=MoEConfig(num_experts=E, top_k=k,
                                        capacity_factor=cap),
                          dtype=jnp.float32)
        p = init_moe_params(jax.random.key(0), cfg, jnp.float32)
        return cfg, p

    def test_token_permutation_equivariance(self):
        """With generous capacity (no drops), permuting tokens permutes
        outputs — routing is per-token."""
        cfg, p = self._setup()
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(1, 16, 16)).astype(np.float32))
        y, _ = moe_ffn(x, p, cfg, quant=QuantConfig())
        perm = rng.permutation(16)
        y_perm, _ = moe_ffn(x[:, perm], p, cfg, quant=QuantConfig())
        np.testing.assert_allclose(np.asarray(y[:, perm]),
                                   np.asarray(y_perm), rtol=2e-4, atol=2e-5)

    def test_capacity_zero_drop_vs_tight(self):
        """Tight capacity drops tokens (output = partial combine), generous
        capacity keeps all; both stay finite."""
        cfg_loose, p = self._setup(cap=8.0)
        cfg_tight, _ = self._setup(cap=0.25)
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(1, 32, 16)).astype(np.float32))
        y1, _ = moe_ffn(x, p, cfg_loose, quant=QuantConfig())
        y2, _ = moe_ffn(x, p, cfg_tight, quant=QuantConfig())
        assert np.isfinite(np.asarray(y1)).all()
        assert np.isfinite(np.asarray(y2)).all()
        # tight capacity must have dropped something
        assert float(jnp.linalg.norm(y1 - y2)) > 1e-3

    def test_aux_loss_balanced_router_is_minimal(self):
        """The Switch aux loss is ~1x router_aux_loss at perfect balance."""
        cfg, p = self._setup(E=4, k=1)
        d = 16
        # craft inputs routed uniformly: use many random tokens
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(4, 64, d)).astype(np.float32))
        _, aux = moe_ffn(x, p, cfg, quant=QuantConfig())
        # aux = c*E*sum(me*ce); for near-uniform routing ~= c
        assert float(aux) < cfg.moe.router_aux_loss * 4


# ---------------------------------------------------------------------------
# greedy bitwidth search
# ---------------------------------------------------------------------------
class TestGreedySearch:
    def test_finds_minimal_bits_on_synthetic_problem(self):
        from repro.core.search import greedy_bitwidth_search
        # synthetic: group 'a' tolerates 4 bits, group 'b' needs 8
        ref = jnp.asarray(np.eye(4, dtype=np.float32))

        def apply_fn(bits):
            out = ref
            if bits["a"] < 4:
                out = jnp.roll(out, 1, axis=1)   # flip every argmax
            if bits["b"] < 8:
                out = jnp.roll(out, 1, axis=1)
            return out

        res = greedy_bitwidth_search(apply_fn, ["a", "b"], max_bits=10,
                                     min_bits=3, budget=0.01)
        assert res.bits == {"a": 4, "b": 8}
        assert res.mean_bits == 6.0
        assert any(not ok for (_, _, _, ok) in res.trace)

    def test_search_respects_budget_metric(self):
        from repro.core.search import greedy_bitwidth_search
        rng = np.random.default_rng(0)
        base = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))

        def apply_fn(bits):
            noise = sum(2.0 ** -bits[g] for g in bits)
            return base + noise * jnp.asarray(
                rng.normal(size=base.shape).astype(np.float32))

        res = greedy_bitwidth_search(apply_fn, ["w"], max_bits=10,
                                     min_bits=2, budget=0.05,
                                     metric="cosine", reference=base)
        assert 2 <= res.bits["w"] <= 10
