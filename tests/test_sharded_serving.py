"""Sharded kernel-mode serving: bit-exactness + zero-recompile batching.

Runs ``repro.serving.sharded_check`` as a SUBPROCESS (so the forced host
devices never leak into this test process — the dryrun-test pattern) on a
2-device 'model' mesh:

  * column-parallel sharded kernel ``classify()`` on DeiT-Tiny shapes must
    equal the single-device ``mode='sim'`` oracle BIT-FOR-BIT;
  * the row-parallel (psum) strategy must run and stay close (its f32
    psum legitimately re-orders accumulation — DESIGN.md §10);
  * a mixed-size request stream through ``ClassifyScheduler`` must add
    ZERO jit specializations after the warmup batch (jit cache stats).
"""
import pytest
import json
import os
import subprocess
import sys
from pathlib import Path

pytestmark = pytest.mark.slow    # subprocess + forced multi-device jax init (fast CI lane skips)

ROOT = Path(__file__).resolve().parents[1]


def _run_check(extra=(), devices=2):
    env = dict(os.environ)
    env["REPRO_XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.serving.sharded_check", *extra],
        capture_output=True, text=True, timeout=560, env=env, cwd=str(ROOT))
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_kernel_bit_exact_and_zero_recompiles():
    rep = _run_check()
    assert rep["devices"] >= 2
    assert rep["ok"]

    # tentpole acceptance 1: sharded kernel == single-device sim, bitwise
    assert rep["parity"]["column"]["bit_exact"]
    assert rep["parity"]["column"]["max_abs_diff"] == 0.0

    # the row/psum strategy runs; close but honestly not bit-exact
    assert rep["parity"]["row"]["max_abs_diff"] < 1.0

    # tentpole acceptance 2: mixed request sizes, fixed-shape jit stays warm
    sched = rep["scheduler"]
    assert sched["all_classified"]
    assert sched["requests"] == 7
    assert sched["jit_cache_after_warmup"] == 1
    assert sched["recompiles_after_warmup"] == 0


def test_data_axis_composes_with_model_tp():
    """ROADMAP "Data-axis serving shards": a ("data", "model") mesh shards
    the batch over 2 data shards COMPOSED with 2-way model TP (4 forced
    host devices).  Batch rows are independent through the whole MXInt
    datapath, so both the composed dp x tp engine and the dp-only engine
    stay BIT-IDENTICAL to the single-device sim oracle, and the
    ClassifyScheduler stream still never recompiles."""
    rep = _run_check(["--dp", "2", "--tp", "2"], devices=4)
    assert rep["devices"] >= 4
    assert rep["ok"]
    assert rep["dp"] == 2

    # composed dp x tp column engine: bitwise vs single-device sim
    assert rep["parity"]["column"]["bit_exact"]
    assert rep["parity"]["column"]["max_abs_diff"] == 0.0
    # row/psum still runs under the data axis (close, not bit-exact)
    assert rep["parity"]["row"]["max_abs_diff"] < 1.0

    # dp-only (tp=1) engine: batch sharding alone is bit-exact too
    assert rep["parity_dp_only"]["column"]["bit_exact"]

    # continuous batching composes with the data axis: one specialization
    sched = rep["scheduler"]
    assert sched["all_classified"]
    assert sched["recompiles_after_warmup"] == 0
