"""HLO cost parser: exact FLOPs on known programs, trip-count scaling,
collective accounting, roofline assembly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import parse_program_costs, _shape_bytes
from repro.launch import hlo_analysis


class TestShapeParsing:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4
        assert _shape_bytes("bf16[2,3]") == 12
        assert _shape_bytes("(f32[4], s8[8])") == 16 + 8
        assert _shape_bytes("pred[]") == 1
        assert _shape_bytes("f32[64,128]{1,0:T(8,128)}") == 64 * 128 * 4


class TestProgramCosts:
    def test_plain_matmul_flops_exact(self):
        f = lambda x, w: x @ w
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 16), jnp.float32)).compile()
        cost = parse_program_costs(c.as_text())
        assert cost.flops == 2 * 32 * 64 * 16

    def test_scan_scales_by_trip_count(self):
        def f(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=10)
            return y.sum()
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
        cost = parse_program_costs(c.as_text())
        assert cost.flops == 2 * 64 * 128 * 128 * 10
        assert cost.n_while_loops == 1
        assert cost.unknown_trip_counts == 0

    def test_grad_of_scan(self):
        def g(w, x):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=8)
            return (y ** 2).sum()
        c = jax.jit(jax.grad(g)).lower(
            jax.ShapeDtypeStruct((128, 128), jnp.float32),
            jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile()
        cost = parse_program_costs(c.as_text())
        # fwd dot + two bwd dots per step
        assert cost.flops == 2 * 64 * 128 * 128 * 8 * 3

    def test_bytes_nonzero_and_bounded(self):
        f = lambda x: jnp.tanh(x) * 2 + 1
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((1024, 1024), jnp.float32)).compile()
        cost = parse_program_costs(c.as_text())
        nbytes = 1024 * 1024 * 4
        # one fused elementwise: read + write
        assert nbytes <= cost.bytes <= 4 * nbytes

    def test_roofline_assembly(self):
        f = lambda x, w: jax.nn.relu(x @ w).sum()
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((256, 512), jnp.float32),
            jax.ShapeDtypeStruct((512, 256), jnp.float32)).compile()
        roof = hlo_analysis.roofline_from_compiled(c, model_flops=1e9)
        assert roof.compute_s > 0 and roof.memory_s > 0
        assert roof.collective_s == 0.0
        assert roof.bottleneck in ("compute", "memory")
        assert roof.device_flops == 2 * 256 * 512 * 256


class TestModelFlopsEstimate:
    def test_dense_vs_moe_active(self):
        from repro.configs import full_config
        dense = full_config("llama3_8b")
        moe = full_config("mixtral_8x7b")
        td, ad = hlo_analysis.param_counts(dense)
        tm, am = hlo_analysis.param_counts(moe)
        assert abs(td - 8.0e9) / 8.0e9 < 0.1          # ~8B params
        assert abs(tm - 46.7e9) / 46.7e9 < 0.12        # ~47B total
        assert abs(am - 12.9e9) / 12.9e9 < 0.15        # ~13B active
        assert am < tm / 2

    def test_counts_scale_with_shapes(self):
        from repro.configs import full_config
        from repro.models.model_api import TRAIN_4K, DECODE_32K
        cfg = full_config("llama3_8b")
        tr = hlo_analysis.model_flops_estimate(cfg, TRAIN_4K, 256)
        de = hlo_analysis.model_flops_estimate(cfg, DECODE_32K, 256)
        assert tr > de * 100
