"""§Roofline: aggregate the dry-run artifacts into the 40-cell table.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
prints, per (arch x shape x mesh x variant): the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and per-device HBM bytes.

The STATIC half of the table needs no experiments: the per-pallas_call
FLOPs/HBM-bytes model (DESIGN.md §14) is ingested from, in order, a
live import of ``repro.analysis.cost_model``, a ``repro_lint --json``
report at ``benchmarks/_cache/cost_model_report.json``, or the
committed baseline — so ``roofline/static/*`` rows render on machines
that never ran a dry-run sweep.
"""
from __future__ import annotations

import json
from collections import defaultdict
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
_CACHE = Path(__file__).resolve().parent / "_cache"
COST_REPORT = _CACHE / "cost_model_report.json"
COST_BASELINE = _CACHE / "cost_model_baseline.json"


def load_static_costs(path: str | Path | None = None):
    """Return ``(rows, fusion)`` from the static cost model.

    ``rows`` is a list of per-kernel dicts (label/flops/hbm_bytes/...);
    ``fusion`` is the DeiT LN->qkv fusion summary or None.  Sources, in
    preference order: in-process model (PYTHONPATH=src), an explicit or
    cached ``repro_lint --json`` report, the committed byte baseline.
    """
    if path is None:
        try:
            from repro.analysis import cost_model

            rep = cost_model.report(Path(__file__).resolve().parents[1])
            return rep["rows"], rep["fusion"]
        except ImportError:
            pass
    for f in (Path(path) if path else None, COST_REPORT, COST_BASELINE):
        if f is None or not f.exists():
            continue
        payload = json.loads(f.read_text())
        payload = payload.get("cost_model", payload)  # full lint report?
        rows = payload.get("rows", [])
        if isinstance(rows, dict):      # baseline form: label -> metrics
            rows = [{"label": k, **v} for k, v in sorted(rows.items())]
        fusion = payload.get("fusion")
        if fusion and "saving_pct" not in fusion:  # baseline: arch-keyed
            fusion = next(iter(fusion.values()), None)
        return rows, fusion
    return [], None


def static_rows(path: str | Path | None = None):
    rows, fusion = load_static_costs(path)
    out = []
    for r in rows:
        flops, hbm = r.get("flops", 0), r.get("hbm_bytes", 0)
        inten = r.get("intensity") or (flops / hbm if hbm else 0.0)
        out.append((f"roofline/static/{r['label']}", 0.0,
                    f"flops={flops} hbm_bytes={hbm} "
                    f"intensity={inten:.1f} "
                    f"vmem_bytes={r.get('vmem_bytes', 0)}"))
    if fusion:
        out.append((
            "roofline/static/ln_fusion_deit_tiny", 0.0,
            f"fused={fusion['fused_bytes']} "
            f"unfused={fusion['unfused_bytes']} "
            f"saving={fusion['saving_pct']:.2f}%"))
    return out


def load_cells(mesh_filter: str = "", tag: str = None):
    if tag is None:
        # prefer the post-§Perf 'final' sweep, fall back to earlier tags
        for t in (".final", ".prod2", ".prod"):
            if list(DRYRUN_DIR.glob(f"*{t}.json")):
                tag = t
                break
        else:
            return {}
    cells = {}
    for f in sorted(DRYRUN_DIR.glob(f"*{tag}.json")):
        if f.name.startswith("summary"):
            continue
        rec = json.loads(f.read_text())
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        key = (rec["arch"], rec["shape"], rec["mesh"],
               rec.get("variant", "-"))
        cells[key] = rec
    return cells


def fmt_row(rec) -> str:
    if rec.get("skipped"):
        return "SKIP (" + rec["reason"][:60] + "...)"
    if not rec.get("ok"):
        return "FAIL"
    r = rec["roofline"]
    tot = rec["memory"]["total_device_bytes"]
    parts = [
        f"compute={r['compute_s']*1e3:.3f}ms",
        f"memory={r['memory_s']*1e3:.3f}ms",
        f"collective={r['collective_s']*1e3:.3f}ms",
        f"bound={r['bottleneck']}",
        f"useful_flops={100*r['useful_flops_ratio']:.1f}%"
        if r.get("useful_flops_ratio") else "useful_flops=n/a",
        f"dev_hbm={tot/2**30:.2f}GiB",
    ]
    return " ".join(parts)


def run():
    rows = static_rows()
    cells = load_cells()
    if not cells:
        rows.append(("roofline/dryrun_missing", 0.0,
                     "run `python -m repro.launch.dryrun` for the "
                     "compiled half of the table"))
        return rows
    for (arch, shape, mesh, variant), rec in sorted(cells.items()):
        rows.append((f"roofline/{arch}/{shape}/{mesh}/{variant}",
                     rec.get("compile_seconds") or 0.0, fmt_row(rec)))
    # aggregate: bottleneck census on the single-pod bf16 baseline
    census = defaultdict(int)
    for (arch, shape, mesh, variant), rec in cells.items():
        if mesh == "single_16x16" and variant in ("bf16", "-") and \
                rec.get("ok") and not rec.get("skipped"):
            census[rec["roofline"]["bottleneck"]] += 1
    rows.append(("roofline/bottleneck_census_single_bf16", 0.0,
                 " ".join(f"{k}={v}" for k, v in sorted(census.items()))))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
