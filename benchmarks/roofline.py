"""§Roofline: aggregate the dry-run artifacts into the 40-cell table.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
prints, per (arch x shape x mesh x variant): the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and per-device HBM bytes.
"""
from __future__ import annotations

import json
from collections import defaultdict
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh_filter: str = "", tag: str = None):
    if tag is None:
        # prefer the post-§Perf 'final' sweep, fall back to earlier tags
        for t in (".final", ".prod2", ".prod"):
            if list(DRYRUN_DIR.glob(f"*{t}.json")):
                tag = t
                break
        else:
            return {}
    cells = {}
    for f in sorted(DRYRUN_DIR.glob(f"*{tag}.json")):
        if f.name.startswith("summary"):
            continue
        rec = json.loads(f.read_text())
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        key = (rec["arch"], rec["shape"], rec["mesh"],
               rec.get("variant", "-"))
        cells[key] = rec
    return cells


def fmt_row(rec) -> str:
    if rec.get("skipped"):
        return "SKIP (" + rec["reason"][:60] + "...)"
    if not rec.get("ok"):
        return "FAIL"
    r = rec["roofline"]
    tot = rec["memory"]["total_device_bytes"]
    parts = [
        f"compute={r['compute_s']*1e3:.3f}ms",
        f"memory={r['memory_s']*1e3:.3f}ms",
        f"collective={r['collective_s']*1e3:.3f}ms",
        f"bound={r['bottleneck']}",
        f"useful_flops={100*r['useful_flops_ratio']:.1f}%"
        if r.get("useful_flops_ratio") else "useful_flops=n/a",
        f"dev_hbm={tot/2**30:.2f}GiB",
    ]
    return " ".join(parts)


def run():
    rows = []
    cells = load_cells()
    if not cells:
        rows.append(("roofline/missing", 0.0,
                     "run `python -m repro.launch.dryrun` first"))
        return rows
    for (arch, shape, mesh, variant), rec in sorted(cells.items()):
        rows.append((f"roofline/{arch}/{shape}/{mesh}/{variant}",
                     rec.get("compile_seconds") or 0.0, fmt_row(rec)))
    # aggregate: bottleneck census on the single-pod bf16 baseline
    census = defaultdict(int)
    for (arch, shape, mesh, variant), rec in cells.items():
        if mesh == "single_16x16" and variant in ("bf16", "-") and \
                rec.get("ok") and not rec.get("skipped"):
            census[rec["roofline"]["bottleneck"]] += 1
    rows.append(("roofline/bottleneck_census_single_bf16", 0.0,
                 " ".join(f"{k}={v}" for k, v in sorted(census.items()))))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
