"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:

  table5_quantization  — Table V / Fig 1b format sweep + outlier microbench
  per_op_tables        — Tables II/III/IV + Figs 4/7/8/9 datapath DSE
  table6_lut_savings   — Table VI LUT-entry savings (>=16x claim)
  fig10_speedup        — Fig 10 modeled MXInt-vs-float speedup (roofline)
  table7_system        — Table VII system resource/performance analogue
  kernel_bench         — Pallas kernel wall-times (interpret mode)
  roofline             — §Roofline 40-cell table from dry-run artifacts
"""
from __future__ import annotations

import sys
import traceback

MODULES = [
    "table5_quantization",
    "per_op_tables",
    "table6_lut_savings",
    "fig10_speedup",
    "table7_system",
    "greedy_search_bench",
    "kernel_bench",
    "roofline",
]


def main() -> None:
    only = sys.argv[1:] or MODULES
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if mod_name not in only:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us},{derived}", flush=True)
        except Exception:
            failed.append(mod_name)
            print(f"{mod_name},ERROR,{traceback.format_exc()[-300:]!r}",
                  flush=True)
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
