"""Tables II / III / IV + Figs 4 / 7 / 8 / 9: per-operator datapaths.

For each non-linear operator (LayerNorm, GELU, Softmax) the model runs with
ONLY that operator quantized (linears at 16-bit mantissa = lossless), and
each datapath variant:

    original          float op
    fixedpoint8       [9] / HeatViT / I-ViT style integer datapath
    relu6             SDA's GELU substitute (GELU only)
    vanilla mxint     huge-LUT MXInt (paper's 'Vanilla MXInt' rows)
    optimized mxint   the paper's final datapath (5 / 5 / 2 bits)

plus the paper's DSE sweeps:
    Fig 4: LayerNorm rsqrt-LUT bits      2..8
    Fig 7: GELU LUT domain a             1..4   (bits=8)
    Fig 8: GELU LUT bits                 3..8   (domain=3)
    Fig 9: Softmax r bits                1..6
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks import common
from repro.core.mx_types import MXFormat, NonlinearConfig, QuantConfig
from repro.models import build_model

_LOSSLESS_LIN = dict(weight_fmt=MXFormat(mant_bits=16, block_size=256),
                     act_fmt=MXFormat(mant_bits=16, block_size=16))


def _cfg(op, nl=None, nl_emulate=None):
    return QuantConfig(mode="sim", quantize_nonlinear=True, nl_ops=(op,),
                       nonlinear=nl or NonlinearConfig(),
                       nl_emulate=nl_emulate, **_LOSSLESS_LIN)


def _acc(model_cfg_quant, params):
    m = build_model(dataclasses.replace(common.BENCH_DEIT,
                                        quant=model_cfg_quant))
    t0 = time.perf_counter()
    acc = common.eval_accuracy(m, params)
    return acc, (time.perf_counter() - t0) * 1e6


def run():
    model, params = common.trained_deit_micro()
    base = common.eval_accuracy(model, params)
    rows = [("per_op/float_baseline", 0.0, f"acc={base:.4f}")]

    # ---- Table II: LayerNorm --------------------------------------------
    variants = [
        ("table2/fixedpoint8", _cfg("layernorm", nl_emulate="fixedpoint"),
         "bits=8"),
        ("table2/vanilla_mxint", _cfg("layernorm",
                                      NonlinearConfig(ln_lut_bits=13)),
         "bits=13"),
        ("table2/optimized_mxint", _cfg("layernorm",
                                        NonlinearConfig(ln_lut_bits=5)),
         "bits=5"),
    ]
    for name, q, meta in variants:
        acc, us = _acc(q, params)
        rows.append((name, round(us, 1),
                     f"{meta} acc={acc:.4f} loss={base - acc:+.4f}"))

    # Fig 4: rsqrt LUT bits sweep
    for bits in (2, 3, 4, 5, 6, 8):
        acc, us = _acc(_cfg("layernorm", NonlinearConfig(ln_lut_bits=bits)),
                       params)
        rows.append((f"fig4/ln_lut_bits_{bits}", round(us, 1),
                     f"acc={acc:.4f} loss={base - acc:+.4f}"))

    # ---- Table III: GELU ---------------------------------------------------
    variants = [
        ("table3/fixedpoint8_poly", _cfg("gelu", nl_emulate="fixedpoint"),
         "bits=8"),
        ("table3/sda_relu6", _cfg("gelu", nl_emulate="relu6"), "bits=8"),
        ("table3/vanilla_mxint", _cfg(
            "gelu", NonlinearConfig(gelu_lut_bits=14, gelu_domain=8.0)),
         "bits=14"),
        ("table3/optimized_mxint", _cfg(
            "gelu", NonlinearConfig(gelu_lut_bits=5, gelu_domain=3.0)),
         "bits=5"),
    ]
    for name, q, meta in variants:
        acc, us = _acc(q, params)
        rows.append((name, round(us, 1),
                     f"{meta} acc={acc:.4f} loss={base - acc:+.4f}"))

    # Fig 7: domain sweep at bits=8
    for dom in (1.0, 2.0, 3.0, 4.0):
        acc, us = _acc(_cfg("gelu", NonlinearConfig(gelu_lut_bits=8,
                                                    gelu_domain=dom)),
                       params)
        rows.append((f"fig7/gelu_domain_{dom:g}", round(us, 1),
                     f"acc={acc:.4f} loss={base - acc:+.4f}"))
    # Fig 8: bits sweep at domain=3
    for bits in (3, 4, 5, 6, 8):
        acc, us = _acc(_cfg("gelu", NonlinearConfig(gelu_lut_bits=bits,
                                                    gelu_domain=3.0)),
                       params)
        rows.append((f"fig8/gelu_bits_{bits}", round(us, 1),
                     f"acc={acc:.4f} loss={base - acc:+.4f}"))

    # ---- Table IV: Softmax --------------------------------------------------
    variants = [
        ("table4/fixedpoint8_shiftexp", _cfg("softmax",
                                             nl_emulate="fixedpoint"),
         "bits=8"),
        ("table4/vanilla_mxint", _cfg(
            "softmax", NonlinearConfig(softmax_r_bits=16)), "bits=16"),
        ("table4/mxint_match_sda", _cfg(
            "softmax", NonlinearConfig(softmax_r_bits=5)), "bits=5"),
        ("table4/optimized_mxint", _cfg(
            "softmax", NonlinearConfig(softmax_r_bits=2)), "bits=2"),
    ]
    for name, q, meta in variants:
        acc, us = _acc(q, params)
        rows.append((name, round(us, 1),
                     f"{meta} acc={acc:.4f} loss={base - acc:+.4f}"))

    # Fig 9: r bits sweep
    for bits in (1, 2, 3, 4, 6):
        acc, us = _acc(_cfg("softmax", NonlinearConfig(softmax_r_bits=bits)),
                       params)
        rows.append((f"fig9/softmax_r_bits_{bits}", round(us, 1),
                     f"acc={acc:.4f} loss={base - acc:+.4f}"))

    # ---- combined: the paper's full final datapath -----------------------
    full = QuantConfig(mode="sim", quantize_nonlinear=True,
                       weight_fmt=MXFormat(mant_bits=6, block_size=256),
                       act_fmt=MXFormat(mant_bits=8, block_size=16))
    acc, us = _acc(full, params)
    rows.append(("per_op/full_mxint_system", round(us, 1),
                 f"W6A8+LN5+GELU5+SM2 acc={acc:.4f} "
                 f"loss={base - acc:+.4f} within_1pct={base - acc < 0.01}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
