"""Wall-time of the Pallas kernels (interpret mode on CPU) vs jnp oracles.

interpret=True timings are NOT TPU performance — they validate that the
kernels run and give a cost sanity check; the TPU performance story is the
roofline analysis (benchmarks/roofline.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core import MXFormat, quantize
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mxint_gelu import mxint_gelu
from repro.kernels.mxint_layernorm import mxint_layernorm
from repro.kernels.mxint_matmul import mxint_matmul
from repro.kernels.mxint_softmax import mxint_softmax


def run():
    rng = np.random.default_rng(0)
    rows = []

    x = jnp.asarray(rng.normal(size=(128, 1024)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(1024, 512)).astype(np.float32) * 0.05)
    wq = quantize(w, MXFormat(6, 256), axis=0)

    t = timer(lambda: mxint_matmul(x, wq.mantissa, wq.exponent, w_block=256,
                                   bm=128, bn=128, bk=256))
    rows.append(("kernel/mxint_matmul_128x1024x512", round(t, 1),
                 "pallas interpret"))
    t = timer(lambda: ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent,
                                           w_block=256))
    rows.append(("kernel/mxint_matmul_ref", round(t, 1), "jnp oracle"))

    xl = jnp.asarray(rng.normal(size=(256, 768)).astype(np.float32))
    g, b = jnp.ones((768,)), jnp.zeros((768,))
    t = timer(lambda: mxint_layernorm(xl, g, b, block_rows=128))
    rows.append(("kernel/mxint_layernorm_256x768", round(t, 1), "pallas"))
    t = timer(lambda: ref.mxint_layernorm_ref(xl, g, b))
    rows.append(("kernel/mxint_layernorm_ref", round(t, 1), "jnp oracle"))

    t = timer(lambda: mxint_softmax(xl, block_rows=128))
    rows.append(("kernel/mxint_softmax_256x768", round(t, 1), "pallas"))
    t = timer(lambda: mxint_gelu(xl, block_rows=128))
    rows.append(("kernel/mxint_gelu_256x768", round(t, 1), "pallas"))

    q = jnp.asarray(rng.normal(size=(4, 256, 128)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.normal(size=(4, 256, 128)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(4, 256, 128)).astype(np.float32))
    t = timer(lambda: flash_attention(q, k, v, causal=True))
    rows.append(("kernel/flash_attention_float", round(t, 1), "pallas"))
    t = timer(lambda: flash_attention(q, k, v, causal=True,
                                      exp_mode="mxint"))
    rows.append(("kernel/flash_attention_mxint", round(t, 1),
                 "pallas, Eq14-19 exp datapath"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
