"""Wall-time of the Pallas kernels (interpret mode on CPU) vs jnp oracles.

interpret=True timings are NOT TPU performance — they validate that the
kernels run and give a cost sanity check; the TPU performance story is the
roofline analysis (benchmarks/roofline.py).

Also reports the end-to-end DeiT execution-mode comparison: the same
forward pass in mode='off' (float), mode='sim' (XLA emulation of the MXInt
datapaths) and mode='kernel' (packed planes through the Pallas wrappers).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core import MXFormat, QuantConfig, quantize
from repro.kernels import ref
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_decode)
from repro.kernels.mxint_gelu import mxint_gelu
from repro.kernels.mxint_layernorm import mxint_layernorm
from repro.kernels.mxint_matmul import mxint_matmul
from repro.kernels.mxint_softmax import mxint_softmax


def run():
    rng = np.random.default_rng(0)
    rows = []

    x = jnp.asarray(rng.normal(size=(128, 1024)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(1024, 512)).astype(np.float32) * 0.05)
    wq = quantize(w, MXFormat(6, 256), axis=0)

    t = timer(lambda: mxint_matmul(x, wq.mantissa, wq.exponent, w_block=256,
                                   bm=128, bn=128, bk=256))
    rows.append(("kernel/mxint_matmul_128x1024x512", round(t, 1),
                 "pallas interpret"))
    t = timer(lambda: ref.mxint_matmul_ref(x, wq.mantissa, wq.exponent,
                                           w_block=256))
    rows.append(("kernel/mxint_matmul_ref", round(t, 1), "jnp oracle"))

    xl = jnp.asarray(rng.normal(size=(256, 768)).astype(np.float32))
    g, b = jnp.ones((768,)), jnp.zeros((768,))
    t = timer(lambda: mxint_layernorm(xl, g, b, block_rows=128))
    rows.append(("kernel/mxint_layernorm_256x768", round(t, 1), "pallas"))
    t = timer(lambda: ref.mxint_layernorm_ref(xl, g, b))
    rows.append(("kernel/mxint_layernorm_ref", round(t, 1), "jnp oracle"))

    t = timer(lambda: mxint_softmax(xl, block_rows=128))
    rows.append(("kernel/mxint_softmax_256x768", round(t, 1), "pallas"))
    t = timer(lambda: mxint_gelu(xl, block_rows=128))
    rows.append(("kernel/mxint_gelu_256x768", round(t, 1), "pallas"))

    q = jnp.asarray(rng.normal(size=(4, 256, 128)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.normal(size=(4, 256, 128)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(4, 256, 128)).astype(np.float32))
    t = timer(lambda: flash_attention(q, k, v, causal=True))
    rows.append(("kernel/flash_attention_float", round(t, 1), "pallas"))
    t = timer(lambda: flash_attention(q, k, v, causal=True,
                                      exp_mode="mxint"))
    rows.append(("kernel/flash_attention_mxint", round(t, 1),
                 "pallas, Eq14-19 exp datapath"))
    t = timer(lambda: flash_attention(q, k, v, causal=True,
                                      exp_mode="mxint",
                                      quantize_scores=True))
    rows.append(("kernel/flash_attention_mxint_flash", round(t, 1),
                 "pallas, full Eq14-20 blocked datapath"))

    # native cache layout: (b, hkv, g, d) queries, (b, W, hkv, d) rings
    qd = jnp.asarray(rng.normal(size=(2, 4, 4, 128)).astype(np.float32)) * 0.3
    kd = jnp.asarray(rng.normal(
        size=(2, 256, 4, 128)).astype(np.float32)) * 0.3
    vd = jnp.asarray(rng.normal(size=(2, 256, 4, 128)).astype(np.float32))
    valid = jnp.arange(256) <= 200
    t = timer(lambda: flash_attention_decode(qd, kd, vd, valid))
    rows.append(("kernel/flash_decode_float", round(t, 1),
                 "pallas, single-query cache-ring decode"))
    t = timer(lambda: flash_attention_decode(qd, kd, vd, valid,
                                             exp_mode="mxint",
                                             quantize_scores=True))
    rows.append(("kernel/flash_decode_mxint", round(t, 1),
                 "pallas, Eq14-20 decode datapath"))

    rows.extend(deit_mode_rows())
    rows.extend(deit_ln_fusion_rows())
    rows.extend(deit_sharded_rows())
    rows.extend(lm_batching_rows())
    return rows


def _ln_linear_hbm_bytes(rows: int, d: int, n: int, w_block: int,
                         n_linears: int, fused: bool,
                         act_bytes: int = 4) -> int:
    """Analytic HBM bytes for a pre-norm feeding ``n_linears`` linears.

    Interpret-mode counters for the DESIGN.md §12 accounting: the kernels
    are deterministic about what crosses HBM — activations at
    ``act_bytes``/elt, packed planes at 1 byte/elt (int8 mantissas +
    int8 shared exponents), outputs at 4 bytes/elt.  Unfused pays the
    LN write + per-linear read of the normalized tile; fused keeps it in
    VMEM (the x tile is re-read per fused call instead).
    """
    a = rows * d * act_bytes                     # one activation tile
    planes = d * n + (d // w_block) * n          # mantissa + exponent plane
    outs = rows * n * 4
    per_linear = planes + outs
    if fused:
        return n_linears * (a + per_linear)      # x read per fused call
    #         LN read + LN write   + per-linear read of y
    return (a + a) + n_linears * (a + per_linear)


def deit_ln_fusion_rows(archs=("deit_tiny", "deit_small"), batch: int = 1):
    """Fused vs unfused LN->qkv on DeiT shapes (ROADMAP fused-LN item).

    Wall-clocks are CPU interpret mode (validity, not TPU perf); the
    HBM-byte rows are the meaningful counters — the fused composite
    moves strictly fewer bytes (the normalized tile never leaves VMEM),
    which on TPU is the win for these bandwidth-bound blocks.
    """
    from repro.configs.deit import BY_NAME
    from repro.core.quantize import pack_weight
    from repro.kernels import ops

    q = QuantConfig(mode="kernel", quantize_nonlinear=True)
    rng = np.random.default_rng(0)
    rows = []
    for arch in archs:
        cfg = BY_NAME[arch]
        d = cfg.d_model
        seq = (cfg.image_size // cfg.patch_size) ** 2 + 1
        M = batch * seq
        x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
        g = jnp.ones((d,), jnp.float32)
        b = jnp.zeros((d,), jnp.float32)
        wqkv = [pack_weight(
            jnp.asarray(rng.normal(size=(d, d)).astype(np.float32) * 0.05),
            q.weight_fmt, axis=0) for _ in range(3)]
        w_block = wqkv[0].block_size
        kw = dict(act_block=q.act_fmt.block_size,
                  mant_bits=q.act_fmt.mant_bits,
                  lut_bits=q.nonlinear.ln_lut_bits)

        def unfused():
            h = ops.mxint_layernorm_op(x, g, b, quantize_out=True, **kw)
            return [ops.mxint_linear(
                h, w.mantissa, w.exponent, w_block=w_block,
                quantize_act=True, act_block=q.act_fmt.block_size,
                act_mant_bits=q.act_fmt.mant_bits) for w in wqkv]

        def fused():
            return [ops.mxint_ln_linear_op(
                x, g, b, w.mantissa, w.exponent, w_block=w_block, **kw)
                for w in wqkv]

        # parity guard: the bench never times two different computations
        for got, want in zip(fused(), unfused()):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

        t_un = timer(lambda: unfused(), repeats=3)
        t_fu = timer(lambda: fused(), repeats=3)
        rows.append((f"kernel/{arch}_ln_qkv_unfused", round(t_un, 1),
                     "pallas interpret, LN kernel + 3 linear kernels"))
        rows.append((f"kernel/{arch}_ln_qkv_fused", round(t_fu, 1),
                     "pallas interpret, 3 fused LN->linear kernels"))
        hbm_un = _ln_linear_hbm_bytes(M, d, d, w_block, 3, fused=False)
        hbm_fu = _ln_linear_hbm_bytes(M, d, d, w_block, 3, fused=True)
        rows.append((f"kernel/{arch}_ln_qkv_hbm_bytes_unfused", hbm_un,
                     "activation+plane+output bytes over HBM"))
        rows.append((f"kernel/{arch}_ln_qkv_hbm_bytes_fused", hbm_fu,
                     f"normalized tile stays in VMEM "
                     f"(-{100 * (hbm_un - hbm_fu) // hbm_un}% bytes)"))
    return rows


def deit_mode_rows(archs=("deit_tiny", "deit_small"), batch: int = 1,
                   n_layers: int = 2):
    """off / sim / kernel wall-clock of a DeiT forward (CPU interpret).

    ``n_layers`` is truncated (the per-layer cost is uniform) so the CPU
    bench stays minutes-scale; relative mode cost is what matters here —
    absolute TPU numbers come from the roofline.
    """
    from repro.configs.deit import BY_NAME
    from repro.models import build_model
    from repro.serving.engine import pack_params_mxint

    modes = {
        "off": (QuantConfig(mode="off"), False),
        "sim": (QuantConfig(mode="sim", quantize_nonlinear=True), False),
        "kernel": (QuantConfig(mode="kernel", quantize_nonlinear=True),
                   True),
    }
    rows = []
    rng = np.random.default_rng(0)
    for arch in archs:
        cfg = dataclasses.replace(BY_NAME[arch], n_layers=n_layers)
        imgs = jnp.asarray(rng.normal(
            size=(batch, cfg.image_size, cfg.image_size, 3))
            .astype(np.float32))
        params = build_model(cfg).init(jax.random.key(0))
        for mode, (qcfg, pack) in modes.items():
            model = build_model(dataclasses.replace(cfg, quant=qcfg))
            p = pack_params_mxint(params, qcfg.weight_fmt) if pack else params
            fwd = jax.jit(model.logits)
            t = timer(lambda: fwd(p, imgs), repeats=3)
            rows.append((f"kernel/{arch}_L{n_layers}_forward_{mode}",
                         round(t, 1),
                         "pallas interpret" if mode == "kernel"
                         else "xla"))
    return rows


def deit_sharded_rows(tp: int = 2):
    """off / sim / kernel / kernel-sharded forward wall-clock (CPU).

    The sharded cell needs a multi-device backend, which can only be
    forced BEFORE jax initializes — so this row runs
    ``repro.serving.sharded_check --bench`` as a subprocess (the dryrun
    pattern) and converts its timings.  Returns a skip row when the
    subprocess fails (e.g. single-core CI without fakeable devices).
    """
    import json
    import os
    import subprocess
    import sys
    from pathlib import Path

    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["REPRO_XLA_FLAGS"] = f"--xla_force_host_platform_device_count={tp}"
    env["PYTHONPATH"] = str(root / "src")
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.serving.sharded_check",
             "--bench", "--tp", str(tp)],
            capture_output=True, text=True, timeout=560, env=env,
            cwd=str(root))
        rep = json.loads(proc.stdout.strip().splitlines()[-1])
    except Exception as e:                       # bench must never hard-fail
        return [("kernel/deit_tiny_sharded_skipped", 0.0, f"skipped: {e}")]
    if proc.returncode != 0:
        return [("kernel/deit_tiny_sharded_skipped", 0.0,
                 "skipped: " + proc.stderr[-200:])]
    rows = []
    for mode, ms in rep["bench_ms"].items():
        note = ("pallas interpret, shard_map" if mode.startswith("kernel_tp")
                else "pallas interpret" if mode == "kernel" else "xla")
        rows.append((f"kernel/{rep['arch']}_forward_tp_bench_{mode}",
                     round(ms * 1e3, 1), note))     # ms -> us (CSV unit)
    rows.append((f"kernel/{rep['arch']}_sharded_bit_exact",
                 float(rep["parity"]["column"]["bit_exact"]),
                 "column TP == single-device sim, bitwise"))
    return rows


def lm_batching_rows(batch: int = 4, n_requests: int = 16):
    """Slot vs wave continuous batching on a ragged decode workload.

    Same engine, same requests, same per-row index datapath — only the
    admission policy differs.  The workload alternates short and long
    ``max_new_tokens`` so wave admission (slots freed only when the whole
    batch drains) strands capacity behind each long tail while slot
    admission refills freed rows immediately.  CPU wall-clock, xla mode
    (mode='off') — the ratio, not the absolute tokens/sec, is the point.
    """
    import time

    from repro.models.model_api import ModelConfig
    from repro.models.transformer import DecoderLM
    from repro.serving.engine import ServeConfig, ServingEngine
    from repro.serving.scheduler import BatchScheduler, Request

    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=128, ffn_kind="gelu",
                      dtype=jnp.float32, quant=QuantConfig(mode="off"))
    model = DecoderLM(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, ServeConfig(max_len=96, batch=batch))

    def stream():
        rng = np.random.default_rng(0)             # identical every replay
        reqs = []
        for uid in range(n_requests):
            plen = int(rng.integers(2, 12))
            max_new = 48 if uid % batch == 0 else 4    # heavy ragged tail
            prompt = rng.integers(1, 128, plen).astype(np.int32)
            reqs.append(Request(uid=uid, prompt=prompt,
                                max_new_tokens=max_new))
        return reqs

    def bench(admission):
        sched = BatchScheduler(eng, batch_size=batch, prefill_len=16,
                               admission=admission)
        for r in stream():
            sched.submit(r)
        sched.run()                                    # warm the jits
        sched = BatchScheduler(eng, batch_size=batch, prefill_len=16,
                               admission=admission)
        for r in stream():
            sched.submit(r)
        t0 = time.perf_counter()
        done = sched.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in done)
        assert len(done) == n_requests
        return toks / dt

    rows = []
    wave = bench("wave")
    slot = bench("slot")
    rows.append(("kernel/lm_batching_wave_tok_s", round(wave, 1),
                 "wave-synchronous admission, ragged max_new"))
    rows.append(("kernel/lm_batching_slot_tok_s", round(slot, 1),
                 "slot-level admission, same workload"))
    rows.append(("kernel/lm_batching_slot_speedup", round(slot / wave, 2),
                 "slot / wave decode throughput"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
