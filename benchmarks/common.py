"""Shared benchmark helpers: a trained micro-DeiT + format emulations.

The container has no ImageNet, so accuracy numbers come from a DeiT of the
same family trained on a synthetic 10-class task (class-conditional blobs,
repro.data).  Quantization is then *post-training* exactly as in the paper,
and every table reports the accuracy DELTA against the float model — the
quantity the paper budgets (<1%).
"""
from __future__ import annotations

import functools
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.deit import DEIT_MICRO
from repro.data.pipeline import SyntheticImageData
from repro.models import build_model
from repro.models.model_api import unwrap, Param, is_param
from repro.optim.adamw import AdamWConfig
from repro.train.state import make_train_state
from repro.train.step import make_train_step

CACHE = Path(__file__).resolve().parent / "_cache"
CACHE.mkdir(exist_ok=True)


def timer(fn, *args, repeats: int = 5, warmup: int = 1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6        # us


# A HARD task config: 100 thin-margin classes, heavy noise, outlier image
# channels (the activation-outlier phenomenon that breaks per-tensor int
# quantization on real ViTs).  The float model lands well below 100%, so
# quantization formats separate — the paper's Table V regime.
import dataclasses as _dc
BENCH_DEIT = _dc.replace(DEIT_MICRO, n_classes=100)
_TASK = dict(n_classes=100, image_size=32, noise=1.0, class_sep=0.25,
             outlier_channels=False)


@functools.lru_cache(maxsize=1)
def trained_deit_micro(steps: int = 700):
    """Train (or load cached) micro-DeiT on the hard synthetic task."""
    model = build_model(BENCH_DEIT)
    params = model.init(jax.random.key(0))
    cache_file = CACHE / f"deit_micro_h{steps}.npz"
    flat, treedef = jax.tree_util.tree_flatten(unwrap(params))
    if cache_file.exists():
        data = np.load(cache_file)
        leaves = [jnp.asarray(data[f"leaf_{i}"]) for i in range(len(flat))]
        vals = jax.tree_util.tree_unflatten(treedef, leaves)
        params = jax.tree_util.tree_map(
            lambda p, v: Param(v, p.axes), params, vals, is_leaf=is_param)
        return model, params
    data = SyntheticImageData(batch=64, seed=0, **_TASK)
    state = make_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(
        model, lr_fn=lambda s: jnp.asarray(1e-3, jnp.float32),
        opt_cfg=AdamWConfig(weight_decay=0.01)))
    for _ in range(steps):
        state, metrics = step(state, data.next_batch())
    params = state.params
    leaves = jax.tree_util.tree_leaves(unwrap(params))
    np.savez(cache_file, **{f"leaf_{i}": np.asarray(l)
                            for i, l in enumerate(leaves)})
    return model, params


def eval_accuracy(model, params, n_batches: int = 8, seed: int = 99) -> float:
    data = SyntheticImageData(batch=128, seed=seed, **_TASK)
    acc_fn = jax.jit(model.accuracy)
    accs = []
    for _ in range(n_batches):
        b = data.next_batch()
        accs.append(float(acc_fn(params, b)))
    return float(np.mean(accs))


# ---------------------------------------------------------------------------
# format emulations for Table V
# ---------------------------------------------------------------------------
def qdq_int(x, bits: int):
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    s = amax / (2 ** (bits - 1) - 1)
    return jnp.clip(jnp.round(x / s), -(2 ** (bits - 1)),
                    2 ** (bits - 1) - 1) * s


def qdq_fp8_e4m3(x):
    """e4m3 emulation: 3 mantissa bits, exponent range [-6, 8]."""
    xf = jnp.asarray(x, jnp.float32)
    m, e = jnp.frexp(xf)
    e = jnp.clip(e, -6, 9)
    scale = jnp.exp2(3.0 - e.astype(jnp.float32))          # 3 mantissa bits
    q = jnp.round(xf * scale) / scale
    return jnp.clip(q, -448.0, 448.0)


def map_weights(params, fn):
    """Apply fn to every >=2-D kernel leaf (PTQ of the weights)."""
    def one(p: Param):
        v = p.value
        if hasattr(v, "ndim") and v.ndim >= 2 and v.size > 256:
            return Param(fn(v), p.axes)
        return p
    return jax.tree_util.tree_map(one, params, is_leaf=is_param)
