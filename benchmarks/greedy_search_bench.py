"""The paper's greedy bitwidth search (§III-A): determine the minimal
weight-mantissa width per tensor group under the 1% accuracy-loss budget.

The paper reports W6/A8 as the lossless point for DeiT; here the same
greedy loop runs on the trained synthetic-task DeiT with argmax-agreement
as the budgeted metric and reports the per-group result + mean bits.

Hosted by ``repro.dse`` since ISSUE 10: the groups are proper per-layer
scopes ("block/*/attn" / "block/*/ffn" / "head") on a SearchSpace over a
weight-QDQ base config with near-lossless 16-bit activations, and the
loop is ``dse.drivers.greedy_search`` — the re-hosted
``core.search.greedy_bitwidth_search`` accept rule (so the old ad-hoc
leaf-requantizing loop and the subsystem cannot drift apart).  Row names
are unchanged.
"""
from __future__ import annotations

import time

from benchmarks import common
from repro.core.mx_types import MXFormat, QuantConfig
from repro.data.pipeline import SyntheticImageData
from repro.dse import Evaluator, GroupSpace, SearchSpace, greedy_search

# bench row group -> model scope glob (row names are the stable API)
ROW_SCOPES = (("attn_w", "block/*/attn"),
              ("ffn_w", "block/*/ffn"),
              ("head_w", "head"))
WIDTHS = tuple(range(10, 2, -1))        # 10 (reference) down to 3


def run():
    model, params = common.trained_deit_micro()
    data = SyntheticImageData(batch=256, seed=500, **common._TASK)
    batch = data.next_batch()

    # weight-only QDQ sweep: activations at 16 bits are lossless on this
    # task, so the budget binds on the weight mantissas (paper Table V)
    base = QuantConfig(mode="fake",
                       weight_fmt=MXFormat(mant_bits=10, block_size=256),
                       act_fmt=MXFormat(mant_bits=16, block_size=16))
    space = SearchSpace(base=base, groups=tuple(
        GroupSpace(scope=scope, weight_mant_bits=WIDTHS)
        for _, scope in ROW_SCOPES))
    ev = Evaluator(space, model.cfg, params, batch["images"],
                   kernel_rows=())

    t0 = time.perf_counter()
    res = greedy_search(space, ev, budget=0.01,
                        order=[scope for _, scope in ROW_SCOPES])
    us = (time.perf_counter() - t0) * 1e6
    rows = [(f"greedy/{g}_bits", 0.0, str(res.bits[scope]))
            for g, scope in ROW_SCOPES]
    rows.append(("greedy/mean_bits", round(us, 0),
                 f"{res.mean_bits:.2f} (paper: W6 for DeiT) "
                 f"steps={len(res.trace)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
