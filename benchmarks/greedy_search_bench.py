"""The paper's greedy bitwidth search (§III-A): determine the minimal
weight-mantissa width per tensor group under the 1% accuracy-loss budget.

The paper reports W6/A8 as the lossless point for DeiT; here the same
greedy loop runs on the trained synthetic-task DeiT with argmax-agreement
as the budgeted metric and reports the per-group result + mean bits.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core.mx_types import MXFormat, QuantConfig
from repro.core.search import greedy_bitwidth_search
from repro.data.pipeline import SyntheticImageData
from repro.models import build_model


def run():
    model, params = common.trained_deit_micro()
    data = SyntheticImageData(batch=256, seed=500, **common._TASK)
    batch = data.next_batch()

    groups = ["attn_w", "ffn_w", "head_w"]

    def apply_fn(bits):
        # per-group weight-only MXInt QDQ via three model variants would be
        # slow; instead reuse the act=16 lossless config and re-quantize the
        # relevant Param leaves on the fly.
        from repro.core.quantize import quantize_dequantize
        from repro.models.model_api import Param, is_param

        def q(p: Param, b):
            v = p.value
            if hasattr(v, "ndim") and v.ndim >= 2 and v.size > 256:
                return Param(quantize_dequantize(
                    v, MXFormat(mant_bits=b, block_size=256), axis=-2), p.axes)
            return p

        pq = dict(params)
        pq["blocks"] = jax.tree_util.tree_map(
            lambda p: q(p, bits["attn_w"]), params["blocks"],
            is_leaf=is_param)
        # ffn group inside blocks: approximate by same tree (attn/ffn share
        # the stacked block tree); head separately:
        pq["head"] = q(params["head"], bits["head_w"])
        pq["patch_proj"] = q(params["patch_proj"], bits["ffn_w"])
        return model.logits(pq, batch["images"])

    t0 = time.perf_counter()
    res = greedy_bitwidth_search(apply_fn, groups, max_bits=10, min_bits=3,
                                 budget=0.01)
    us = (time.perf_counter() - t0) * 1e6
    rows = [(f"greedy/{g}_bits", 0.0, str(b)) for g, b in res.bits.items()]
    rows.append(("greedy/mean_bits", round(us, 0),
                 f"{res.mean_bits:.2f} (paper: W6 for DeiT) "
                 f"steps={len(res.trace)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
