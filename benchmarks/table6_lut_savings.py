"""Table VI: LUT-entry savings of the optimized datapaths vs vanilla.

The paper counts LUT entry bits (vanilla -> ours): GELU 14->5, Softmax
16->2, LayerNorm 13->5, i.e. >=16x fewer entries per operator.  On TPU the
area analogue is table BYTES in VMEM (DESIGN.md §2); the >=16x claim is
checked on entries, and elementwise fidelity of each optimized datapath is
reported against the exact op (tensor-level, deterministic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import luts
from repro.core import nonlinear as nl
from repro.core.mx_types import MXFormat, NonlinearConfig

FMT = MXFormat(8, 16)

PAPER_BITS = {          # (vanilla, optimized) LUT entry bits, Table VI
    "gelu": (14, 5),
    "softmax": (16, 2),
    "layernorm": (13, 5),
}


def _fidelity(op: str, bits: int) -> float:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32)) * 3
    if op == "gelu":
        cfg = NonlinearConfig(gelu_lut_bits=bits)
        got = nl.gelu_value(x, cfg, FMT)
        ref = jax.nn.gelu(x, approximate=False)
    elif op == "softmax":
        cfg = NonlinearConfig(softmax_r_bits=bits)
        got = nl.softmax_value(x, cfg, FMT)
        ref = jax.nn.softmax(x, -1)
    else:
        cfg = NonlinearConfig(ln_lut_bits=bits)
        g, b = jnp.ones((256,)), jnp.zeros((256,))
        got = nl.layernorm_value(x, g, b, cfg, FMT)
        ref = (x - x.mean(-1, keepdims=True)) / jnp.sqrt(
            x.var(-1, keepdims=True) + 1e-6)
    err = float(jnp.mean(jnp.abs(got - ref)))
    scale = float(jnp.mean(jnp.abs(ref))) + 1e-12
    return err / scale


def run():
    rows = []
    total_vanilla = total_ours = 0
    for op, (vb, ob) in PAPER_BITS.items():
        ev, eo = 2 ** vb, 2 ** ob
        total_vanilla += ev
        total_ours += eo
        red = ev / eo
        fid_v = _fidelity(op, vb if op != "gelu" else 8)
        fid_o = _fidelity(op, ob)
        rows.append((f"table6/{op}", 0.0,
                     f"vanilla_entries={ev} ours={eo} reduction={red:.0f}x "
                     f"bytes_ours={luts.table_bytes(eo)} "
                     f"rel_err_vanilla={fid_v:.4f} rel_err_ours={fid_o:.4f}"))
        rows.append((f"table6/{op}_claim", 0.0,
                     f"ge16x={red >= 16}"))
    rows.append(("table6/total", 0.0,
                 f"vanilla={total_vanilla} ours={total_ours} "
                 f"reduction={total_vanilla / total_ours:.0f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
