"""Fig 10 / speedup: modeled MXInt-vs-float speedup per DeiT size.

The paper reports >=93x vs Float16 and Fig 10's bars vs Float8 on FPGA —
driven by LUT-area-limited parallelism, which has no TPU meaning
(DESIGN.md §2).  The TPU-native reading of the same comparison is the
roofline-time ratio of one inference:

    t(fmt) = max(flops / peak(fmt), bytes(fmt) / HBM_bw)

where MXInt runs the MXU in int8 (2x bf16 peak) and moves ~4-5x fewer
weight bytes.  Both the paper-faithful datapoint (per-model speedup) and
the terms are reported; batch=1 (latency, the paper's FPS regime) and
batch=64 (throughput) both shown.
"""
from __future__ import annotations

from repro.core.mx_types import (HBM_BW, PEAK_FLOPS_BF16, PEAK_FLOPS_INT8)
from repro.configs.deit import DEIT_TINY, DEIT_SMALL, DEIT_BASE


def _vit_cost(cfg, batch: int):
    """(flops, param_count, act_elems) for one forward pass."""
    s = (cfg.image_size // cfg.patch_size) ** 2 + 1
    d, ff, L, H = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.n_heads
    per_layer = 2 * s * (4 * d * d + 2 * d * ff) + 2 * 2 * s * s * d
    flops = batch * (L * per_layer + 2 * s * 3 * cfg.patch_size ** 2 * d)
    params = L * (4 * d * d + 2 * d * ff) + 3 * cfg.patch_size ** 2 * d + \
        d * cfg.n_classes
    acts = batch * s * d * (L * 8)
    return flops, params, acts


def _roof_time(flops, weight_bytes, act_bytes, peak):
    t_c = flops / peak
    t_m = (weight_bytes + act_bytes) / HBM_BW
    return max(t_c, t_m), t_c, t_m


def run():
    rows = []
    for cfg in (DEIT_TINY, DEIT_SMALL, DEIT_BASE):
        for batch in (1, 64):
            flops, params, acts = _vit_cost(cfg, batch)
            # float16 baseline: 2B weights/acts, bf16 MXU
            t16, c16, m16 = _roof_time(flops, params * 2, acts * 2,
                                       PEAK_FLOPS_BF16)
            # float8: 1B, int8-rate MXU
            t8, _, _ = _roof_time(flops, params * 1, acts * 1,
                                  PEAK_FLOPS_INT8)
            # MXInt W6.03/A8.5: packed bytes, int8 MXU
            wb = params * 6.03125 / 8
            ab = acts * 8.5 / 8
            tmx, cmx, mmx = _roof_time(flops, wb, ab, PEAK_FLOPS_INT8)
            rows.append((
                f"fig10/{cfg.name}_b{batch}", 0.0,
                f"t_f16={t16*1e6:.1f}us t_f8={t8*1e6:.1f}us "
                f"t_mxint={tmx*1e6:.1f}us "
                f"speedup_vs_f16={t16/tmx:.2f}x "
                f"speedup_vs_f8={t8/tmx:.2f}x "
                f"bound={'mem' if mmx > cmx else 'compute'}"))
    rows.append(("fig10/note", 0.0,
                 "paper's 93x is FPGA LUT-area-parallelism-limited; "
                 "TPU-native ratio is roofline-time (DESIGN.md §2)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
