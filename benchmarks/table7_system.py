"""Table VII analogue: system-level resource/performance per DeiT size.

FPGA columns (kLUT/DSP/BRAM/Fmax/power) have no TPU meaning; the analogous
system table is: parameter count, packed weight bytes (the paper's memory
claim, measured on the real packed pytree), modeled latency/FPS at batch 1
on one v5e chip, and GOPs/s — for both Float16 and MXInt W6/A8.5.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.fig10_speedup import _roof_time, _vit_cost
from repro.core.mx_types import (MXINT6_WEIGHT, PEAK_FLOPS_BF16,
                                 PEAK_FLOPS_INT8)
from repro.core.quantize import packed_bytes
from repro.configs.deit import DEIT_TINY, DEIT_SMALL, DEIT_BASE
from repro.models import build_model
from repro.models.model_api import unwrap
from repro.serving.engine import pack_params_mxint


def run():
    rows = []
    for cfg in (DEIT_TINY, DEIT_SMALL, DEIT_BASE):
        model = build_model(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        raw = unwrap(params)
        n_params = sum(int(l.size) for l in jax.tree_util.tree_leaves(raw))
        f16_bytes = n_params * 2
        packed = pack_params_mxint(params, MXINT6_WEIGHT, abstract=True)
        pb = 0
        from repro.core.quantize import MXTensor
        for leaf in jax.tree_util.tree_leaves(
                unwrap(packed), is_leaf=lambda l: isinstance(l, MXTensor)):
            if isinstance(leaf, MXTensor):
                pb += leaf.nbytes_packed()
            else:
                pb += int(leaf.size) * 2
        flops, _, acts = _vit_cost(cfg, batch=1)
        t16, _, _ = _roof_time(flops, f16_bytes, acts * 2, PEAK_FLOPS_BF16)
        tmx, _, _ = _roof_time(flops, pb, acts * 8.5 / 8, PEAK_FLOPS_INT8)
        rows.append((f"table7/{cfg.name}", 0.0,
                     f"params={n_params/1e6:.1f}M f16_bytes={f16_bytes/1e6:.1f}MB "
                     f"mxint_bytes={pb/1e6:.1f}MB "
                     f"density={f16_bytes/pb:.2f}x_vs_f16 "
                     f"fps_f16={1/t16:,.0f} fps_mxint={1/tmx:,.0f} "
                     f"gops_mxint={flops/tmx/1e9:,.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
