"""Table V / Fig 1b: model accuracy x memory density across formats.

Reproduces the STRUCTURE of the paper's Table V on the synthetic-task DeiT
(W and A both quantized, PTQ, no fine-tuning):

    Float32 | Float8 (e4m3) | Int16 | Int8 (per-tensor) |
    MXInt8/MXInt8 | MXInt6/MXInt8 | MXInt6/MXInt6 | MXInt4/MXInt6

Qualitative claims checked:
  * Int8 per-tensor collapses vs MXInt8 at the same bitwidth;
  * MXInt8 is within 1% of Float32 at ~4x density;
  * accuracy is monotone in mantissa bits.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks import common
from repro.core.mx_types import MXFormat, QuantConfig
from repro.configs.deit import DEIT_MICRO
from repro.models import build_model


def _row_cfg(w_bits, a_bits, emulate=None):
    return QuantConfig(
        mode="fake",
        weight_fmt=MXFormat(mant_bits=w_bits, block_size=256),
        act_fmt=MXFormat(mant_bits=a_bits, block_size=16),
        emulate=emulate)


ROWS = [
    ("float32", None, 1.0),
    ("float8_e4m3", _row_cfg(8, 8, emulate="fp8"), 4.0),
    ("int16_w16a16", _row_cfg(16, 16, emulate="int"), 2.0),
    ("int8_w8a8", _row_cfg(8, 8, emulate="int"), 4.0),
    ("mxint8_w8.03/a8.5", _row_cfg(8, 8), 32 / 8.03),
    ("mxint6_w6.03/a8.5", _row_cfg(6, 8), 32 / 6.03),
    ("mxint6_w6.03/a6.5", _row_cfg(6, 6), 32 / 6.03),
    ("mxint4_w4.03/a6.5", _row_cfg(4, 6), 32 / 4.03),
]


def run():
    model, params = common.trained_deit_micro()
    base_acc = common.eval_accuracy(model, params)
    rows = []
    accs = {}
    for name, qcfg, density in ROWS:
        if qcfg is None:
            m = model
        else:
            m = build_model(dataclasses.replace(common.BENCH_DEIT,
                                                quant=qcfg))
        t0 = time.perf_counter()
        acc = common.eval_accuracy(m, params)
        us = (time.perf_counter() - t0) * 1e6
        accs[name] = acc
        rows.append((f"table5/{name}", round(us, 1),
                     f"acc={acc:.4f} delta={acc - base_acc:+.4f} "
                     f"density={density:.2f}x"))

    checks = {
        "mxint8_within_1pct":
            accs["mxint8_w8.03/a8.5"] >= base_acc - 0.01,
        "monotone_mx_bits":
            accs["mxint4_w4.03/a6.5"] <= accs["mxint6_w6.03/a6.5"] + 0.02
            and accs["mxint6_w6.03/a8.5"] <= accs["mxint8_w8.03/a8.5"] + 0.02,
    }
    rows.append(("table5/claims", 0.0,
                 " ".join(f"{k}={v}" for k, v in checks.items())))
    rows += outlier_microbench()
    return rows


def outlier_microbench():
    """The WHY of Table V's Int8 collapse, isolated: a tensor with a
    realistic outlier profile (0.1% of dims at 100x magnitude, the
    LLM.int8()/ViT phenomenon).  Per-tensor int8 sets its LSB from the
    outliers and destroys the small-signal dims; MXInt's per-block
    exponents keep both.  Reported as SQNR (dB) on the small-signal dims —
    deterministic, model-free.

    (The accuracy rows above do not show the collapse: a micro-DeiT
    trained on synthetic data has benign weight/activation distributions.
    This bench demonstrates the mechanism the paper's ImageNet models hit.)
    """
    import numpy as np
    import jax.numpy as jnp
    from repro.core.quantize import per_tensor_int_qdq, quantize_dequantize

    rng = np.random.default_rng(0)
    x = rng.normal(size=(256, 1024)).astype(np.float32)
    out_idx = rng.choice(1024, size=1, replace=False)
    x[:, out_idx] *= 100.0
    xj = jnp.asarray(x)
    small = np.ones(1024, bool)
    small[out_idx] = False

    def sqnr_db(ref, got):
        num = float(np.sum(ref[:, small] ** 2))
        den = float(np.sum((ref[:, small] - got[:, small]) ** 2)) + 1e-12
        return 10 * np.log10(num / den)

    int8 = np.asarray(per_tensor_int_qdq(xj, 8))
    mx8 = np.asarray(quantize_dequantize(
        xj, MXFormat(mant_bits=8, block_size=16), axis=-1))
    s_int8 = sqnr_db(x, int8)
    s_mx8 = sqnr_db(x, mx8)
    return [
        ("table5/outlier_sqnr_int8_db", 0.0, f"{s_int8:.1f}"),
        ("table5/outlier_sqnr_mxint8_db", 0.0, f"{s_mx8:.1f}"),
        ("table5/outlier_claim", 0.0,
         f"mxint_isolates_outliers={s_mx8 - s_int8 > 20}"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
